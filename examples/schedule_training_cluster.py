"""Computing + networking integration, end to end (the paper's thesis).

Takes REAL per-step compute/communication profiles from the multi-pod
dry-run (experiments/dryrun_results.json), converts them into DCSim jobs
via repro.core.bridge, and compares a computing-only scheduler
(performance_first) against the computing+networking schedulers (jobgroup
co-location, netaware delay/congestion-priced placement) on the paper's
heterogeneous testbed.

    PYTHONPATH=src python examples/schedule_training_cluster.py
"""
import os
import sys
sys.path.insert(0, "src")

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, run_sim, summarize)
from repro.core.bridge import MLJobSpec, jobs_from_results, workload_from_jobs

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_results.json")


def fallback_jobs():
    """Analytic job mix if the dry-run results are absent."""
    return [
        MLJobSpec("smollm-360m", "train_4k", 6, 10, 1.5e14, 5e9, 4.0),
        MLJobSpec("qwen2.5-3b", "train_4k", 6, 10, 1.2e14, 7e9, 8.0),
        MLJobSpec("olmoe-1b-7b", "train_4k", 6, 10, 6e13, 9e9, 8.0),
    ]


def main() -> None:
    jobs = (jobs_from_results(RESULTS, n_workers=6, steps=10)
            if os.path.exists(RESULTS) else fallback_jobs())
    print(f"scheduling {len(jobs)} ML jobs "
          f"({sum(j.n_workers for j in jobs)} containers):")
    for j in jobs:
        print(f"  {j.arch:20s} {j.flops_per_step:9.2e} FLOP/step/worker  "
              f"{j.coll_bytes_per_step/2**30:6.2f} GiB/step collectives")

    cfg = SimConfig(horizon=220, max_containers_per_host=10)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg, bw=10000.0)

    print(f"\n{'policy':20s} {'completed':>9s} {'avg_runtime':>11s} "
          f"{'avg_comm':>9s} {'cost':>8s}")
    results = {}
    for policy in ["performance_first", "jobgroup", "netaware"]:
        conts = workload_from_jobs(jobs, cfg)
        sim0 = init_sim(hosts, conts, net)
        final, metrics = run_sim(sim0, cfg, get_policy(policy),
                                 spec.n_hosts, spec.n_nodes, cfg.horizon)
        rep = summarize(final, metrics)
        results[policy] = rep
        print(f"{policy:20s} {rep['n_completed']:9d} "
              f"{rep['avg_runtime']:11.2f} {rep['avg_comm_time']:9.2f} "
              f"{rep['total_cost']:8.0f}")

    best = min(("jobgroup", "netaware"),
               key=lambda p: results[p]["avg_runtime"])
    ratio = (results["performance_first"]["avg_runtime"]
             / max(results[best]["avg_runtime"], 1e-9))
    if ratio >= 1.0:
        print(f"\ncomputing+networking scheduling ({best}) runs ML jobs "
              f"{ratio:.2f}x faster than computing-only placement")
    else:
        print(f"\ncomputing-only placement wins on this profile "
              f"({1 / ratio:.2f}x faster than {best}) — the network-aware "
              f"policies pay off under fabric contention, not fat idle links")


if __name__ == "__main__":
    main()
