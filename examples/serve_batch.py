"""Batched serving: prefill a batch of prompts, greedy-decode continuations
(reduced Qwen2.5 config on CPU; full configs via launch/serve.py on TPU).

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys
sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_mesh_for
from repro.models import transformer
from repro.serve.step import generate

cfg = get_reduced("qwen2.5-3b")
mesh = make_mesh_for(jax.device_count())
params = transformer.init_params(cfg, jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
B, prompt_len, gen_len = 4, 48, 24
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, prompt_len)),
                               jnp.int32)}

with mesh:
    t0 = time.time()
    out = np.asarray(generate(cfg, params, batch, gen_len))
    dt = time.time() - t0

print(f"generated {out.shape} in {dt:.2f}s "
      f"({B * gen_len / dt:.1f} tok/s incl. compile)")
for i in range(B):
    print(f"  seq{i}: {out[i][:12].tolist()} ...")
assert out.shape == (B, gen_len)
assert (out >= 0).all()
print("ok")
