"""Quickstart: reproduce the paper's headline experiment in one file.

Runs all five scheduling algorithms on the paper's exact testbed
(Table 5 hosts, Table 6 workload, Fig 3 spine-leaf fabric) and prints the
evaluation metrics of §4.1.  ~30 s on a laptop CPU (one XLA compile per
policy, then the whole 120 s simulation runs as a single compiled program).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import (ExecPlan, SimConfig, build_paper_hosts,
                        build_paper_network, get_policy, init_sim,
                        list_policies, paper_workload, run_sim, summarize)


def main() -> None:
    cfg = SimConfig()                        # paper Table 6 defaults
    hosts = build_paper_hosts()              # paper Table 5 hosts
    spec, net = build_paper_network(cfg)     # paper Fig 3 spine-leaf

    print(f"{'policy':20s} {'completed':>9s} {'avg_resp':>9s} "
          f"{'avg_runtime':>11s} {'avg_comm':>9s} {'cost':>8s}")
    for name in list_policies():
        containers = paper_workload(cfg, seed=0)
        sim0 = init_sim(hosts, containers, net, seed=0)
        final, metrics = run_sim(sim0, cfg, get_policy(name),
                                 spec.n_hosts, spec.n_nodes, cfg.horizon)
        rep = summarize(final, metrics)
        print(f"{name:20s} {rep['n_completed']:9d} "
              f"{rep['avg_response_time']:9.2f} {rep['avg_runtime']:11.2f} "
              f"{rep['avg_comm_time']:9.2f} {rep['total_cost']:8.0f}")

    # Streaming mode (PR 7): the same run chunked, with O(state) online
    # summaries instead of the stacked per-tick series — the way to run
    # horizons where [T]-stacked metrics would not fit.  Same final
    # state bit-for-bit; summarize() accepts either representation.
    containers = paper_workload(cfg, seed=0)
    sim0 = init_sim(hosts, containers, net, seed=0)
    final, online = run_sim(sim0, cfg, get_policy("netaware"),
                            spec.n_hosts, spec.n_nodes, cfg.horizon,
                            plan=ExecPlan(chunk=32))
    rep = summarize(final, online)
    print(f"\nstreaming (chunk=32)  netaware: completed="
          f"{rep['n_completed']}, mean_util={rep['mean_util']:.3f}, "
          f"peak_running={rep['peak_running']} "
          f"(summary folded online, no [T] metrics stack)")


if __name__ == "__main__":
    main()
