"""Train a ~360M-param-family LM (reduced size for CPU) for a few hundred
steps with checkpointing, restart recovery and deterministic data replay.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

On a TPU pod slice the SAME code trains the full config: the mesh grows to
(data, model) = (16, 16), the sharding specs in repro/models/sharding.py
apply unchanged, and launch/dryrun.py proves the program compiles there.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, make_dataset
from repro.distributed import checkpoint as ckpt
from repro.launch.mesh import make_mesh_for
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_reduced("smollm-360m")
    mesh = make_mesh_for(jax.device_count())
    data = make_dataset(DataConfig(seq_len=args.seq,
                                   global_batch=args.batch,
                                   vocab=cfg.vocab, seed=0))
    train_step = jax.jit(
        make_train_step(cfg, OptimizerConfig(lr=1e-3, warmup_steps=20,
                                             total_steps=args.steps)),
        donate_argnums=(0,))

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    start = 0
    latest = ckpt.latest_step_dir(args.ckpt_dir)
    if latest:
        state, start = ckpt.restore_checkpoint(latest, state)
        print(f"resumed from step {start}")

    losses = []
    with mesh:
        for step in range(start, args.steps):
            batch = {k: jax.device_put(v)
                     for k, v in data.batch_at(step).items()}
            state, m = train_step(state, batch)
            losses.append(float(m["loss"]))
            if step % 25 == 0:
                print(f"step {step:4d}  loss {losses[-1]:.4f}")
            if step > 0 and step % 100 == 0:
                ckpt.save_checkpoint(f"{args.ckpt_dir}/step_{step}",
                                     state, step)

    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], "training did not reduce loss"
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
