"""Render the data-driven sections of EXPERIMENTS.md from the result JSONs.

    PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
import json
import os

HERE = os.path.dirname(__file__)


def fmt(x, nd=3):
    return f"{x:.{nd}f}" if isinstance(x, (int, float)) else str(x)


NON_TP_HEADS = {"smollm_360m", "phi4_mini_3_8b", "minitron_4b"}
MOE = {"deepseek_v2_236b", "olmoe_1b_7b"}
SSM = {"mamba2_1_3b", "zamba2_1_2b"}


def fix_hint(r) -> str:
    """One sentence: what would move the dominant term down (§Roofline)."""
    arch, shape, dom = r["arch"], r["shape"], r["bottleneck"]
    if shape.startswith("decode") or shape.startswith("long"):
        if dom == "collective":
            return ("batch more decode steps per dispatch; keep cache "
                    "T-sharded to skip the per-step gather")
        return ("decode is cache-bandwidth bound by construction; raise "
                "arithmetic intensity by batching requests")
    if arch in NON_TP_HEADS:
        return ("seq_parallel=full: heads don't divide the model axis, so "
                "the baseline replicates attention 16x (measured 15.8x / "
                "3.8x wins, §Perf)")
    if arch in MOE and dom in ("memory", "collective"):
        return ("moe_impl=a2a (+sp_full): removes replicated dispatch and "
                "the full-token combine psum (measured 1.9x, §Perf)")
    if arch in SSM and dom == "memory":
        return ("smaller ssm_chunk or the Pallas ssd_scan kernel keeps the "
                "[Q,Q] dual-form block in VMEM instead of HBM round-trips")
    if dom == "collective":
        return ("seq_parallel=full converts TP output psums into bf16 "
                "weight gathers (measured 29.6x on phi4, §Perf)")
    if dom == "memory":
        return ("flash-attention Pallas lowering avoids materializing "
                "S^2 logits; CPU-fusion bias also overstates this term")
    return "compute-bound: already near the useful-flops ceiling for " \
           "this shape"


def dryrun_tables():
    rows = json.load(open(os.path.join(HERE, "dryrun_results.json")))
    for mesh in ("single", "multi"):
        sel = sorted((r for r in rows if r["mesh"] == mesh),
                     key=lambda r: (r["arch"], r["shape"]))
        print(f"\n### Dry-run — {'16x16 (256 chips)' if mesh == 'single' else '2x16x16 (512 chips, 2 pods)'}\n")
        print("| arch | shape | status | bottleneck | t_compute (s) | "
              "t_memory (s) | t_collective (s) | useful | coll GiB/dev | "
              "what moves the dominant term |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in sel:
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                      f"{r.get('reason', r.get('error',''))[:60]} | | | | | | |")
                continue
            print(f"| {r['arch']} | {r['shape']} | ok | {r['bottleneck']} | "
                  f"{fmt(r['t_compute'], 4)} | {fmt(r['t_memory'], 3)} | "
                  f"{fmt(r['t_collective'], 3)} | {fmt(r['useful_ratio'])} | "
                  f"{fmt(r['coll_bytes_per_dev'] / 2**30, 1)} | "
                  f"{fix_hint(r)} |")


def hillclimb_table():
    path = os.path.join(HERE, "hillclimb_results.json")
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### Perf hillclimb\n")
    print("| cell | variant | bottleneck | t_compute | t_memory | "
          "t_collective | useful | dominant-term Δ |")
    print("|---|---|---|---|---|---|---|---|")
    base = {}
    for r in rows:
        if "error" in r:
            print(f"| {r['cell']} | {r['variant']} | ERROR "
                  f"{r['error'][:50]} | | | | | |")
            continue
        dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        if r["variant"] == "baseline":
            base[r["cell"]] = dom
        delta = (f"{base[r['cell']] / dom:.1f}x better"
                 if r["cell"] in base and dom > 0 else "-")
        print(f"| {r['cell']} | {r['variant']} | {r['bottleneck']} | "
              f"{fmt(r['t_compute'])} | {fmt(r['t_memory'])} | "
              f"{fmt(r['t_collective'])} | {fmt(r['useful_ratio'], 4)} | "
              f"{delta} |")


def bench_table():
    path = os.path.join(HERE, "bench_rows.json")
    if not os.path.exists(path):
        return
    rows = json.load(open(path))
    print("\n### Benchmark rows (paper figures)\n")
    for name, rs in rows.items():
        print(f"\n**{name}** — {len(rs)} rows")
        if not rs:
            continue
        keys = list(rs[0].keys())
        print("| " + " | ".join(keys) + " |")
        print("|" + "---|" * len(keys))
        for r in rs[:30]:
            print("| " + " | ".join(str(r.get(k, "")) for k in keys) + " |")


if __name__ == "__main__":
    dryrun_tables()
    hillclimb_table()
    bench_table()
