"""Perf hillclimb driver: probe roofline terms for config variants of the
three chosen cells (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python experiments/hillclimb.py [--cell NAME]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import probe_costs
from repro.launch.mesh import make_production_mesh
from repro.train.step import StepConfig

OUT = os.path.join(os.path.dirname(__file__), "hillclimb_results.json")

# cell -> list of (variant-name, config-overrides)
CELLS = {
    # worst useful-ratio cell: 15 heads don't divide model=16 -> S^2 logits
    # replicated on every model shard
    "smollm_prefill": ("smollm-360m", "prefill_32k", [
        ("baseline", {}),
        ("sp_attn", {"seq_parallel": "attn"}),
        ("sp_full", {"seq_parallel": "full"}),
    ]),
    # most collective-bound cell (24H % 16 != 0 as well)
    "phi4_prefill": ("phi4-mini-3.8b", "prefill_32k", [
        ("baseline", {}),
        ("sp_attn", {"seq_parallel": "attn"}),
        ("sp_full", {"seq_parallel": "full"}),
    ]),
    # the paper-representative cell: flagship MoE training step
    "deepseek_train": ("deepseek-v2-236b", "train_4k", [
        ("baseline", {}),
        # iteration 2: bf16 rope (apply_rope no longer leaks f32 q/k) +
        # explicit head-sharding constraints inside MLA prefill
        ("rope_bf16+mla_headshard", {}),
        ("moe_a2a", {"moe_impl": "a2a"}),
        ("sp_full", {"seq_parallel": "full"}),
        ("moe_a2a+sp_full", {"moe_impl": "a2a", "seq_parallel": "full"}),
    ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    args = ap.parse_args()

    results = []
    if os.path.exists(OUT):
        results = json.load(open(OUT))
    done = {(r["cell"], r["variant"]) for r in results}

    mesh = make_production_mesh()
    cells = {args.cell: CELLS[args.cell]} if args.cell else CELLS
    for cell, (arch, shape_name, variants) in cells.items():
        for vname, overrides in variants:
            if (cell, vname) in done:
                print(f"[cached] {cell}/{vname}")
                continue
            cfg = dataclasses.replace(get_config(arch), **overrides)
            t0 = time.time()
            try:
                terms = probe_costs(cfg, SHAPES[shape_name], mesh,
                                    StepConfig())
                row = {
                    "cell": cell, "variant": vname, "arch": arch,
                    "shape": shape_name,
                    "t_compute": terms.t_compute,
                    "t_memory": terms.t_memory,
                    "t_collective": terms.t_collective,
                    "bottleneck": terms.bottleneck,
                    "useful_ratio": round(terms.useful_ratio, 4),
                    "flops": terms.flops, "hbm_bytes": terms.hbm_bytes,
                    "coll_bytes": terms.coll_bytes,
                    "coll_breakdown": terms.coll_breakdown,
                    "wall_s": round(time.time() - t0, 1),
                }
            except Exception as e:
                row = {"cell": cell, "variant": vname, "arch": arch,
                       "shape": shape_name, "error": f"{type(e).__name__}: {e}"}
            results.append(row)
            json.dump(results, open(OUT, "w"), indent=1)
            dom = row.get("bottleneck", "ERR")
            print(f"[{cell}/{vname}] bound={dom} "
                  f"t=({row.get('t_compute',0):.3f},{row.get('t_memory',0):.3f},"
                  f"{row.get('t_collective',0):.3f})s "
                  f"useful={row.get('useful_ratio')} "
                  f"({row.get('wall_s','-')}s)", flush=True)
            jax.clear_caches()


if __name__ == "__main__":
    main()
