"""Tracked engine benchmark -> BENCH_engine.json (ISSUE 1 acceptance).

Measures steady-state ``ticks_per_s`` and ``state_mb`` per scale point so
the perf trajectory is tracked across PRs.  At the 500-host/3000-container
point BOTH flow engines run in the same process, giving an apples-to-apples
``sparse_speedup`` of the segment-based flow path over the dense [F, E]
oracle; the 2000-host point runs sparse-only (the dense membership tensor
at that scale is the OOM ceiling this PR removes).

ISSUE 3 adds the ``sweep`` entry: the 6-policy x 4-scenario ladder as ONE
compiled call (compile-cache-miss counter recorded), against the per-point
cold (compile + run) loop the pre-policy-as-data architecture paid — one
XLA compilation per (policy, scenario) point, reproduced with
``jax.clear_caches()`` between calls.

ISSUE 4: the sweep is fully vmapped (policy x scenario x seed), the entry
grows ``vmap_cell_tax`` (vmapped per-cell steady time / mean warm
standalone cell), and full mode re-measures the quick-scale grid into
``sweep_quick`` — the committed baseline ``benchmarks/check_regression.py``
gates CI quick runs against (30% tolerance).

ISSUE 5 (branch-free scoring): the policy axis no longer evaluates every
registered branch under ``vmap`` — ``vmap_cell_tax`` is the tracked
acceptance number (target <= 1.25 at the 24-cell 500h/3000c grid) — and a
``tune`` smoke entry measures the weight-search driver
(``repro.launch.tune``: weight samples on the policy batch axis, one
compile) so the learned-weights path is regression-gated too.

ISSUE 7 (streaming engine) adds the ``longhorizon`` entry
(``benchmarks/longhorizon_bench.py``): subprocess max-RSS of the chunked
streaming run vs the stacked per-tick path at a long horizon.  Full mode
demonstrates the crossing — streaming completes under a fixed
``ceiling_mb`` the stacked run's scan-ys buffer exceeds (the stacked child
is killed at the crossing by a VmHWM poll); quick mode re-measures the
streaming side only, gated absolutely against the committed ceiling.

ISSUE 6 turns this into a backend LADDER: every point records the JAX
``backend``/``device`` it ran on, and the full bench adds kernel-on
('auto') vs kernel-off ('off') variants of the 500h/3000c and 2000h/6000c
points under ``delay_mode='fw'`` — the APSP refresh the ``fw_minplus``
Pallas kernel fuses — plus a cheap 100h/1500c fw pair both modes measure
(so the CI quick gate exercises the kernel dispatch path too).  On CPU,
'auto' resolves to the jnp reference (``kernels_active: false`` in the
row), so the on/off pair measures the same code there; the pair only
separates on TPU/GPU.  check_regression.py refuses cross-backend
comparisons outright.

ISSUE 8 (multi-process fabric) adds the ``sweep_dist`` entry: the same
smoke grid through the in-process streamed sweep and three spawned
``repro.launch.dist`` arms (1 proc, 2 procs, 2 procs serial-gather),
gated on bit-identical results, the <=2/process compile bill, and the
within-run overlap ratio; full mode also appends a headline row to
``BENCH_history.jsonl`` via ``benchmarks.archive``.

ISSUE 9 (differentiable simulator) adds the ``tune_grad`` entry: gradient
descent on the soft-placement surrogate (``run_tune_grad`` — one
value_and_grad executable + one hard-oracle executable, tau annealed as a
traced RunParams field) raced against an equal-oracle-budget random
search.  Gated numbers (``grad_vs_random``, the 2-executable compile
bill) are within-run and machine-independent; the cold wall stays out of
the skew-normalized pack.

ISSUE 10 (event-horizon telescoping) adds the ``telescope`` entry: the
sparse-event long-horizon point (4h/16c, 30k ticks, 8 seeds, refresh
interval 100) through the vmapped streaming driver with the macro-tick
engine on vs off.  Gated numbers: ``finals_bitwise_equal`` (must be true
— telescoping is an exact transform, docs/events.md), the within-run
``telescope_speedup`` (the ISSUE 10 >= 3x acceptance), and the ON-side
``ticks_per_s`` in the skew-normalized ratio pack.  Both modes measure
the same grid, so quick CI runs gate like-for-like.

    PYTHONPATH=src python -m benchmarks.engine_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import measure_scale_point

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")
# --quick runs must not clobber the tracked full-ladder artifact
BENCH_QUICK_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "BENCH_engine_quick.json")

# the quick-mode sweep grid — the FULL bench measures the same grid into
# the committed ``sweep_quick`` entry, so the CI regression gate
# (benchmarks/check_regression.py) has a like-for-like baseline
QUICK_SWEEP = dict(n_hosts=50, n_containers=300, horizon=40)
# the tune smoke grid: both modes measure the SAME grid (the quick run is
# gated against the committed entry like-for-like)
TUNE_SMOKE = dict(n_hosts=50, n_containers=300, horizon=40, samples=8)
# the differentiable-tuning smoke grid (ISSUE 9): the slow-net scenario
# where placement weights have headroom, small enough that 6 grad steps +
# the equal-budget random race fit in the quick bench.  steps=6 with
# eval_every=3 spends exactly 3 oracle rounds x batch candidates, so the
# random arm gets n_samples = oracle_evals — a like-for-like budget.
TUNE_GRAD_SMOKE = dict(n_hosts=20, n_containers=40, horizon=30, steps=6,
                       batch=4)
# the multi-process fabric smoke grid (ISSUE 8): small enough that three
# spawned arms fit in the quick bench, large enough for several slabs per
# worker (24 cells / slab 6 = 4 slabs) so the handout and the overlapped
# gather actually cycle
DIST_SMOKE = dict(n_hosts=20, n_containers=120, horizon=40, chunk=20,
                  slab=6)
# the telescoping point (ISSUE 10): a tiny fleet at a LONG horizon with a
# sparse event stream (1 placement/tick, refresh every 100 ticks) — the
# regime the macro-tick engine exists for, quiescent tail included.  Both
# modes measure the same grid; the off arm dominates the wall (~tens of
# seconds of per-tick streaming on CPU).
TELESCOPE_SMOKE = dict(n_hosts=4, n_containers=16, horizon=30_000, seeds=8,
                       chunk=4096, interval=100)


def _timed(f) -> float:
    t0 = time.time()
    f()
    return time.time() - t0


def bench_scenarios():
    """The 4-scenario ladder of the sweep entry: the scenario layer's own
    healthy-fabric + Fig 5/8 bw/loss degradations, plus a benchmark-only
    runtime-threshold variant."""
    from repro.core.scenario import ScenarioSpec, default_scenarios
    return default_scenarios()[:3] + [
        ScenarioSpec("tight", overload_threshold=0.5, queue_coef=1.0),
    ]


def measure_sweep_point(n_hosts: int, n_containers: int, horizon: int,
                        with_loop: bool = True) -> dict:
    """6 policies x 4 scenarios x 1 seed in one fully-vmapped compiled call,
    vs (a) warm standalone cells — the ``vmap_cell_tax`` the scatter-free
    tick is accountable for — and (b, full mode) the old-world per-point
    cold loop (compile + run each, via clear_caches)."""
    import jax

    from repro.core import SimConfig, get_policy, list_policies, run_sim
    from repro.core.scenario import build_scenarios
    from repro.launch.sweep import make_sweep_fn, stack_policies

    cfg = SimConfig(n_jobs=max(10, n_containers // 3), n_tasks=n_containers,
                    n_containers=n_containers, horizon=horizon)
    n_leaf = max(4, n_hosts // 5)
    specs = bench_scenarios()
    net_spec, sims, rps = build_scenarios(
        specs, cfg, n_hosts=n_hosts, n_spine=max(2, n_leaf // 4),
        n_leaf=n_leaf, seeds=(0,))
    pols = list_policies()
    pol = stack_policies(pols)
    cells = len(pols) * len(specs)

    jax.clear_caches()
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, horizon)
    t0 = time.time()
    fn(sims, pol, rps)[0].t.block_until_ready()
    cold = time.time() - t0

    # Warm standalone reference: mean steady cell over ALL (policy,
    # scenario) cells — the denominator of the vmapped per-cell tax.
    # Scenarios do genuinely different amounts of work (lossy fabrics
    # retransmit, bursts pile up queues), so a baseline-scenario-only
    # reference would overstate the tax.  One compilation covers all
    # cells (policy and runtime params are data), so this is warm after
    # the first pass.  The sweep reps and the standalone passes are
    # INTERLEAVED in rounds, taking the min over rounds of each side:
    # host-level contention on a shared box is bursty on the minutes
    # scale, and measuring numerator and denominator minutes apart turns
    # one burst into a bogus tax ratio — with interleaving, any clean
    # round yields a clean ratio.
    def solo_pass():
        solo = 0.0
        for s in range(len(specs)):
            sim0 = jax.tree.map(lambda x: x[s, 0], sims)
            rp0 = jax.tree.map(lambda x: x[s], rps)
            for p in pols:
                solo += _timed(
                    lambda: run_sim(sim0, cfg, get_policy(p),
                                    net_spec.n_hosts, net_spec.n_nodes,
                                    horizon,
                                    params=rp0)[0].t.block_until_ready())
        return solo / cells

    solo_pass()                                   # warm every cell's cache
    sweeps, solos = [], []
    for _ in range(4):
        sweeps.append(_timed(
            lambda: fn(sims, pol, rps)[0].t.block_until_ready()))
        solos.append(solo_pass())
    steady = min(sweeps)
    standalone_cell = min(solos)

    out = {
        "n_hosts": n_hosts,
        "n_containers": n_containers,
        "horizon": horizon,
        "policies": len(pols),
        "scenarios": len(specs),
        "seeds": 1,
        "cells": cells,
        "vmap_axes": "policy,scenario,seed",
        "compile_cache_misses": fn._cache_size(),
        "sweep_cold_s": round(cold, 2),
        "sweep_steady_s": round(steady, 2),
        "cells_per_s": round(cells / max(steady, 1e-9), 2),
        "per_cell_steady_s": round(steady / cells, 4),
        "standalone_cell_s": round(standalone_cell, 4),
        "vmap_cell_tax": round(steady / cells / max(standalone_cell, 1e-9),
                               2),
    }
    if with_loop:
        total = 0.0
        for s in range(len(specs)):
            sim0 = jax.tree.map(lambda x: x[s, 0], sims)
            rp = jax.tree.map(lambda x: x[s], rps)
            for p in pols:
                jax.clear_caches()
                t0 = time.time()
                run_sim(sim0, cfg, get_policy(p), net_spec.n_hosts,
                        net_spec.n_nodes, horizon,
                        params=rp)[0].t.block_until_ready()
                total += time.time() - t0
        out["per_point_cold_loop_s"] = round(total, 2)
        out["sweep_speedup_vs_loop"] = round(total / cold, 2)
    return out


def measure_tune_point(n_hosts: int, n_containers: int, horizon: int,
                       samples: int) -> dict:
    """Weight-search smoke: ``samples`` weight vectors x 3 scenarios x 1
    seed through the compiled sweep (one jit; ``run_tune``'s wall clock
    includes the cold compile after ``clear_caches``).  Also records how
    much the best random sample improves on the registered incumbent —
    the simplest tracked signal that the search finds signal."""
    import jax

    from repro.core import SimConfig
    from repro.launch.tune import run_tune

    cfg = SimConfig(n_jobs=max(10, n_containers // 3), n_tasks=n_containers,
                    n_containers=n_containers, horizon=horizon)
    n_leaf = max(4, n_hosts // 5)
    jax.clear_caches()
    res = run_tune(n_samples=samples, seeds=(0,), cfg=cfg, n_hosts=n_hosts,
                   n_spine=max(2, n_leaf // 4), n_leaf=n_leaf,
                   objective="avg_runtime", reps=3)
    import numpy as np
    cells = samples * len(res.scenarios) * len(res.seeds)
    incumbent, best = float(res.scores[0]), float(res.scores[res.best])
    return {
        "n_hosts": n_hosts,
        "n_containers": n_containers,
        "horizon": horizon,
        "samples": samples,
        "scenarios": len(res.scenarios),
        "seeds": len(res.seeds),
        "cells": cells,
        "compile_cache_misses": res.compile_cache_misses,
        "tune_cold_s": res.wall_s,
        # min warm repeat of the SAME compiled call — runtime-dominated,
        # unlike the cold wall (mostly XLA compile on this small grid);
        # this is the number check_regression's ratio pack gates
        "tune_steady_s": res.steady_s,
        "cells_per_s": round(cells / max(res.steady_s or res.wall_s, 1e-9),
                             2),
        "objective": res.objective,
        "incumbent_score": round(incumbent, 4),
        "best_score": round(best, 4),
        "best_vs_incumbent": (round(incumbent / best, 4)
                              if np.isfinite(best) and best > 0 else None),
    }


def measure_tune_grad_point(n_hosts: int, n_containers: int, horizon: int,
                            steps: int, batch: int) -> dict:
    """Differentiable-tuning smoke (ISSUE 9): descend the soft-placement
    surrogate with ``jax.grad`` through the compiled sweep
    (``run_tune_grad``: one value_and_grad executable + one hard-oracle
    executable, tau annealed as a traced RunParams field), then race the
    SAME oracle budget of random search through ``run_tune``.  Tracked
    numbers are within-run and machine-independent:

    * ``grad_vs_random``    — random-best / grad-best oracle score on the
      minimized objective (>1 means gradient search wins at equal budget
      — the ISSUE 9 acceptance claim);
    * ``grad_vs_incumbent`` — incumbent / grad-best (>= 1 by
      construction: the incumbent is oracle-scored before step 0);
    * ``compile_cache_misses`` — must stay at 2 (surrogate + oracle);
      tau/weights ride traced leaves, so annealing never recompiles.

    The cold wall is compile-bound at smoke scale and stays out of
    check_regression's skew-normalized ratio pack (like tune_cold_s)."""
    import jax
    import numpy as np

    from repro.core import SimConfig
    from repro.core.scenario import ScenarioSpec
    from repro.launch.tune import run_tune, run_tune_grad

    cfg = SimConfig(n_jobs=max(10, n_containers // 4), n_tasks=n_containers,
                    n_containers=n_containers, horizon=horizon,
                    arrival_window=10.0, placements_per_tick=16,
                    migrations_per_tick=2)
    scen = [ScenarioSpec("slow_net", bw=200.0)]
    jax.clear_caches()
    t0 = time.time()
    g = run_tune_grad(steps=steps, batch=batch, lr=0.3, eval_every=3,
                      seeds=(0,), scenarios=scen, cfg=cfg, n_hosts=n_hosts,
                      n_spine=2, n_leaf=4, objective="avg_runtime", seed=0)
    grad_wall = time.time() - t0
    # the equal-budget random arm: as many oracle-scored samples as the
    # grad run spent, same base/space/seed machinery, same hard oracle —
    # its row 0 is the untouched incumbent, which the grad result does
    # not carry separately
    r = run_tune(n_samples=g.oracle_evals, seeds=(0,), scenarios=scen,
                 cfg=cfg, n_hosts=n_hosts, n_spine=2, n_leaf=4,
                 objective="avg_runtime", seed=0)
    random_best = float(r.scores[r.best])
    incumbent = float(r.scores[0])

    def vs(a, b):
        return (round(a / b, 4)
                if np.isfinite(a) and np.isfinite(b) and b > 0 else None)

    return {
        "n_hosts": n_hosts,
        "n_containers": n_containers,
        "horizon": horizon,
        "steps": steps,
        "batch": batch,
        "scenarios": len(scen),
        "seeds": 1,
        "objective": g.objective,
        "surrogate": g.surrogate_name,
        "compile_cache_misses": g.compile_cache_misses,
        "tune_grad_cold_s": round(grad_wall, 2),
        "surrogate_evals": g.surrogate_evals,
        "oracle_evals": g.oracle_evals,
        "tau_final": g.history[-1]["tau"] if g.history else None,
        "incumbent_score": round(incumbent, 4),
        "best_oracle": round(g.best_oracle, 4),
        "random_best": round(random_best, 4),
        "grad_vs_incumbent": vs(incumbent, g.best_oracle),
        "grad_vs_random": vs(random_best, g.best_oracle),
    }


def measure_telescope_point(n_hosts: int, n_containers: int, horizon: int,
                            seeds: int, chunk: int, interval: int) -> dict:
    """Event-horizon telescoping (ISSUE 10): the vmapped streaming run at
    a sparse-event long horizon, macro-tick engine off vs on.

    The off arm is the PR 7 chunked per-tick path; the on arm is
    ``engine.simulate_telescoped`` through the same driver
    (``run_sim_vmapped(telescope=True)``).  Tracked numbers:

    * ``finals_bitwise_equal`` — telescoping is an exact transform; the
      final states must agree to the bit (hard gate);
    * ``telescope_speedup``   — within-run off/on wall ratio (the >= 3x
      ISSUE 10 acceptance; machine-independent);
    * ``on_ticks_per_s``      — the ON-side throughput for the
      skew-normalized ratio pack;
    * ``n_full_ticks_seed0``  — how many ticks actually ran as full ticks
      on seed 0 (``with_stats``), i.e. how much telescoping there was.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (SimConfig, build_paper_network, get_policy,
                            init_sim, paper_workload, scaled_hosts)
    from repro.core import stats
    from repro.core.engine import simulate_telescoped
    from repro.launch.sweep import run_sim_vmapped

    cfg = SimConfig(n_jobs=max(4, n_containers // 3), n_tasks=n_containers,
                    n_containers=n_containers, horizon=horizon,
                    placements_per_tick=1, migrations_per_tick=1,
                    waterfill_rounds=2, delay_update_interval=interval)
    hosts = scaled_hosts(n_hosts, 2)
    spec, net = build_paper_network(cfg, n_hosts=n_hosts, n_spine=2,
                                    n_leaf=2)
    pol = get_policy("firstfit")
    params = cfg.run_params()
    sim_list = [init_sim(hosts, paper_workload(cfg, seed=s), net, seed=s)
                for s in range(seeds)]
    sims = jax.tree.map(lambda *xs: jnp.stack(xs), *sim_list)

    def timed(telescope: bool):
        def run():
            return run_sim_vmapped(sims, cfg, pol, spec.n_hosts,
                                   spec.n_nodes, horizon, params=params,
                                   chunk=chunk, telescope=telescope)
        f, s = run()                                  # compile + warm
        jax.tree.leaves(f)[0].block_until_ready()
        t0 = time.time()
        f, s = run()
        jax.tree.leaves(f)[0].block_until_ready()
        return time.time() - t0, f, s

    off_t, off_f, off_s = timed(False)
    on_t, on_f, on_s = timed(True)

    def close(a, b):
        return all(np.allclose(np.asarray(x), np.asarray(y),
                               rtol=3e-6, atol=1e-6)
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    import functools
    n_full_fn = jax.jit(functools.partial(
        simulate_telescoped, cfg=cfg, policy=pol, n_hosts=spec.n_hosts,
        n_nodes=spec.n_nodes, chunk=horizon, params=params,
        with_stats=True))
    _, _, n_full = n_full_fn(sim_list[0], stats.acc_init(),
                             jnp.zeros((), jnp.int32))
    total_ticks = horizon * seeds
    return {
        "n_hosts": n_hosts,
        "n_containers": n_containers,
        "horizon": horizon,
        "seeds": seeds,
        "chunk": chunk,
        "delay_update_interval": interval,
        "policy": "firstfit",
        "off_wall_s": round(off_t, 2),
        "on_wall_s": round(on_t, 2),
        "off_ticks_per_s": round(total_ticks / max(off_t, 1e-9), 1),
        "on_ticks_per_s": round(total_ticks / max(on_t, 1e-9), 1),
        "telescope_speedup": round(off_t / max(on_t, 1e-9), 2),
        "finals_bitwise_equal": _trees_bitwise_equal(off_f, on_f),
        "summary_close": close(off_s, on_s),
        "n_full_ticks_seed0": int(n_full),
        "full_tick_fraction": round(int(n_full) / horizon, 4),
    }


def _trees_bitwise_equal(a, b) -> bool:
    """Leaf-by-leaf byte equality (NaN-safe: same bits compare equal)."""
    import jax
    import numpy as np

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.shape != y.shape or x.dtype != y.dtype \
                or x.tobytes() != y.tobytes():
            return False
    return True


def measure_dist_point(n_hosts: int, n_containers: int, horizon: int,
                       chunk: int, slab: int) -> dict:
    """Multi-process sweep fabric smoke (ISSUE 8): the same grid through
    (a) the in-process streamed sweep — the bit-identity reference — then
    three SPAWNED arms: 1 process, 2 processes, and 2 processes with the
    overlapped slab driver disabled.  Every arm must reproduce the
    reference finals+summary bit-for-bit and compile at most twice per
    process (steady jstep + final-slab remainder).  Spawned walls are
    COLD (python + jax import and XLA compile dominate at smoke scale),
    so they stay out of check_regression's skew-normalized ratio pack —
    the tracked numbers are the within-run ratios:

    * ``overlap_ratio``       — serial / overlapped max worker wall at
      2 processes (>1 means the overlapped gather hides transfer time);
    * ``dist_parallel_ratio`` — 1-proc / 2-proc max worker wall.

    On a single-core box both sit near 1.0 BY DESIGN: two worker
    processes time-share the core and there is no spare compute to hide
    gathers under.  The committed baseline records whatever the bench box
    offers and the gate compares like-for-like (plus cross-backend skip).
    """
    import jax

    from repro.core import ExecPlan, SimConfig, list_policies
    from repro.launch import dist
    from repro.launch.sweep import run_sweep

    cfg = SimConfig(n_jobs=max(10, n_containers // 3), n_tasks=n_containers,
                    n_containers=n_containers, horizon=horizon)
    n_leaf = max(4, n_hosts // 5)
    n_spine = max(2, n_leaf // 4)
    pols = list_policies()
    specs = bench_scenarios()
    cells = len(pols) * len(specs)

    jax.clear_caches()
    t0 = time.time()
    ref = run_sweep(pols, specs, seeds=(0,), cfg=cfg, n_hosts=n_hosts,
                    n_spine=n_spine, n_leaf=n_leaf,
                    plan=ExecPlan(chunk=chunk, slab=slab))
    inproc_wall = time.time() - t0

    def arm(num_procs: int, overlap: bool) -> dict:
        res = dist.run_dist_sweep(
            pols, specs, seeds=(0,), cfg=cfg, n_hosts=n_hosts,
            n_spine=n_spine, n_leaf=n_leaf,
            plan=ExecPlan(procs=num_procs, devices_per_proc=1, chunk=chunk,
                          slab=slab, overlap=overlap),
            timeout_s=600.0)
        metas = sorted(res.worker_meta, key=lambda m: m["process_index"])
        return {
            "procs": num_procs,
            "overlap": overlap,
            "wall_s": res.wall_s,
            "max_worker_wall_s": round(max(m["wall_s"] for m in metas), 2),
            "compile_cache_misses": res.compile_cache_misses,
            "slabs_per_worker": [len(m["slabs"]) for m in metas],
            "finals_match": (
                _trees_bitwise_equal(res.finals, ref.finals)
                and _trees_bitwise_equal(res.summary, ref.summary)),
        }

    arms = {
        "1proc": arm(1, True),
        "2proc": arm(2, True),
        "2proc_serial": arm(2, False),
    }

    def ratio(num, den):
        return round(arms[num]["max_worker_wall_s"]
                     / max(arms[den]["max_worker_wall_s"], 1e-9), 2)

    return {
        "n_hosts": n_hosts,
        "n_containers": n_containers,
        "horizon": horizon,
        "policies": len(pols),
        "scenarios": len(specs),
        "seeds": 1,
        "cells": cells,
        "chunk": chunk,
        "slab": slab,
        "devices_per_proc": 1,
        "inproc_wall_s": round(inproc_wall, 2),
        "arms": arms,
        "overlap_ratio": ratio("2proc_serial", "2proc"),
        "dist_parallel_ratio": ratio("1proc", "2proc"),
        "finals_match": all(a["finals_match"] for a in arms.values()),
    }


def bench_engine(quick: bool = False):
    """Rows + claims for benchmarks.run; writes BENCH_engine.json."""
    import jax

    points = []
    # small tracking points (cheap, both engines)
    for sparse in (True, False):
        points.append(measure_scale_point(100, 1500, horizon=40,
                                          sparse=sparse))
    # kernel ladder, small rung (both modes, so the CI quick gate covers
    # the dispatch path): APSP delay refresh, kernel-on vs kernel-off
    for kernels in ("auto", "off"):
        points.append(measure_scale_point(100, 1500, horizon=40,
                                          delay_mode="fw", kernels=kernels))
    # the headline comparison: 500 hosts / 3000 containers, same run
    if not quick:
        for sparse in (True, False):
            points.append(measure_scale_point(500, 3000, horizon=40,
                                              sparse=sparse))
        # policy ladder at the headline scale: static score vs the two
        # scan-carried co-location scores (jobgroup, netaware)
        for pol in ("jobgroup", "netaware"):
            points.append(measure_scale_point(500, 3000, horizon=40,
                                              policy=pol))
        # kernel ladder, headline + ceiling rungs.  The fw refresh is the
        # O(N^3) hot loop the fw_minplus kernel fuses; the 2000h point runs
        # horizon 30 (3 refreshes) because the CPU jnp reference costs ~10 s
        # per refresh at N=2500 — the ladder's point is the TPU/GPU rows,
        # where 'auto' resolves to the compiled kernel.
        for kernels in ("auto", "off"):
            points.append(measure_scale_point(500, 3000, horizon=40,
                                              delay_mode="fw",
                                              kernels=kernels))
            points.append(measure_scale_point(2000, 6000, horizon=30,
                                              delay_mode="fw",
                                              kernels=kernels))
        # beyond the dense ceiling: sparse-only 2000-host point.  Horizon 60
        # (was 20): with ~30-unit durations and a 36 s arrival window, no
        # container can FINISH inside 20 ticks, so the point used to report
        # completed: 0 and validated nothing end-to-end.
        p2000 = measure_scale_point(2000, 6000, horizon=60, sparse=True)
        assert p2000["completed"] > 0, (
            f"2000-host point completed nothing — horizon too short to "
            f"validate end-to-end behavior: {p2000}")
        points.append(p2000)

    def tps(h, c, mode, policy="firstfit", delay_mode="path",
            kernels="off"):
        for p in points:
            if ((p["n_hosts"], p["n_containers"], p["mode"],
                 p.get("policy", "firstfit"), p.get("delay_mode", "path"),
                 p.get("kernels", "off"))
                    == (h, c, mode, policy, delay_mode, kernels)):
                return p["ticks_per_s"]
        return None

    cmp_h, cmp_c = (100, 1500) if quick else (500, 3000)
    sp, de = tps(cmp_h, cmp_c, "sparse"), tps(cmp_h, cmp_c, "dense")
    speedup = round(sp / de, 2) if sp and de else None
    # the sweep entry: quick mode measures a small grid (compile-once +
    # regression-gate numbers for CI); full mode measures the 500h/3000c
    # grid against the per-point cold loop (the ISSUE 3 >=3x acceptance)
    # AND re-measures the quick grid into ``sweep_quick`` — the committed
    # baseline benchmarks/check_regression.py gates quick CI runs against
    if quick:
        sweep = measure_sweep_point(**QUICK_SWEEP, with_loop=False)
        sweep_quick = None
    else:
        sweep = measure_sweep_point(500, 3000, horizon=20, with_loop=True)
        sweep_quick = measure_sweep_point(**QUICK_SWEEP, with_loop=False)
    tune = measure_tune_point(**TUNE_SMOKE)
    # the differentiable-tuning arm (ISSUE 9): measured in BOTH modes on
    # the same smoke grid — the gated numbers (grad_vs_random, the 2-
    # executable compile bill) are within-run and machine-independent
    tune_grad = measure_tune_grad_point(**TUNE_GRAD_SMOKE)
    # the multi-process fabric arms (ISSUE 8): measured in BOTH modes on
    # the same smoke grid so the CI quick gate has a like-for-like
    # committed twin (bit-identity + compile bill + overlap ratio)
    sweep_dist = measure_dist_point(**DIST_SMOKE)
    # the telescoping arm (ISSUE 10): measured in BOTH modes on the same
    # sparse-event long-horizon grid — the gated numbers (bitwise
    # equality, the within-run on/off speedup) are machine-independent
    telescope = measure_telescope_point(**TELESCOPE_SMOKE)
    from benchmarks.longhorizon_bench import measure_longhorizon
    longhorizon = measure_longhorizon(quick=quick)
    backend = jax.default_backend()
    sweep["backend"] = backend
    tune["backend"] = backend
    tune_grad["backend"] = backend
    sweep_dist["backend"] = backend
    telescope["backend"] = backend
    out = {
        "bench": "engine_tick_throughput",
        "backend": backend,
        "device": jax.devices()[0].device_kind,
        "points": points,
        "comparison_point": {"n_hosts": cmp_h, "n_containers": cmp_c},
        "sparse_speedup": speedup,
        "sweep": sweep,
        "tune": tune,
        "tune_grad": tune_grad,
        "sweep_dist": sweep_dist,
        "telescope": telescope,
        "longhorizon": longhorizon,
    }
    if sweep_quick is not None:
        sweep_quick["backend"] = backend
        out["sweep_quick"] = sweep_quick
    if not quick:
        out["policy_comparison"] = {
            pol: tps(500, 3000, "sparse", pol)
            for pol in ("firstfit", "jobgroup", "netaware")
        }
    path = BENCH_QUICK_PATH if quick else BENCH_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    kon = tps(cmp_h if not quick else 100, cmp_c if not quick else 1500,
              "sparse", delay_mode="fw", kernels="auto")
    koff = tps(cmp_h if not quick else 100, cmp_c if not quick else 1500,
               "sparse", delay_mode="fw", kernels="off")
    claims = [
        (f"sparse vs dense ticks_per_s @ {cmp_h}h/{cmp_c}c",
         f"{sp} vs {de} ({speedup}x)"),
        (f"fw kernel ladder [{backend}] kernels=auto vs off ticks_per_s",
         f"{kon} vs {koff}"
         + ("" if backend in ("tpu", "gpu")
            else " (CPU: 'auto' -> jnp ref; pair separates on TPU/GPU)")),
        (f"sweep {sweep['cells']} cells @ {sweep['n_hosts']}h "
         f"compiled {sweep['compile_cache_misses']}x, vmap all axes",
         f"cold {sweep['sweep_cold_s']}s, steady {sweep['sweep_steady_s']}s, "
         f"per-cell {sweep['per_cell_steady_s']}s = "
         f"{sweep['vmap_cell_tax']}x standalone"
         + (f", {sweep['sweep_speedup_vs_loop']}x vs per-point cold loop"
            if "sweep_speedup_vs_loop" in sweep else "")),
        (f"tune {tune['cells']} cells ({tune['samples']} weight samples) "
         f"compiled {tune['compile_cache_misses']}x",
         f"cold {tune['tune_cold_s']}s, best/incumbent "
         f"{tune['best_vs_incumbent']}x on {tune['objective']}"),
        (f"tune-grad {tune_grad['steps']} steps x {tune_grad['batch']} "
         f"candidates ({tune_grad['compile_cache_misses']} executables: "
         f"surrogate grad + hard oracle)",
         f"oracle best {tune_grad['best_oracle']} vs random "
         f"{tune_grad['random_best']} at {tune_grad['oracle_evals']} "
         f"oracle evals = {tune_grad['grad_vs_random']}x, "
         f"vs incumbent {tune_grad['grad_vs_incumbent']}x on "
         f"{tune_grad['objective']}"),
        (f"dist fabric {sweep_dist['cells']} cells (chunk "
         f"{sweep_dist['chunk']}, slab {sweep_dist['slab']}) x "
         f"{{1,2}} procs",
         f"bitwise match: {sweep_dist['finals_match']}, "
         f"overlap {sweep_dist['overlap_ratio']}x, 2-proc parallel "
         f"{sweep_dist['dist_parallel_ratio']}x, compiles/process <= "
         f"{max(a['compile_cache_misses'] for a in sweep_dist['arms'].values())}"),
        (f"telescope @ {telescope['horizon']} ticks x "
         f"{telescope['seeds']} seeds (refresh interval "
         f"{telescope['delay_update_interval']})",
         f"on {telescope['on_ticks_per_s']} vs off "
         f"{telescope['off_ticks_per_s']} ticks/s = "
         f"{telescope['telescope_speedup']}x, bitwise equal: "
         f"{telescope['finals_bitwise_equal']}, full ticks seed0: "
         f"{telescope['n_full_ticks_seed0']}/{telescope['horizon']}"),
        (f"longhorizon streaming @ {longhorizon['horizon']} ticks x "
         f"{longhorizon['seeds']} seeds",
         f"{longhorizon['stream']['max_rss_mb']} MB peak RSS, "
         f"{longhorizon['stream']['ticks_per_s']} ticks/s"
         + (f"; stacked exceeded {longhorizon['ceiling_mb']} MB ceiling: "
            f"{longhorizon['stacked']['exceeded_ceiling']}"
            if "stacked" in longhorizon else " (quick: streaming only)")),
        ("json", os.path.abspath(path)),
    ]
    if not quick:
        p2000 = [p for p in points if p["n_hosts"] == 2000]
        if p2000:
            claims.append(("2000-host point (dense cannot run)",
                           f"{p2000[0]['ticks_per_s']} ticks/s, "
                           f"{p2000[0]['state_mb']} MB state"))
        claims.append(("policy ticks/s @ 500h/3000c "
                       "(firstfit vs jobgroup vs netaware)",
                       str(out.get("policy_comparison"))))
        # every full refresh appends one headline row to the perf-history
        # log (deduped by content digest — a no-change rerun appends none)
        from benchmarks.archive import HISTORY_PATH, append_history
        claims.append(("bench history",
                       f"appended={append_history()} -> "
                       f"{os.path.abspath(HISTORY_PATH)}"))
    return points, claims


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the 100-host tracking points")
    args = ap.parse_args()
    rows, claims = bench_engine(quick=args.quick)
    for r in rows:
        print(r)
    for c in claims:
        print(f"# {c[0]}: {c[1]}")


if __name__ == "__main__":
    main()
