"""Tracked engine benchmark -> BENCH_engine.json (ISSUE 1 acceptance).

Measures steady-state ``ticks_per_s`` and ``state_mb`` per scale point so
the perf trajectory is tracked across PRs.  At the 500-host/3000-container
point BOTH flow engines run in the same process, giving an apples-to-apples
``sparse_speedup`` of the segment-based flow path over the dense [F, E]
oracle; the 2000-host point runs sparse-only (the dense membership tensor
at that scale is the OOM ceiling this PR removes).

    PYTHONPATH=src python -m benchmarks.engine_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import measure_scale_point

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")
# --quick runs must not clobber the tracked full-ladder artifact
BENCH_QUICK_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "BENCH_engine_quick.json")


def bench_engine(quick: bool = False):
    """Rows + claims for benchmarks.run; writes BENCH_engine.json."""
    points = []
    # small tracking points (cheap, both engines)
    for sparse in (True, False):
        points.append(measure_scale_point(100, 1500, horizon=40,
                                          sparse=sparse))
    # the headline comparison: 500 hosts / 3000 containers, same run
    if not quick:
        for sparse in (True, False):
            points.append(measure_scale_point(500, 3000, horizon=40,
                                              sparse=sparse))
        # policy ladder at the headline scale: static score vs the two
        # scan-carried co-location scores (jobgroup, netaware)
        for pol in ("jobgroup", "netaware"):
            points.append(measure_scale_point(500, 3000, horizon=40,
                                              policy=pol))
        # beyond the dense ceiling: sparse-only 2000-host point
        points.append(measure_scale_point(2000, 6000, horizon=20,
                                          sparse=True))

    def tps(h, c, mode, policy="firstfit"):
        for p in points:
            if ((p["n_hosts"], p["n_containers"], p["mode"],
                 p.get("policy", "firstfit")) == (h, c, mode, policy)):
                return p["ticks_per_s"]
        return None

    cmp_h, cmp_c = (100, 1500) if quick else (500, 3000)
    sp, de = tps(cmp_h, cmp_c, "sparse"), tps(cmp_h, cmp_c, "dense")
    speedup = round(sp / de, 2) if sp and de else None
    out = {
        "bench": "engine_tick_throughput",
        "points": points,
        "comparison_point": {"n_hosts": cmp_h, "n_containers": cmp_c},
        "sparse_speedup": speedup,
    }
    if not quick:
        out["policy_comparison"] = {
            pol: tps(500, 3000, "sparse", pol)
            for pol in ("firstfit", "jobgroup", "netaware")
        }
    path = BENCH_QUICK_PATH if quick else BENCH_PATH
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    claims = [
        (f"sparse vs dense ticks_per_s @ {cmp_h}h/{cmp_c}c",
         f"{sp} vs {de} ({speedup}x)"),
        ("json", os.path.abspath(path)),
    ]
    if not quick:
        p2000 = [p for p in points if p["n_hosts"] == 2000]
        if p2000:
            claims.append(("2000-host point (dense cannot run)",
                           f"{p2000[0]['ticks_per_s']} ticks/s, "
                           f"{p2000[0]['state_mb']} MB state"))
        claims.append(("policy ticks/s @ 500h/3000c "
                       "(firstfit vs jobgroup vs netaware)",
                       str(out.get("policy_comparison"))))
    return points, claims


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the 100-host tracking points")
    args = ap.parse_args()
    rows, claims = bench_engine(quick=args.quick)
    for r in rows:
        print(r)
    for c in claims:
        print(f"# {c[0]}: {c[1]}")


if __name__ == "__main__":
    main()
