"""Beyond-paper benchmark: schedule REAL ML jobs (from dry-run rooflines)
through DCSim and compare computing-only vs computing+networking policies —
the paper's core thesis, quantified with measured communication matrices.
"""
from __future__ import annotations

import os

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, run_sim, summarize)
from repro.core.bridge import jobs_from_results, workload_from_jobs

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_results.json")


def bridge_scheduling():
    if not os.path.exists(RESULTS):
        return [], [("bridge", "skipped: run the dry-run first")]
    jobs = jobs_from_results(RESULTS, shape="train_4k", n_workers=6,
                             steps=10)
    if not jobs:
        return [], [("bridge", "skipped: no train_4k cells in results")]
    cfg = SimConfig(horizon=200, max_containers_per_host=10)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg, bw=10000.0)   # 10 GbE fabric

    rows = []
    runtime = {}
    for policy in ["round", "performance_first", "jobgroup"]:
        conts = workload_from_jobs(jobs, cfg)
        sim0 = init_sim(hosts, conts, net)
        final, metrics = run_sim(sim0, cfg, get_policy(policy),
                                 spec.n_hosts, spec.n_nodes, cfg.horizon)
        rep = summarize(final, metrics)
        rows.append({"policy": policy,
                     "n_ml_containers": rep["n_containers"],
                     "completed": rep["n_completed"],
                     "avg_runtime": round(rep["avg_runtime"], 2),
                     "avg_comm_time": round(rep["avg_comm_time"], 2),
                     "total_cost": round(rep["total_cost"], 0)})
        runtime[policy] = rep["avg_runtime"]
    claims = [
        ("comm-aware (jobgroup) beats comm-oblivious (round) on ML jobs",
         runtime["jobgroup"] < runtime["round"]),
        ("jobs sourced from real dry-run rooflines", f"{len(jobs)} jobs"),
    ]
    return rows, claims
