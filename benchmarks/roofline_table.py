"""§Roofline feed: formats experiments/dryrun_results.json into the
per-(arch x shape x mesh) table used by EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_results.json")


def load(mesh: str = "single"):
    with open(RESULTS) as f:
        rows = json.load(f)
    return [r for r in rows if r["mesh"] == mesh]


def roofline_rows(mesh: str = "single"):
    rows = []
    for r in sorted(load(mesh), key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": mesh, "status": "skipped"})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": mesh, "status": "ERROR"})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": mesh,
            "status": "ok",
            "t_compute_s": round(r["t_compute"], 4),
            "t_memory_s": round(r["t_memory"], 4),
            "t_collective_s": round(r["t_collective"], 4),
            "bottleneck": r["bottleneck"],
            "model_flops": f"{r['model_flops']:.3e}",
            "useful_ratio": r["useful_ratio"],
            "coll_gib_per_dev": round(r["coll_bytes_per_dev"] / 2**30, 2),
        })
    return rows


def run_table():
    rows = roofline_rows("single")
    ok = [r for r in rows if r["status"] == "ok"]
    n_skip = sum(r["status"] == "skipped" for r in rows)
    bounds = {}
    for r in ok:
        bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
    claims = [
        ("cells compiled", f"{len(ok)} ok / {n_skip} documented skips"),
        ("bottleneck mix", str(bounds)),
    ]
    return rows, claims
