"""CI bench-regression gate (PR 4).

Compares the quick-run benchmark JSON (``experiments/BENCH_engine_quick.json``,
produced by ``python -m benchmarks.engine_bench --quick``) against the
committed ``BENCH_engine.json`` baseline and FAILS on regression, instead of
only checking that the JSON parses:

* every quick scale point's ``ticks_per_s`` must stay within ``tol`` of the
  committed point at the same (n_hosts, n_containers, mode, policy,
  delay_mode, kernels) — and ONLY when both were measured on the same JAX
  backend; cross-backend pairs (a cpu CI runner vs a gpu-refreshed
  baseline) are skipped with a loud note instead of gated (ISSUE 6);
* the quick sweep's per-cell steady time must not exceed the committed
  ``sweep_quick`` per-cell time by more than ``tol`` (the full-mode bench
  records the quick-scale grid exactly so the two runs are comparable);
* the sweep must still compile exactly once;
* the ``longhorizon`` streaming entry (PR 7) is gated on MEMORY
  absolutely: the quick streaming child's subprocess peak RSS must stay
  under the committed ``ceiling_mb`` — the fixed ceiling the committed
  full bench demonstrated the stacked path exceeding
  (``stacked.exceeded_ceiling`` must still read true in the baseline, so
  a baseline refresh cannot silently drop the demonstration) — and its
  ticks/s joins the skew-normalized pack.  RSS is a same-backend,
  same-machine-class number; cross-backend pairs skip like the rest, and
  the ceiling itself already carries 1.25x headroom over the measured
  streaming peak.

Machine-skew correction: the committed baseline was measured on whatever
box last ran the full bench, and a CI runner can legitimately be uniformly
slower — absolute wall-clock gating would then be permanently red.  Every
metric is therefore compared as a quick/committed speed ratio *normalized
by the median ratio across all gated metrics*: a uniformly slower (or
faster) machine moves every ratio together and cancels out, while a code
regression that hits one path — a scatter creeping back into the tick, the
sweep losing its single compilation's fusion — drags its ratio below the
pack and fails.  (A perfectly uniform code slowdown is indistinguishable
from a slow machine within one run; that is the price of cross-machine
comparability, and the full-bench refresh on the next PR catches it.)

The skew correction is blind to regressions that move most wall-clock
metrics together, so two *within-run* ratios — machine-independent by
construction — are gated absolutely as well: ``sparse_speedup`` (sparse vs
dense ticks_per_s, same run) and ``vmap_cell_tax`` (vmapped per-cell vs
warm standalone cell, same run).  Since the branch-free scoring engine
(ISSUE 5) the tax additionally has a hard acceptance ceiling — the policy
axis pays one shared feature bank, not an all-branch ``lax.switch``
evaluation, and both the committed full-grid baseline (<= 1.35) and the
quick run (<= 1.35 * (1 + tol)) are held to it.  (The ceiling was 1.25
through ISSUE 8; ISSUE 9 made standalone cells ~6% faster without moving
the sweep's steady wall, which inflates the ratio's denominator-relative
reading — the ceiling moved with it so a faster baseline is not reported
as a slower sweep.)  The ``tune`` smoke entry
(weight search through the compiled sweep) must exist, compile exactly
once, and its per-cell wall joins the skew-normalized pack.

ISSUE 8 (multi-process fabric) adds the ``sweep_dist`` gate: the quick
run's spawned arms must stay bit-identical to the in-process sweep, keep
the per-process compile bill at <= 2, and hold the within-run
``overlap_ratio`` (serial vs overlapped gather, machine-independent) to
within ``tol`` of the committed one.  The spawn-cold arm walls never join
the skew pack — they are compile-bound, like ``tune_cold_s``.

ISSUE 9 (differentiable simulator) adds the ``tune_grad`` gate, all
within-run and machine-independent: the entry must exist, build exactly 2
executables (surrogate value_and_grad + hard oracle — tau annealing rides
a traced RunParams field, so a third executable means something static
leaked into a cache key), and gradient search must keep beating both the
incumbent and an equal-oracle-budget random search on the hard oracle
(``grad_vs_incumbent``/``grad_vs_random`` >= 1).  The committed baseline
must itself demonstrate the grad-beats-random claim, so a refresh cannot
silently drop it.  The compile-bound cold wall stays out of the skew
pack.

ISSUE 10 (event-horizon telescoping) adds the ``telescope`` gate: the
quick run's macro-tick arm must stay bit-identical to the per-tick path
(``finals_bitwise_equal``, absolute — exactness is the feature), the
within-run on/off ``telescope_speedup`` (machine-independent) must not
fall more than ``tol`` below the committed one, and the committed
baseline must itself demonstrate the >= 3x acceptance claim so a refresh
cannot silently drop it.  The ON-side ticks/s joins the skew-normalized
pack (same backend only).

``tol`` defaults to 0.30 — headroom for per-metric CI noise on top of the
skew correction; the gate is one-sided, so getting faster never fails.
Override with ``BENCH_TOL``.

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import json
import os
import statistics
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE = os.path.join(HERE, "..", "BENCH_engine.json")
QUICK = os.path.join(HERE, "..", "experiments", "BENCH_engine_quick.json")


def point_key(p: dict) -> tuple:
    # delay_mode/kernels default to the pre-ladder values so baselines
    # written before the kernel ladder (ISSUE 6) keep their identity
    return (p["n_hosts"], p["n_containers"], p["mode"],
            p.get("policy", "firstfit"), p.get("delay_mode", "path"),
            p.get("kernels", "off"))


def backends_differ(a: dict, b: dict) -> bool:
    """True when both entries record a backend and they disagree.

    Wall-clock numbers from different XLA backends (cpu vs gpu vs tpu) are
    not comparable at any tolerance — a CPU quick run gated against a GPU
    baseline would drown the skew-normalized pack in bogus ratios.  Entries
    without a ``backend`` field (pre-ladder baselines) are assumed
    comparable, so old baselines keep gating until the next full refresh.
    """
    return (a.get("backend") is not None and b.get("backend") is not None
            and a["backend"] != b["backend"])


def check(quick: dict, base: dict, tol: float) -> list[str]:
    failures: list[str] = []

    # -- shape sanity (was the whole CI check before PR 4) ------------------
    if quick.get("bench") != "engine_tick_throughput":
        failures.append(f"unexpected bench id: {quick.get('bench')!r}")
    if not quick.get("points"):
        failures.append("no scale points recorded")
    sw = quick.get("sweep") or {}
    if sw.get("cells") != (sw.get("policies", 0) * sw.get("scenarios", 0)
                           * sw.get("seeds", 0)):
        failures.append(f"sweep cell count inconsistent: {sw}")
    if sw.get("cells", 0) < 24:
        failures.append(f"sweep grid too small: {sw.get('cells')} cells")
    if sw.get("compile_cache_misses") != 1:
        failures.append(
            f"sweep must compile exactly once, got "
            f"{sw.get('compile_cache_misses')}")
    tn = quick.get("tune") or {}
    if not tn:
        failures.append("no 'tune' smoke entry recorded (weight search "
                        "through the compiled sweep, ISSUE 5)")
    elif tn.get("compile_cache_misses") != 1:
        failures.append(
            f"tune must compile exactly once (weights are the policy batch "
            f"axis), got {tn.get('compile_cache_misses')}")
    # -- tune_grad: within-run, machine-independent gates (ISSUE 9) ---------
    tg = quick.get("tune_grad") or {}
    if not tg:
        failures.append(
            "no 'tune_grad' smoke entry recorded (gradient descent on the "
            "soft-placement surrogate, ISSUE 9)")
    else:
        if tg.get("compile_cache_misses", 99) > 2:
            failures.append(
                f"tune_grad must build exactly 2 executables (surrogate "
                f"value_and_grad + hard oracle; tau anneals as a traced "
                f"RunParams field), got {tg.get('compile_cache_misses')}")
        gvi = tg.get("grad_vs_incumbent")
        if gvi is not None and gvi < 1.0:
            failures.append(
                f"regression: tune_grad ranked BELOW the incumbent "
                f"(grad_vs_incumbent {gvi} < 1.0) — the oracle-bounded "
                f"best tracking broke (the incumbent is oracle-scored "
                f"before step 0, so this can never legitimately happen)")
        gvr = tg.get("grad_vs_random")
        if gvr is not None and gvr < 1.0:
            failures.append(
                f"regression: gradient search stopped beating random "
                f"search at equal oracle budget (within-run "
                f"grad_vs_random {gvr} < 1.0) — the surrogate's gradient "
                f"no longer carries signal about the hard objective")
    ref_tg = base.get("tune_grad")
    if ref_tg is None:
        failures.append(
            "committed BENCH_engine.json has no 'tune_grad' entry; re-run "
            "the full bench to record the differentiable-tuning reference "
            "(ISSUE 9)")
    else:
        if (ref_tg.get("grad_vs_random") or 0) < 1.0:
            failures.append(
                "committed tune_grad baseline does not demonstrate "
                "gradient search beating equal-budget random search "
                f"(grad_vs_random {ref_tg.get('grad_vs_random')}); the "
                "differentiable-path claim is ungated — re-run the full "
                "bench")
        if tg:
            grid = ("n_hosts", "n_containers", "horizon", "steps", "batch")
            if any(tg.get(k) != ref_tg.get(k) for k in grid):
                failures.append(
                    f"tune_grad grid {[tg.get(k) for k in grid]} != "
                    f"committed {[ref_tg.get(k) for k in grid]}")

    # -- gather (name, speed ratio) per gated metric ------------------------
    # ratio > 1 means this run is faster than the committed baseline; the
    # median ratio estimates machine skew and normalizes it away (module
    # docstring) so only relative regressions fail.
    ratios: list[tuple[str, float]] = []
    committed = {point_key(p): p for p in base.get("points", [])}
    for p in quick.get("points", []):
        if p["ticks_per_s"] <= 0:
            failures.append(f"non-positive ticks_per_s: {p}")
            continue
        ref = committed.get(point_key(p))
        if ref is None:
            continue  # a quick-only point has no committed twin to gate on
        if backends_differ(p, ref):
            print(f"note: skipping cross-backend comparison at "
                  f"{point_key(p)}: quick ran on {p['backend']!r}, "
                  f"committed baseline on {ref['backend']!r}")
            continue
        ratios.append((
            f"ticks_per_s at {point_key(p)} "
            f"({p['ticks_per_s']} vs committed {ref['ticks_per_s']})",
            p["ticks_per_s"] / ref["ticks_per_s"]))

    ref_sw = base.get("sweep_quick")
    if ref_sw is None:
        failures.append(
            "committed BENCH_engine.json has no 'sweep_quick' entry; "
            "re-run the full bench to record the quick-scale reference")
    elif sw:
        grid = ("n_hosts", "n_containers", "horizon", "cells")
        if backends_differ(sw, ref_sw):
            print(f"note: skipping cross-backend sweep comparison: quick "
                  f"ran on {sw['backend']!r}, committed sweep_quick on "
                  f"{ref_sw['backend']!r}")
        elif any(sw.get(k) != ref_sw.get(k) for k in grid):
            failures.append(
                f"quick sweep grid {[sw.get(k) for k in grid]} != committed "
                f"sweep_quick grid {[ref_sw.get(k) for k in grid]}")
        elif sw.get("sweep_steady_s", 0) > 0:
            got = sw["sweep_steady_s"] / sw["cells"]
            ref = ref_sw["sweep_steady_s"] / ref_sw["cells"]
            ratios.append((
                f"sweep per-cell steady ({got:.3f}s vs committed "
                f"{ref:.3f}s)", ref / got))

    ref_tn = base.get("tune")
    if ref_tn is None:
        failures.append(
            "committed BENCH_engine.json has no 'tune' entry; re-run the "
            "full bench to record the weight-search reference")
    elif tn:
        grid = ("n_hosts", "n_containers", "horizon", "cells")
        if backends_differ(tn, ref_tn):
            print(f"note: skipping cross-backend tune comparison: quick "
                  f"ran on {tn['backend']!r}, committed tune on "
                  f"{ref_tn['backend']!r}")
        elif any(tn.get(k) != ref_tn.get(k) for k in grid):
            failures.append(
                f"tune grid {[tn.get(k) for k in grid]} != committed "
                f"{[ref_tn.get(k) for k in grid]}")
        # gate the WARM repeat, not tune_cold_s: the cold wall is mostly
        # XLA compile on the smoke grid, and mixing a compile-bound
        # metric into a runtime-ratio pack turns a jax-pin bump into a
        # bogus regression (or hides a real runtime one)
        elif (tn.get("tune_steady_s") or 0) > 0 and \
                (ref_tn.get("tune_steady_s") or 0) > 0:
            got = tn["tune_steady_s"] / tn["cells"]
            ref = ref_tn["tune_steady_s"] / ref_tn["cells"]
            ratios.append((
                f"tune per-cell steady ({got:.3f}s vs committed "
                f"{ref:.3f}s)", ref / got))

    # -- longhorizon streaming: absolute memory ceiling + speed pack --------
    lh = quick.get("longhorizon") or {}
    ref_lh = base.get("longhorizon")
    if ref_lh is None:
        failures.append(
            "committed BENCH_engine.json has no 'longhorizon' entry; "
            "re-run the full bench to record the streaming-memory "
            "reference (ceiling + stacked crossing)")
    else:
        if not (ref_lh.get("stacked") or {}).get("exceeded_ceiling"):
            failures.append(
                "committed longhorizon baseline does not demonstrate the "
                "stacked path exceeding ceiling_mb "
                f"({ref_lh.get('stacked')}); the streaming memory claim "
                "is ungated — re-run the full bench")
        q_stream = lh.get("stream") or {}
        r_stream = ref_lh.get("stream") or {}
        if not lh:
            failures.append("no 'longhorizon' entry in the quick run")
        elif backends_differ(q_stream, r_stream):
            print(f"note: skipping cross-backend longhorizon comparison: "
                  f"quick ran on {q_stream.get('backend')!r}, committed "
                  f"on {r_stream.get('backend')!r}")
        else:
            grid = ("n_hosts", "n_containers", "seeds", "chunk")
            ceiling = ref_lh.get("ceiling_mb")
            if any(lh.get(k) != ref_lh.get(k) for k in grid):
                failures.append(
                    f"longhorizon grid {[lh.get(k) for k in grid]} != "
                    f"committed {[ref_lh.get(k) for k in grid]}")
            elif ceiling and q_stream.get("max_rss_mb"):
                if q_stream["max_rss_mb"] > ceiling:
                    failures.append(
                        f"regression: streaming peak RSS "
                        f"{q_stream['max_rss_mb']} MB exceeds the "
                        f"committed ceiling {ceiling} MB — the O(state) "
                        f"memory property broke")
                if q_stream.get("ticks_per_s", 0) > 0 \
                        and r_stream.get("ticks_per_s", 0) > 0:
                    ratios.append((
                        f"longhorizon stream ticks_per_s "
                        f"({q_stream['ticks_per_s']} vs committed "
                        f"{r_stream['ticks_per_s']})",
                        q_stream["ticks_per_s"] / r_stream["ticks_per_s"]))

    # -- multi-process fabric: identity + compile bill + overlap ratio ------
    # The spawned-arm walls are cold (process spin-up + XLA compile
    # dominate at smoke scale), so — like tune_cold_s — they stay OUT of
    # the skew-normalized ratio pack.  What IS gated: the distributed
    # results must remain bit-identical to the in-process sweep, every arm
    # must compile at most twice per process (steady jstep + final-slab
    # remainder), and the within-run overlap_ratio (serial / overlapped
    # max worker wall, machine-independent by construction) must not fall
    # more than tol below the committed one.
    sd = quick.get("sweep_dist") or {}
    ref_sd = base.get("sweep_dist")
    if ref_sd is None:
        failures.append(
            "committed BENCH_engine.json has no 'sweep_dist' entry; "
            "re-run the full bench to record the multi-process fabric "
            "reference (ISSUE 8)")
    else:
        if not ref_sd.get("finals_match"):
            failures.append(
                "committed sweep_dist baseline does not demonstrate "
                "bit-identical distributed finals — the fabric's identity "
                "claim is ungated; re-run the full bench")
        if not sd:
            failures.append("no 'sweep_dist' entry in the quick run")
        elif backends_differ(sd, ref_sd):
            print(f"note: skipping cross-backend sweep_dist comparison: "
                  f"quick ran on {sd['backend']!r}, committed on "
                  f"{ref_sd['backend']!r}")
        else:
            grid = ("n_hosts", "n_containers", "horizon", "cells",
                    "chunk", "slab")
            if any(sd.get(k) != ref_sd.get(k) for k in grid):
                failures.append(
                    f"sweep_dist grid {[sd.get(k) for k in grid]} != "
                    f"committed {[ref_sd.get(k) for k in grid]}")
            else:
                if not sd.get("finals_match"):
                    failures.append(
                        "regression: distributed sweep results are no "
                        "longer bit-identical to the in-process sweep "
                        "(sweep_dist finals_match is false)")
                for name, arm in (sd.get("arms") or {}).items():
                    if arm.get("compile_cache_misses", 99) > 2:
                        failures.append(
                            f"regression: sweep_dist arm {name!r} compiled "
                            f"{arm.get('compile_cache_misses')}x per "
                            f"process (must be <= 2: steady jstep + "
                            f"final-slab remainder)")
                got = sd.get("overlap_ratio")
                ref = ref_sd.get("overlap_ratio")
                if got and ref and got < ref * (1.0 - tol):
                    failures.append(
                        f"regression: within-run dist overlap_ratio "
                        f"{got} < committed {ref} - {tol:.0%} — the "
                        f"overlapped slab driver stopped hiding gathers")

    # -- telescoping: exactness + within-run speedup (ISSUE 10) -------------
    # finals_bitwise_equal and telescope_speedup are computed inside ONE
    # run on ONE machine (off vs on through the same vmapped driver), so
    # both are machine-independent: equality gates absolutely, the
    # speedup gates one-sided against the committed one, and the
    # committed baseline must itself demonstrate the >= 3x acceptance.
    # Only the ON-side ticks_per_s joins the skew-normalized pack.
    tl = quick.get("telescope") or {}
    ref_tl = base.get("telescope")
    SPEEDUP_FLOOR = 3.0
    if ref_tl is None:
        failures.append(
            "committed BENCH_engine.json has no 'telescope' entry; re-run "
            "the full bench to record the macro-tick engine reference "
            "(ISSUE 10)")
    else:
        if not ref_tl.get("finals_bitwise_equal"):
            failures.append(
                "committed telescope baseline does not demonstrate bitwise "
                "equality of telescoped vs per-tick finals; the exactness "
                "claim is ungated — re-run the full bench")
        if (ref_tl.get("telescope_speedup") or 0) < SPEEDUP_FLOOR:
            failures.append(
                f"committed telescope baseline does not demonstrate the "
                f">= {SPEEDUP_FLOOR}x acceptance speedup "
                f"(telescope_speedup {ref_tl.get('telescope_speedup')}); "
                f"the claim is ungated — re-run the full bench")
        if not tl:
            failures.append("no 'telescope' entry in the quick run")
        else:
            grid = ("n_hosts", "n_containers", "horizon", "seeds", "chunk",
                    "delay_update_interval")
            if any(tl.get(k) != ref_tl.get(k) for k in grid):
                failures.append(
                    f"telescope grid {[tl.get(k) for k in grid]} != "
                    f"committed {[ref_tl.get(k) for k in grid]}")
            else:
                if not tl.get("finals_bitwise_equal"):
                    failures.append(
                        "regression: telescoped finals are no longer "
                        "bit-identical to the per-tick path (telescope "
                        "finals_bitwise_equal is false)")
                got = tl.get("telescope_speedup")
                ref = ref_tl.get("telescope_speedup")
                if got and ref and got < ref * (1.0 - tol):
                    failures.append(
                        f"regression: within-run telescope_speedup {got} < "
                        f"committed {ref} - {tol:.0%} — the macro-tick "
                        f"engine stopped skipping quiescent ticks")
                if backends_differ(tl, ref_tl):
                    print(f"note: skipping cross-backend telescope "
                          f"throughput comparison: quick ran on "
                          f"{tl.get('backend')!r}, committed on "
                          f"{ref_tl.get('backend')!r}")
                elif tl.get("on_ticks_per_s", 0) > 0 \
                        and ref_tl.get("on_ticks_per_s", 0) > 0:
                    ratios.append((
                        f"telescope on_ticks_per_s "
                        f"({tl['on_ticks_per_s']} vs committed "
                        f"{ref_tl['on_ticks_per_s']})",
                        tl["on_ticks_per_s"] / ref_tl["on_ticks_per_s"]))

    # -- one-sided gate on skew-normalized ratios ---------------------------
    if ratios:
        skew = statistics.median(r for _, r in ratios)
        for name, r in ratios:
            if r < skew * (1.0 - tol):
                failures.append(
                    f"regression: {name} is {r:.2f}x baseline speed while "
                    f"this machine's median is {skew:.2f}x — "
                    f">{tol:.0%} below the pack")
        if skew < 0.5:
            print(f"note: this machine runs at {skew:.2f}x the baseline "
                  f"machine's speed; relative gating still applies")

    # -- within-run ratios: machine-independent, gated absolutely -----------
    # The median normalization above is blind to regressions that move 2+
    # of its 3 wall-clock metrics together (a scatter creeping back into
    # the shared tick slows sparse AND the sweep).  These two ratios are
    # computed inside ONE run on ONE machine, so machine skew cancels by
    # construction and they gate the blind spot directly:
    # * sparse_speedup — sparse vs dense ticks_per_s at the quick point
    #   (the dense oracle barely shares the sparse hot paths);
    # * vmap_cell_tax — vmapped per-cell steady vs warm standalone cell
    #   (catches the sweep losing its batching efficiency specifically).
    qp = {point_key(p): p for p in quick.get("points", [])}
    spq = qp.get((100, 1500, "sparse", "firstfit", "path", "off"))
    deq = qp.get((100, 1500, "dense", "firstfit", "path", "off"))
    spc = committed.get((100, 1500, "sparse", "firstfit", "path", "off"))
    dec = committed.get((100, 1500, "dense", "firstfit", "path", "off"))
    if spq and deq and spc and dec and deq["ticks_per_s"] > 0 \
            and dec["ticks_per_s"] > 0:
        got = spq["ticks_per_s"] / deq["ticks_per_s"]
        ref = spc["ticks_per_s"] / dec["ticks_per_s"]
        if got < ref * (1.0 - tol):
            failures.append(
                f"regression: within-run sparse/dense speedup {got:.2f}x "
                f"< committed {ref:.2f}x - {tol:.0%} — the sparse flow "
                f"path got slower relative to the dense oracle")
    if ref_sw and sw.get("vmap_cell_tax") and ref_sw.get("vmap_cell_tax"):
        got, ref = sw["vmap_cell_tax"], ref_sw["vmap_cell_tax"]
        if got > ref * (1.0 + tol):
            failures.append(
                f"regression: within-run vmap_cell_tax {got} > committed "
                f"{ref} + {tol:.0%} — the vmapped sweep got slower "
                f"relative to standalone cells")
    # ISSUE 5 acceptance ceiling: with branch-free scoring the policy axis
    # must cost (about) what one generic score costs, not a sum of
    # branches.  The committed FULL-grid baseline is held to the target
    # outright; the quick run gets the tolerance on top.  Recalibrated
    # 1.25 -> 1.35 with ISSUE 9: standalone cells got ~6% faster (the
    # denominator of the ratio) while full-grid sweep steady time was
    # unchanged (18.5s -> 18.2s on the same box), so the old ceiling
    # would flag a denominator improvement as a sweep regression.
    TAX_CEILING = 1.35
    base_tax = (base.get("sweep") or {}).get("vmap_cell_tax")
    if base_tax is not None and base_tax > TAX_CEILING:
        failures.append(
            f"committed full-grid vmap_cell_tax {base_tax} exceeds the "
            f"branch-free acceptance ceiling {TAX_CEILING}")
    if sw.get("vmap_cell_tax") and \
            sw["vmap_cell_tax"] > TAX_CEILING * (1.0 + tol):
        failures.append(
            f"regression: quick-run vmap_cell_tax {sw['vmap_cell_tax']} > "
            f"acceptance ceiling {TAX_CEILING} + {tol:.0%}")
    return failures


def main() -> int:
    tol = float(os.environ.get("BENCH_TOL", "0.30"))
    with open(QUICK) as f:
        quick = json.load(f)
    with open(BASELINE) as f:
        base = json.load(f)
    failures = check(quick, base, tol)
    sw = quick.get("sweep", {})
    tn = quick.get("tune", {})
    tg = quick.get("tune_grad", {})
    tl = quick.get("telescope", {})
    print(f"quick bench: {len(quick.get('points', []))} points, "
          f"sparse_speedup={quick.get('sparse_speedup')}, "
          f"sweep {sw.get('cells')} cells in {sw.get('sweep_steady_s')}s "
          f"({sw.get('compile_cache_misses')} compile, "
          f"vmap_cell_tax={sw.get('vmap_cell_tax')}), "
          f"tune {tn.get('cells')} cells in {tn.get('tune_cold_s')}s "
          f"({tn.get('compile_cache_misses')} compile), "
          f"tune_grad {tg.get('grad_vs_random')}x vs random / "
          f"{tg.get('grad_vs_incumbent')}x vs incumbent, "
          f"telescope {tl.get('telescope_speedup')}x "
          f"(bitwise equal: {tl.get('finals_bitwise_equal')})")
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        return 1
    print(f"bench regression gate passed (tol {tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
