"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim,
                        summarize)
from repro.core.datacenter import scaled_hosts
from repro.core.network import SpineLeafSpec, build_network, set_link_params

POLICIES = ["firstfit", "round", "performance_first", "jobgroup"]


def run_policy(name: str, cfg: SimConfig | None = None, bw=None, loss=None,
               seed: int = 0, n_hosts: int = 20):
    cfg = cfg or SimConfig()
    hosts = (build_paper_hosts() if n_hosts == 20
             else scaled_hosts(n_hosts, max(4, n_hosts // 5)))
    spec = SpineLeafSpec(n_spine=2, n_leaf=max(4, n_hosts // 5),
                         n_hosts=n_hosts)
    net = build_network(spec)
    if bw is not None or loss is not None:
        net = set_link_params(net, bw=bw, loss=loss)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    t0 = time.time()
    final, metrics = run_sim(sim0, cfg, get_policy(name), spec.n_hosts,
                             spec.n_nodes, cfg.horizon)
    final.t.block_until_ready()
    wall = time.time() - t0
    rep = summarize(final, metrics)
    rep["wall_s"] = wall
    return rep, metrics


def series(metrics, field):
    return np.asarray(getattr(metrics, field))
