"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim,
                        summarize)
from repro.core.datacenter import scaled_hosts
from repro.core.network import SpineLeafSpec, build_network, set_link_params

POLICIES = ["firstfit", "round", "performance_first", "jobgroup"]


def measure_scale_point(n_hosts: int, n_containers: int, horizon: int = 120,
                        policy: str = "firstfit", seed: int = 0,
                        sparse: bool = True, batched: bool = True,
                        delay_mode: str = "path",
                        kernels: str = "off") -> dict:
    """Build one scale point, run it twice (compile + steady) and time it.

    Shared by fig11_scalability and engine_bench so the timing protocol and
    result schema stay in sync.

    ``delay_mode``/``kernels`` select the delay-refresh algebra and the
    Pallas kernel dispatch flag ('auto'|'on'|'off', applied to both the fw
    APSP and the fused waterfilling kernel).  Every point records the JAX
    ``backend``/``device`` it ran on plus what the flag *resolved* to —
    numbers from different backends are never comparable, and
    check_regression.py refuses to compare them.
    """
    import jax

    from repro.core.types import (STATUS_COMMUNICATING, STATUS_COMPLETED,
                                  STATUS_MIGRATING, STATUS_RUNNING)
    from repro.kernels import kernel_backend, resolve_kernel

    cfg = SimConfig(n_jobs=max(10, n_containers // 3),
                    n_tasks=n_containers, n_containers=n_containers,
                    horizon=horizon, sparse_flows=sparse,
                    batched_placement=batched, delay_mode=delay_mode,
                    delay_kernel=kernels, waterfill_kernel=kernels)
    t0 = time.time()
    n_leaf = max(4, n_hosts // 5)
    hosts = scaled_hosts(n_hosts, n_leaf)
    spec = SpineLeafSpec(n_spine=max(2, n_leaf // 4), n_leaf=n_leaf,
                         n_hosts=n_hosts)
    net = build_network(spec)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    t_init = time.time() - t0

    def once():
        final, _ = run_sim(sim0, cfg, get_policy(policy), spec.n_hosts,
                           spec.n_nodes, horizon)
        final.t.block_until_ready()
        return final

    t0 = time.time()
    final = once()
    t_first = time.time() - t0               # includes XLA compile
    t0 = time.time()
    final = once()
    t_steady = time.time() - t0
    state_mb = sum(x.nbytes for x in jax.tree.leaves(sim0)) / 2**20
    backend = kernel_backend()
    return {
        "n_hosts": n_hosts,
        "n_network_nodes": spec.n_nodes,
        "n_containers": n_containers,
        "mode": "sparse" if sparse else "dense",
        "policy": policy,
        "batched_placement": batched,
        "horizon": horizon,
        "delay_mode": delay_mode,
        "kernels": kernels,
        # what the flag resolved to on THIS backend ('auto' -> kernel on
        # TPU/GPU, jnp ref on CPU) — the honest record of what actually ran
        "kernels_active": bool(resolve_kernel(kernels)),
        "backend": backend,
        "device": jax.devices()[0].device_kind,
        "init_s": round(t_init, 3),
        "sim_first_s": round(t_first, 2),
        "sim_steady_s": round(t_steady, 4),
        "ticks_per_s": round(horizon / max(t_steady, 1e-9), 1),
        "state_mb": round(state_mb, 1),
        "completed": int((np.asarray(final.containers.status)
                          == STATUS_COMPLETED).sum()),
        # deployed at the end of the run — end-to-end sanity for points
        # whose horizon is shorter than any container lifetime
        "deployed": int(np.isin(np.asarray(final.containers.status),
                                [STATUS_RUNNING, STATUS_COMMUNICATING,
                                 STATUS_MIGRATING]).sum()),
    }


def run_policy(name: str, cfg: SimConfig | None = None, bw=None, loss=None,
               seed: int = 0, n_hosts: int = 20):
    cfg = cfg or SimConfig()
    hosts = (build_paper_hosts() if n_hosts == 20
             else scaled_hosts(n_hosts, max(4, n_hosts // 5)))
    spec = SpineLeafSpec(n_spine=2, n_leaf=max(4, n_hosts // 5),
                         n_hosts=n_hosts)
    net = build_network(spec)
    if bw is not None or loss is not None:
        net = set_link_params(net, bw=bw, loss=loss)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    t0 = time.time()
    final, metrics = run_sim(sim0, cfg, get_policy(name), spec.n_hosts,
                             spec.n_nodes, cfg.horizon)
    final.t.block_until_ready()
    wall = time.time() - t0
    rep = summarize(final, metrics)
    rep["wall_s"] = wall
    return rep, metrics


def series(metrics, field):
    return np.asarray(getattr(metrics, field))
