"""Benchmark runner: one entry per paper table/figure (+ roofline feed +
beyond-paper bridge).  Prints ``name,us_per_call,derived`` CSV and dumps
full rows to experiments/bench_rows.json.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import paper_figs
    from benchmarks.bridge_scheduling import bridge_scheduling
    from benchmarks.engine_bench import bench_engine
    from benchmarks.fig11_scalability import (fig11_scalability,
                                              scenario_vmap_throughput)
    from benchmarks.roofline_table import run_table

    benches = {
        "engine_bench": bench_engine,
        "fig4_datacenter": paper_figs.fig4_datacenter,
        "fig5_network": paper_figs.fig5_network,
        "fig6_scheduling": paper_figs.fig6_scheduling,
        "fig7_migration": paper_figs.fig7_migration,
        "fig8_system": paper_figs.fig8_system,
        "fig9_10_variance": paper_figs.fig9_10_variance,
        "fig11_scalability": fig11_scalability,
        "vmap_scenarios": scenario_vmap_throughput,
        "roofline_table": run_table,
        "bridge_scheduling": bridge_scheduling,
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        t0 = time.time()
        try:
            rows, claims = fn()
            status_bits = []
            for c in claims:
                label, val = c
                status_bits.append(f"{label}={val}")
            derived = "; ".join(status_bits)
        except Exception as e:  # keep the harness running
            rows, derived = [], f"ERROR {type(e).__name__}: {e}"
        us = (time.time() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{us:.0f},{derived!r}")

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_rows.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    print(f"# rows -> {out}")


if __name__ == "__main__":
    main()
