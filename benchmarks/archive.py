"""Append-only perf-history log -> BENCH_history.jsonl (ISSUE 8).

``BENCH_engine.json`` is a snapshot: every full-bench refresh overwrites
it, so the perf trajectory across PRs only lives in git history.  This
module condenses each distinct snapshot into ONE compact headline row and
appends it to ``BENCH_history.jsonl`` — greppable trend data without
replaying commits.

Rows are deduplicated by content digest against the LAST row: re-running
the full bench without the committed artifact changing appends nothing,
while a genuine refresh (new numbers, new entries) always lands one row.
The row is stamped with the date/commit of the last commit touching the
artifact when the working copy is clean, or today's date (commit null)
when stamping a just-regenerated, not-yet-committed snapshot.

    PYTHONPATH=src python -m benchmarks.archive          # append if new
    PYTHONPATH=src python -m benchmarks.archive --show   # print all rows
"""
from __future__ import annotations

import argparse
import datetime
import hashlib
import json
import os
import subprocess

HERE = os.path.dirname(os.path.abspath(__file__))
BENCH_PATH = os.path.join(HERE, "..", "BENCH_engine.json")
HISTORY_PATH = os.path.join(HERE, "..", "BENCH_history.jsonl")


def _digest(bench: dict) -> str:
    """Content digest of the snapshot (key-order independent)."""
    blob = json.dumps(bench, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _git_stamp(path: str) -> tuple[str, str | None]:
    """(date, short-sha) the snapshot belongs to.

    A clean working copy means the artifact IS the committed one — stamp
    it with its last commit.  A dirty or untracked artifact is a fresh
    refresh that has not been committed yet — stamp today, commit null
    (the digest still dedups reruns).
    """
    cwd, name = os.path.dirname(os.path.abspath(path)), os.path.basename(path)
    try:
        dirty = subprocess.run(
            ["git", "diff", "--quiet", "HEAD", "--", name], cwd=cwd,
            capture_output=True, timeout=10).returncode != 0
        if not dirty:
            out = subprocess.run(
                ["git", "log", "-1", "--format=%cs %h", "--", name],
                cwd=cwd, capture_output=True, text=True, timeout=10)
            line = out.stdout.strip()
            if out.returncode == 0 and line:
                date, sha = line.split()
                return date, sha
    except OSError:
        pass
    return datetime.date.today().isoformat(), None


def headline(bench: dict) -> dict:
    """The row: one number per tracked subsystem, nulls where a snapshot
    predates an entry (old rows stay parseable as the schema grows)."""
    points = bench.get("points", [])
    sparse = [p for p in points
              if p.get("mode") == "sparse"
              and p.get("policy", "firstfit") == "firstfit"
              and p.get("delay_mode", "path") == "path"]
    top = max(sparse, key=lambda p: p["n_hosts"]) if sparse else None
    sw = bench.get("sweep") or {}
    tn = bench.get("tune") or {}
    tg = bench.get("tune_grad") or {}
    lh = bench.get("longhorizon") or {}
    sd = bench.get("sweep_dist") or {}
    tl = bench.get("telescope") or {}
    return {
        "backend": bench.get("backend"),
        "device": bench.get("device"),
        "points": len(points),
        "sparse_speedup": bench.get("sparse_speedup"),
        "top_point": (f"{top['n_hosts']}h/{top['n_containers']}c"
                      if top else None),
        "top_ticks_per_s": top.get("ticks_per_s") if top else None,
        "sweep_cells_per_s": sw.get("cells_per_s"),
        "vmap_cell_tax": sw.get("vmap_cell_tax"),
        "tune_steady_s": tn.get("tune_steady_s"),
        "tune_grad_vs_random": tg.get("grad_vs_random"),
        "tune_grad_best_oracle": tg.get("best_oracle"),
        "stream_max_rss_mb": (lh.get("stream") or {}).get("max_rss_mb"),
        "dist_overlap_ratio": sd.get("overlap_ratio"),
        "dist_parallel_ratio": sd.get("dist_parallel_ratio"),
        "dist_finals_match": sd.get("finals_match"),
        "telescope_speedup": tl.get("telescope_speedup"),
        "telescope_bitwise_equal": tl.get("finals_bitwise_equal"),
    }


def read_history(history_path: str = HISTORY_PATH) -> list[dict]:
    if not os.path.exists(history_path):
        return []
    with open(history_path) as f:
        return [json.loads(line) for line in f if line.strip()]


def append_history(bench_path: str = BENCH_PATH,
                   history_path: str = HISTORY_PATH) -> bool:
    """Append one headline row for ``bench_path`` unless the last row
    already carries the same content digest.  Returns True if a row was
    written."""
    with open(bench_path) as f:
        bench = json.load(f)
    digest = _digest(bench)
    rows = read_history(history_path)
    if rows and rows[-1].get("digest") == digest:
        return False
    date, sha = _git_stamp(bench_path)
    row = {"date": date, "commit": sha, "digest": digest,
           **headline(bench)}
    with open(history_path, "a") as f:
        f.write(json.dumps(row) + "\n")
    return True


def main() -> None:
    ap = argparse.ArgumentParser("benchmarks.archive")
    ap.add_argument("--show", action="store_true",
                    help="print the history rows instead of appending")
    a = ap.parse_args()
    if a.show:
        for row in read_history():
            print(json.dumps(row))
        return
    if append_history():
        print(f"appended headline row -> {os.path.abspath(HISTORY_PATH)}")
    else:
        print("snapshot unchanged — no row appended")


if __name__ == "__main__":
    main()
