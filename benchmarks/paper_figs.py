"""Paper-figure benchmarks (DCSim §4.1): one function per figure.

Each returns (rows, derived) where rows are CSV-able dicts and ``derived``
is the one-line claim check recorded in EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, run_policy, series
from repro.core import SimConfig


def fig4_datacenter():
    """Queues + overloaded hosts per policy (paper Fig 4)."""
    rows, claims = [], []
    peak_dep = {}
    for p in POLICIES:
        rep, m = run_policy(p)
        over = series(m, "n_overloaded")
        rows.append({
            "policy": p,
            "peak_deployed": rep["peak_deployed"],
            "overloaded_first8s": int(over[:8].sum()),
            "t_first_overload": int(np.argmax(over > 0)) if (over > 0).any()
            else -1,
            "completed": rep["n_completed"],
        })
        peak_dep[p] = rep["peak_deployed"]
    claims.append(("running queue saturates ~120",
                   100 < max(peak_dep.values()) < 150))
    _, m_rd = run_policy("round")
    claims.append(("Round zero overload 0-8s",
                   series(m_rd, "n_overloaded")[:8].max() == 0))
    return rows, claims


def fig5_network():
    """Avg container communication time vs link bw / loss (paper Fig 5)."""
    rows = []
    comm = {}
    for bw, loss in [(1000.0, 0.0), (600.0, 0.0), (200.0, 0.0),
                     (1000.0, 0.01), (1000.0, 0.02), (200.0, 0.02)]:
        for p in POLICIES:
            rep, _ = run_policy(p, bw=bw, loss=loss)
            rows.append({"policy": p, "bw_mbps": bw, "loss": loss,
                         "avg_comm_time": round(rep["avg_comm_time"], 3)})
            comm[(p, bw, loss)] = rep["avg_comm_time"]
    worst = (200.0, 0.02)
    claims = [
        ("JobGroup lowest comm time @200Mbps/2%",
         comm[("jobgroup", *worst)] == min(comm[(p, *worst)]
                                           for p in POLICIES)),
        ("Round highest comm time @200Mbps/2%",
         comm[("round", *worst)] == max(comm[(p, *worst)]
                                        for p in POLICIES)),
        ("comm time rises as bw drops (firstfit)",
         comm[("firstfit", 200.0, 0.0)] > comm[("firstfit", 1000.0, 0.0)]),
        ("comm time rises with loss (firstfit)",
         comm[("firstfit", 1000.0, 0.02)] > comm[("firstfit", 1000.0, 0.0)]),
    ]
    return rows, claims


def fig6_scheduling():
    """Arrivals vs scheduling decisions per round (paper Fig 6)."""
    rows, claims = [], []
    for p in POLICIES:
        rep, m = run_policy(p)
        arr = series(m, "new_arrivals")
        dec = series(m, "decisions")
        rows.append({
            "policy": p,
            "arrivals_total": int(arr.sum()),
            "decisions_total": int(dec.sum()),
            "decisions_0_10s": int(dec[:10].sum()),
            "arrivals_0_10s": int(arr[:10].sum()),
            "t_last_decision": int(np.max(np.nonzero(dec)[0])),
        })
    # early capacity: decisions track arrivals in the first 10 s
    r0 = rows[0]
    claims.append(("decisions~arrivals while capacity lasts (<=10s)",
                   abs(r0["decisions_0_10s"] - r0["arrivals_0_10s"])
                   <= max(6, int(0.15 * max(r0["arrivals_0_10s"], 1)))))
    claims.append(("decisions stop once workload drains",
                   all(r["t_last_decision"] < 90 for r in rows)))
    return rows, claims


def fig7_migration():
    """OverloadMigrate migration timeline (paper Fig 7)."""
    rep, m = run_policy("overload_migrate")
    mig = series(m, "migrations")
    rows = [{"window": "0-40s", "migrations": int(mig[:40].sum())},
            {"window": "40-60s", "migrations": int(mig[40:60].sum())},
            {"window": "60s+", "migrations": int(mig[60:].sum())},
            {"window": "total", "migrations": int(mig.sum())}]
    claims = [("migrations happen", mig.sum() > 0),
              ("migration stops once overload clears",
               mig[80:].sum() == 0)]
    return rows, claims


def fig8_system():
    """Average container runtime vs link loss (paper Fig 8)."""
    rows = []
    rt = {}
    for loss in (0.0, 0.01, 0.02):
        for p in POLICIES:
            rep, _ = run_policy(p, loss=loss)
            rows.append({"policy": p, "loss": loss,
                         "avg_runtime": round(rep["avg_runtime"], 2),
                         "total_cost": round(rep["total_cost"], 0)})
            rt[(p, loss)] = rep["avg_runtime"]
    claims = [
        ("JobGroup lowest avg runtime @2% loss",
         rt[("jobgroup", 0.02)] == min(rt[(p, 0.02)] for p in POLICIES)),
        ("Round worst avg runtime @2% loss",
         rt[("round", 0.02)] == max(rt[(p, 0.02)] for p in POLICIES)),
        ("loss widens the gap",
         (rt[("round", 0.02)] - rt[("jobgroup", 0.02)])
         > (rt[("round", 0.0)] - rt[("jobgroup", 0.0)])),
    ]
    return rows, claims


def fig9_10_variance():
    """Stretched workload: queue drain + utilization variance (Figs 9/10)."""
    rows, claims = [], []
    var = {}
    for window, label in [(36.0, "36s"), (100.0, "100s")]:
        cfg = SimConfig(arrival_window=window,
                        horizon=160 if window > 50 else 120)
        for p in POLICIES:
            rep, m = run_policy(p, cfg=cfg)
            rows.append({"policy": p, "arrival_window": label,
                         "mean_util_variance":
                             round(rep["mean_util_variance"], 5),
                         "peak_waiting": int(series(m, "n_inactive").max()),
                         "completed": rep["n_completed"]})
            var[(p, label)] = rep["mean_util_variance"]
    claims.append(
        ("Round & JobGroup lowest util variance @100s",
         sorted(POLICIES, key=lambda p: var[(p, "100s")])[:2]
         in ([a, b] for a in ("round", "jobgroup")
             for b in ("round", "jobgroup") if a != b)))
    w36 = [r["peak_waiting"] for r in rows if r["arrival_window"] == "36s"]
    w100 = [r["peak_waiting"] for r in rows if r["arrival_window"] == "100s"]
    claims.append(("stretched arrivals shrink the waiting queue",
                   max(w100) < max(w36)))
    return rows, claims
