"""Long-horizon memory bench: streaming O(state) vs stacked O(horizon).

The tentpole claim of the streaming engine is a MEMORY property, so it is
measured as one: each measurement runs in its own subprocess (a fresh
process is the only honest max-RSS scope — the parent's warm XLA arenas
would pollute ``ru_maxrss``), reporting its own peak RSS on exit.

One trap makes the child-side peak subtle: ``subprocess`` here uses
fork+exec (``cwd=`` disables the posix_spawn fast path), and between fork
and exec the child *shares the parent's entire resident set*, so its
VmHWM / ``ru_maxrss`` high-water starts at the PARENT's current RSS.
Launched from a warm ``engine_bench`` parent holding >1 GB of XLA arenas,
that inherited peak buries the real measurement (both modes once reported
the identical parent RSS).  The child therefore resets its peak counter
via ``/proc/self/clear_refs`` as its very first act, and the parent-side
ceiling poll reads current ``VmRSS`` (never the fork-tainted ``VmHWM``),
demanding two consecutive over-ceiling samples before killing.

Full mode demonstrates the crossing at one (config, horizon) point:

* the STREAMING child runs ``run_sim_vmapped(..., chunk=...)`` to
  completion and reports its peak RSS — O(seeds x state), independent of
  horizon;
* ``ceiling_mb`` is fixed at 1.25x the streaming peak (rounded up);
* the STACKED child runs the same (seeds, horizon) with stacked per-tick
  metrics.  Its scan-ys buffer (seeds x horizon x 16 f32/i32 fields) is
  allocated up front by XLA, so the parent's ``/proc/<pid>/status`` VmRSS
  poll sees the crossing within seconds and kills the child early —
  ``exceeded_ceiling: true`` plus the RSS at kill — instead of paying the
  hours the full stacked run would take.

Quick mode runs the streaming child only, at a short horizon;
``benchmarks/check_regression.py`` gates its peak RSS against the
committed ``ceiling_mb`` absolutely (same backend only) and its ticks/s
through the skew-normalized ratio pack, and re-asserts that the committed
baseline's stacked child did exceed the ceiling.

    PYTHONPATH=src python -m benchmarks.longhorizon_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")

# the minimal-tick micro config: small enough that the tick costs ~0.6 ms
# at seeds=8 on CPU, so a few hundred thousand ticks stream in minutes,
# while the stacked ys buffer (seeds x horizon x 64 B) still dwarfs the
# ceiling margin at the full-mode horizon
LONGHORIZON = dict(n_hosts=4, n_containers=16, seeds=8, chunk=4096)
FULL_HORIZON = 400_000      # stacked buffer: 8 x 4e5 x 64 B ~ 205 MB
QUICK_HORIZON = 30_000
CEILING_FACTOR = 1.25       # ceiling = streaming peak x this, rounded up
STACKED_TIMEOUT_S = 600.0


def _build(horizon: int):
    import jax

    from repro.core import SimConfig, get_policy
    from repro.core.scenario import ScenarioSpec, build_scenarios

    lh = LONGHORIZON
    cfg = SimConfig(n_jobs=max(4, lh["n_containers"] // 3),
                    n_tasks=lh["n_containers"],
                    n_containers=lh["n_containers"], horizon=horizon,
                    placements_per_tick=1, migrations_per_tick=1,
                    waterfill_rounds=2, delay_update_interval=100)
    net_spec, sims, rps = build_scenarios(
        [ScenarioSpec("baseline")], cfg, n_hosts=lh["n_hosts"], n_spine=2,
        n_leaf=2, seeds=tuple(range(lh["seeds"])))
    sims1 = jax.tree.map(lambda x: x[0], sims)
    rp1 = jax.tree.map(lambda x: x[0], rps)
    return cfg, net_spec, sims1, rp1, get_policy("firstfit")


def _reset_peak_rss() -> None:
    """Reset this process's peak-RSS counter to its current RSS.

    Writing "5" to ``/proc/self/clear_refs`` (Linux) drops the VmHWM
    high-water back to the live resident set — discarding the fork-time
    inheritance of the parent's RSS (module docstring).  Best-effort: on a
    kernel without it the report falls back to the tainted peak, which is
    at worst conservative for the stream child (inflated, never deflated).
    """
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5\n")
    except OSError:
        pass


def _self_peak_mb() -> float:
    """This process's peak RSS in MB (VmHWM; ru_maxrss fallback)."""
    hwm = _vm_field_mb(os.getpid(), "VmHWM")
    if hwm is not None:
        return hwm
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def child_main(mode: str, horizon: int) -> None:
    """Run one measurement in THIS process and print a JSON line.

    The peak counter is reset before anything allocates, so the reported
    number covers interpreter + jax import + XLA compile + run — exactly
    the RSS an operator's cgroup limit would see — but NOT the fork-time
    snapshot of the launching process.
    """
    _reset_peak_rss()
    import jax

    from repro.launch.sweep import run_sim_vmapped

    cfg, net_spec, sims, rp, pol = _build(horizon)
    chunk = LONGHORIZON["chunk"] if mode == "stream" else None
    # warm the compile on a tail-sized prefix so the timed section is
    # runtime; the stacked child skips warming — its point is allocation
    if mode == "stream":
        run_sim_vmapped(sims, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                        min(chunk, horizon), rp, chunk=chunk)
    t0 = time.time()
    final, _ = run_sim_vmapped(sims, cfg, pol, net_spec.n_hosts,
                               net_spec.n_nodes, horizon, rp, chunk=chunk)
    jax.tree.leaves(final)[0].block_until_ready()
    wall = time.time() - t0
    rss_mb = _self_peak_mb()
    print(json.dumps({
        "mode": mode, "horizon": horizon, "seeds": LONGHORIZON["seeds"],
        "wall_s": round(wall, 2),
        "ticks_per_s": round(horizon / max(wall, 1e-9), 1),
        "max_rss_mb": round(rss_mb, 1),
        "backend": jax.default_backend(),
    }))


def _child_cmd(mode: str, horizon: int) -> tuple[list[str], dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC) + os.pathsep \
        + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.longhorizon_bench", "--child",
           "--mode", mode, "--horizon", str(horizon)]
    return cmd, env


def _vm_field_mb(pid: int, field: str) -> float | None:
    try:
        with open(f"/proc/{pid}/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return int(line.split()[1]) / 1024.0
    except (FileNotFoundError, ProcessLookupError, ValueError):
        pass
    return None


def run_stream_child(horizon: int) -> dict:
    cmd, env = _child_cmd("stream", horizon)
    out = subprocess.run(cmd, env=env, cwd=os.path.join(HERE, ".."),
                         capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_stacked_child(horizon: int, ceiling_mb: float) -> dict:
    """Launch the stacked run and poll its live VmRSS; kill at the ceiling.

    The stacked scan's ys buffer is allocated when execution starts AND
    stays allocated for the whole run, so a genuine O(horizon) path holds
    above the ceiling within seconds — letting it run on would just burn
    hours proving the same number.  The poll reads current ``VmRSS``, not
    ``VmHWM`` (fork-tainted by the parent's RSS — module docstring), and
    kills only after TWO consecutive over-ceiling samples so the sub-ms
    fork window can never fake a crossing.
    """
    cmd, env = _child_cmd("stacked", horizon)
    proc = subprocess.Popen(cmd, env=env, cwd=os.path.join(HERE, ".."),
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True)
    t0 = time.time()
    peak = 0.0
    over = 0
    try:
        while proc.poll() is None:
            rss = _vm_field_mb(proc.pid, "VmRSS")
            if rss is not None:
                peak = max(peak, rss)
                over = over + 1 if rss > ceiling_mb else 0
            if over >= 2:
                proc.kill()
                proc.wait()
                return {"mode": "stacked", "horizon": horizon,
                        "seeds": LONGHORIZON["seeds"],
                        "exceeded_ceiling": True, "killed": True,
                        "max_rss_mb": round(peak, 1),
                        "wall_to_exceed_s": round(time.time() - t0, 2)}
            if time.time() - t0 > STACKED_TIMEOUT_S:
                proc.kill()
                proc.wait()
                return {"mode": "stacked", "horizon": horizon,
                        "seeds": LONGHORIZON["seeds"],
                        "exceeded_ceiling": False, "killed": True,
                        "timeout": True, "max_rss_mb": round(peak, 1)}
            time.sleep(0.2)
    finally:
        if proc.poll() is None:
            proc.kill()
    row = json.loads(proc.stdout.read().strip().splitlines()[-1])
    row["exceeded_ceiling"] = row["max_rss_mb"] > ceiling_mb
    row["killed"] = False
    return row


def measure_longhorizon(quick: bool = False) -> dict:
    """The BENCH_engine.json ``longhorizon`` entry."""
    import jax

    horizon = QUICK_HORIZON if quick else FULL_HORIZON
    stream = run_stream_child(horizon)
    entry = {
        **{k: LONGHORIZON[k] for k in ("n_hosts", "n_containers", "seeds",
                                       "chunk")},
        "horizon": horizon,
        "stacked_buffer_mb": round(
            LONGHORIZON["seeds"] * horizon * 64 / 2**20, 1),
        "backend": jax.default_backend(),
        "stream": stream,
    }
    if not quick:
        ceiling = int(-(-stream["max_rss_mb"] * CEILING_FACTOR // 32) * 32)
        entry["ceiling_mb"] = ceiling
        entry["stacked"] = run_stacked_child(horizon, ceiling)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--mode", choices=["stream", "stacked"])
    ap.add_argument("--horizon", type=int)
    args = ap.parse_args()
    if args.child:
        child_main(args.mode, args.horizon)
        return
    entry = measure_longhorizon(quick=args.quick)
    print(json.dumps(entry, indent=1))


if __name__ == "__main__":
    main()
