"""Paper Fig 11 / Table 7: simulator cost vs scale — plus the headline
DCSim-JAX result: the tensor engine removes Mininet's per-process cost.

Paper reference points (8-core Xeon, §4.2): network-node init ~0.8 s/node;
1000 nodes => ~13 min init, 1342 MB RSS, total sim >> arrival window.
Here the 'network' is link tables: init is O(ms), memory O(N^2) floats,
and the whole simulation is one compiled XLA program.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import measure_scale_point
from repro.core import SimConfig, init_sim, get_policy
from repro.core.workload import paper_workload
from repro.launch.sweep import run_sim_vmapped


def one_scale(n_hosts: int, n_containers: int, horizon: int = 120,
              policy: str = "firstfit", seed: int = 0, sparse: bool = True):
    return measure_scale_point(n_hosts, n_containers, horizon=horizon,
                               policy=policy, seed=seed, sparse=sparse)


def fig11_scalability():
    # paper Table 7 sweep (hosts 20..100, containers 300..1500)
    rows = [one_scale(h, c) for h, c in
            [(20, 300), (40, 600), (60, 900), (80, 1200), (100, 1500)]]
    # beyond-paper: scales Mininet cannot reach on one box (sparse flow
    # engine; the 2000-host point is beyond the dense [F, E] path too —
    # see benchmarks/engine_bench.py for the tracked sparse-vs-dense run)
    rows.append(one_scale(500, 3000, horizon=60))
    rows.append(one_scale(2000, 6000, horizon=20))

    paper_init_1000_nodes_s = 0.8 * 1000
    ours = [r for r in rows if r["n_hosts"] == 100][0]
    claims = [
        ("init cost vs paper @~100 hosts",
         f"{ours['init_s']:.2f}s vs paper ~{0.8 * ours['n_network_nodes']:.0f}s "
         f"({0.8 * ours['n_network_nodes'] / max(ours['init_s'], 1e-9):,.0f}x)"),
        ("steady-state sim speed",
         f"{ours['sim_steady_s']:.2f}s for 120 simulated seconds"),
        ("linear-ish state growth",
         f"{rows[0]['state_mb']:.1f} MB -> {rows[4]['state_mb']:.1f} MB"),
    ]
    return rows, claims


def scenario_vmap_throughput(n_scenarios: int = 8):
    """vmap over seeds: many simulations in one compiled run — structurally
    impossible in the paper's process-per-entity design."""
    cfg = SimConfig(horizon=60)
    from repro.core.datacenter import build_paper_hosts, build_paper_network
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sims = [init_sim(hosts, paper_workload(cfg, seed=s), net, seed=s)
            for s in range(n_scenarios)]
    batched = jax.tree.map(lambda *xs: np.stack(xs), *sims)
    t0 = time.time()
    final, _ = run_sim_vmapped(batched, cfg, get_policy("jobgroup"),
                               spec.n_hosts, spec.n_nodes, cfg.horizon)
    jax.tree.leaves(final)[0].block_until_ready()
    t_batch = time.time() - t0
    t0 = time.time()
    final, _ = run_sim_vmapped(batched, cfg, get_policy("jobgroup"),
                               spec.n_hosts, spec.n_nodes, cfg.horizon)
    jax.tree.leaves(final)[0].block_until_ready()
    t_batch2 = time.time() - t0
    return [{"n_scenarios": n_scenarios,
             "batch_first_s": round(t_batch, 2),
             "batch_steady_s": round(t_batch2, 3),
             "scenarios_per_s": round(n_scenarios / max(t_batch2, 1e-9), 1)}], \
        [("vmap scenarios amortize", f"{n_scenarios} seeds in "
          f"{t_batch2:.2f}s steady-state")]
