"""DeepSeek-V2 236B [arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2].

60L d_model=5120 128H MLA(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64,
v_head=128), MoE: 2 shared + 160 routed top-6, expert d_ff=1536, first layer
dense (d_ff=12288), vocab 102400.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=12288, vocab=102400,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    first_dense=1,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)

REDUCED = dataclasses.replace(
    CONFIG, name="deepseek-v2-reduced",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab=256, n_experts=8, n_shared_experts=1, top_k=2, d_ff_expert=32,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, logit_chunk=32,
)
