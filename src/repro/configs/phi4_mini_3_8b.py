"""Phi-4-mini 3.8B [arXiv:2412.08905; hf microsoft/Phi-4-mini-instruct].

32L d_model=3072 24H (GQA kv=8, d_head=128) d_ff=8192 vocab 200064,
RoPE + SwiGLU + GQA.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=200064,
)

REDUCED = dataclasses.replace(
    CONFIG, name="phi4-mini-reduced",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16, d_ff=256,
    vocab=256, logit_chunk=32,
)
