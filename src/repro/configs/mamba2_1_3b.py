"""Mamba2-1.3B [arXiv:2405.21060; hf state-spaces/mamba2-1.3b] — attention-
free SSD.  48L d_model=2048 d_inner=4096 headdim=64 ssm_state=128
vocab 50280.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280,
    ssm_state=128, d_inner=4096, ssm_head_dim=64, ssm_chunk=256,
)

REDUCED = dataclasses.replace(
    CONFIG, name="mamba2-reduced",
    n_layers=2, d_model=64, d_ff=0, vocab=256,
    ssm_state=16, d_inner=128, ssm_head_dim=32, ssm_chunk=16,
    logit_chunk=32,
)
