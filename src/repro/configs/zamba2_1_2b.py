"""Zamba2-1.2B [arXiv:2411.15242; hf Zyphra/Zamba2-1.2B] — Mamba2 backbone
with one SHARED attention(+MLP) block applied periodically.

38L d_model=2048; attention 32H (kv=32, d_head=64) d_ff=8192; ssm_state=64;
vocab 32000.  The shared block fires every 6 layers (6 applications).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, d_inner=4096, ssm_head_dim=64, ssm_chunk=256,
    attn_every=6,
)

REDUCED = dataclasses.replace(
    CONFIG, name="zamba2-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab=256, ssm_state=16, d_inner=128, ssm_head_dim=32, ssm_chunk=16,
    attn_every=2, logit_chunk=32,
)
