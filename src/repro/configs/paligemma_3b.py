"""PaliGemma-3B [arXiv:2407.07726; hf google/paligemma-3b-pt-224].

Gemma-2B text backbone: 18L d_model=2048 8H (MQA kv=1, d_head=256)
d_ff=16384 vocab 257216.  SigLIP vision tower is a STUB — ``input_specs``
provides 256 precomputed patch embeddings per image (224px / 14px patches).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab=257216,
    frontend="patch_embeds", n_prefix=256,
)

REDUCED = dataclasses.replace(
    CONFIG, name="paligemma-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_head=16, d_ff=192,
    vocab=256, n_prefix=8, logit_chunk=32,
)
