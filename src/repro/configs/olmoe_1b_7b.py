"""OLMoE-1B-7B [arXiv:2409.02060; hf allenai/OLMoE-1B-7B-0924].

16L d_model=2048 16H (kv=16, MHA) expert d_ff=1024, 64 experts top-8,
vocab 50304.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, d_ff_expert=1024,
)

REDUCED = dataclasses.replace(
    CONFIG, name="olmoe-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=64,
    vocab=256, n_experts=8, top_k=2, d_ff_expert=32, logit_chunk=32,
)
