"""Assigned-architecture registry: ``get_config(name)`` / ``get_reduced(name)``.

Each module defines the EXACT published configuration (``CONFIG``) plus a
``REDUCED`` family-preserving miniature for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeSpec, cell_is_runnable  # noqa: F401

ARCH_IDS = [
    "deepseek_v2_236b",
    "olmoe_1b_7b",
    "smollm_360m",
    "phi4_mini_3_8b",
    "minitron_4b",
    "qwen2_5_3b",
    "zamba2_1_2b",
    "paligemma_3b",
    "musicgen_large",
    "mamba2_1_3b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def _resolve(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name in ARCH_IDS:
        return name
    if name in _ALIASES:
        return _ALIASES[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")


def get_config(name: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_resolve(name)}").CONFIG


def get_reduced(name: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_resolve(name)}").REDUCED
