"""MusicGen-large [arXiv:2306.05284; hf facebook/musicgen-large].

Decoder-only over EnCodec tokens: 48L d_model=2048 32H (kv=32, d_head=64)
d_ff=8192 vocab 2048.  The EnCodec frontend is a STUB — ``input_specs``
provides precomputed frame embeddings (sum of codebook embeddings).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=2048,
    frontend="frame_embeds",
)

REDUCED = dataclasses.replace(
    CONFIG, name="musicgen-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
    vocab=128, logit_chunk=32,
)
