"""SmolLM-360M [hf HuggingFaceTB/SmolLM-360M] — llama-arch small.

32L d_model=960 15H (GQA kv=5, d_head=64) d_ff=2560 vocab 49152.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49152,
)

REDUCED = dataclasses.replace(
    CONFIG, name="smollm-reduced",
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=1, d_head=32, d_ff=256,
    vocab=256, logit_chunk=32,
)
