"""Qwen2.5-3B [hf Qwen/Qwen2.5-3B] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2, d_head=128) d_ff=11008 vocab 151936.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_head=128,
    d_ff=11008, vocab=151936, qkv_bias=True,
)

REDUCED = dataclasses.replace(
    CONFIG, name="qwen2.5-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=192,
    vocab=256, logit_chunk=32,
)
