"""Minitron-4B [arXiv:2407.14679; hf nvidia/Minitron-4B-Base] — pruned
Nemotron.  32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab 256000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=9216, vocab=256000,
)

REDUCED = dataclasses.replace(
    CONFIG, name="minitron-reduced",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16, d_ff=288,
    vocab=256, logit_chunk=32,
)
