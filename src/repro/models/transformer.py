"""Decoder assembly for every assigned architecture family.

One parameter tree + three entry points per model:

* ``forward_train``  — full causal forward, returns (hidden, aux_loss);
* ``prefill``        — forward that also returns the per-layer cache;
* ``decode_step``    — one-token step against the cache.

Homogeneous layer stacks are ``lax.scan``-ed over stacked parameters
([L, ...] leaves) with optional ``jax.checkpoint`` (remat) on the body.
Heterogeneous structure (deepseek's leading dense layer, zamba2's shared
attention block every k layers) is handled around/inside the scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    BF16, F32, attn_block, init_attn, init_mlp, mlp, rmsnorm,
)


def _constrain_act(x, mesh, dp, seq: bool = False):
    """Pin [B,S,d] activations to batch-over-data sharding.  Without this,
    GSPMD propagation from the (vocab x d)-sharded embedding table can leave
    full-batch replicas on every device (observed: 3.8 GiB f32 all-gathers).

    ``seq=True`` additionally shards the sequence dim over the model axis
    (sequence parallelism; cfg.seq_parallel — EXPERIMENTS.md §Perf)."""
    if mesh is None or mesh.size == 1:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(dp, "model", None) if seq else P(dp, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _sp_mode(cfg, mesh, S: int, decode: bool) -> str:
    """Resolve the active sequence-parallel mode for this call site."""
    if (cfg.seq_parallel == "off" or mesh is None or mesh.size == 1
            or decode or "model" not in mesh.axis_names):
        return "off"
    if S % dict(mesh.shape)["model"] != 0:
        return "off"
    return cfg.seq_parallel

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, key, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.ones((d,), F32)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    if cfg.use_mla:
        p["attn"] = mla_mod.init_mla(ks[0], cfg)
    else:
        p["attn"] = init_attn(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                              cfg.d_head, cfg.qkv_bias)
    p["ln2"] = jnp.ones((d,), F32)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff)
    return p


def _block_kinds(cfg: ModelConfig) -> Tuple[str, str, int]:
    """(first-layers kind, scanned kind, n_first)."""
    if cfg.family in ("ssm", "hybrid"):
        return "ssm", "ssm", 0
    if cfg.family == "moe":
        return "dense", "moe", cfg.first_dense
    return "dense", "dense", 0


def init_params(cfg: ModelConfig, key) -> Params:
    k_embed, k_first, k_blocks, k_extra, k_out = jax.random.split(key, 5)
    d, Vp = cfg.d_model, cfg.vocab_padded
    first_kind, kind, n_first = _block_kinds(cfg)

    params: Params = {
        "embed": jax.random.normal(k_embed, (Vp, d), F32) * 0.02,
        "final_norm": jnp.ones((d,), F32),
        "unembed": jax.random.normal(k_out, (d, Vp), F32) * (d ** -0.5),
    }
    n_scan = cfg.n_layers - n_first
    keys = jax.random.split(k_blocks, n_scan)
    params["blocks"] = jax.vmap(
        lambda k: _init_block(cfg, k, kind))(keys)
    if n_first:
        fkeys = jax.random.split(k_first, n_first)
        params["first_blocks"] = jax.vmap(
            lambda k: _init_block(cfg, k, first_kind))(fkeys)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_block(cfg, k_extra, "dense")
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _dense_block(p, x, cfg, positions, *, cache=None, cache_len=None,
                 mesh=None, dp=("data",), kind="dense", sp="off"):
    """Residual attention(+MLA) block followed by MLP or MoE.

    Returns (x, new_cache, aux).  ``sp='attn'`` runs the attention body
    sequence-sharded over the model axis — the cure for archs whose head
    count does not divide the model axis (smollm 15H, phi4/minitron 24H),
    where the baseline replicates the whole S^2 logits tensor on every
    model shard (EXPERIMENTS.md §Perf).
    """
    msize = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    attn_sp = (sp == "attn" and cache is None and msize > 1
               and cfg.n_heads % msize != 0)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if attn_sp:
        h = _constrain_act(h, mesh, dp, seq=True)
    if cfg.use_mla:
        if cache is None:
            a, new_cache = mla_mod.mla_prefill(p["attn"], h, cfg, positions,
                                               impl=cfg.attn_impl,
                                               mesh=mesh, dp=dp)
        else:
            a, new_cache = mla_mod.mla_decode(p["attn"], h, cfg, positions,
                                              cache, cache_len)
    else:
        a, new_cache = attn_block(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, rope_theta=cfg.rope_theta, positions=positions,
            impl=cfg.attn_impl, cache_kv=cache, cache_len=cache_len)
    if attn_sp:
        a = _constrain_act(a, mesh, dp, seq=False)
    x = x + a

    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_mod.moe_layer(p["moe"], h2, cfg, mesh, dp)
    else:
        y, aux = mlp(p["mlp"], h2), jnp.zeros((), F32)
    return x + y, new_cache, aux


def _ssm_res_block(p, x, cfg, *, mode="train", state=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    y, new_state = ssm_mod.ssm_block(p["ssm"], h, cfg, mode=mode, state=state,
                                     impl=cfg.ssm_impl)
    return x + y, new_state


# ---------------------------------------------------------------------------
# Embedding / stacks
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens, cfg):
    return params["embed"].astype(BF16)[tokens]


def _assemble_input(params, batch, cfg):
    """Token/stub-frontend embedding -> x [B,S,d] (see config.frontend)."""
    if cfg.frontend == "patch_embeds":
        prefix = batch["patch_embeds"].astype(BF16)          # [B,Np,d]
        text = embed_tokens(params, batch["tokens"], cfg)
        return jnp.concatenate([prefix, text], axis=1)
    if cfg.frontend == "frame_embeds":
        return batch["frame_embeds"].astype(BF16)            # [B,S,d]
    return embed_tokens(params, batch["tokens"], cfg)


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable
                          ) if cfg.remat else fn


def _scan_or_unroll(body, carry, xs, use_scan: bool):
    """lax.scan, or a python unroll (cfg.scan_layers=False).

    The unrolled form exists for the roofline pass: XLA's HloCostAnalysis
    (and any HLO-text collective accounting) counts a while-loop body ONCE,
    so scanned-layer programs under-report FLOPs/bytes/collectives by ~L x.
    Unrolling gives cost-exact HLO; scanning gives fast compiles and is the
    deploy configuration.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys_all = []
    for i in range(L):
        xi = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, ys = body(carry, xi)
        ys_all.append(ys)
    ys = jax.tree.map(lambda *v: jnp.stack(v), *ys_all)
    return carry, ys


def _run_stack(cfg, params, x, positions, *, mode, mesh, dp,
               cache=None, cache_len=None):
    """Apply first_blocks + scanned blocks.  Returns (x, new_cache, aux).

    ``cache`` (decode) / returned cache (prefill) is a pytree whose leading
    axis is the layer for scanned blocks (plus separate entries for the
    leading dense layers and zamba2's shared-attention applications).
    """
    first_kind, kind, n_first = _block_kinds(cfg)
    sp = _sp_mode(cfg, mesh, x.shape[1], decode=(mode == "decode"))
    aux_total = jnp.zeros((), F32)
    new_cache: Dict[str, Any] = {}

    # --- leading (non-scanned) layers -------------------------------------
    if n_first:
        fc = []
        for i in range(n_first):
            p_i = jax.tree.map(lambda a: a[i], params["first_blocks"])
            c_i = None if cache is None else jax.tree.map(
                lambda a: a[i], cache["first"])
            x, c, aux = _dense_block(p_i, x, cfg, positions, cache=c_i,
                                     cache_len=cache_len, mesh=mesh, dp=dp,
                                     kind=first_kind, sp=sp)
            aux_total += aux
            fc.append(c)
        if mode != "train":
            new_cache["first"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *fc)

    # --- scanned stack -----------------------------------------------------
    if cfg.family in ("ssm", "hybrid"):
        x, new_cache, aux = _run_ssm_stack(
            cfg, params, x, positions, mode=mode, cache=cache,
            cache_len=cache_len, new_cache=new_cache, mesh=mesh, dp=dp,
            sp=sp)
        aux_total += aux
        return x, new_cache, aux_total

    def body(carry, xs):
        h = carry
        if cache is None:
            p_l = xs
            c_l = None
        else:
            p_l, c_l = xs
        h, c, aux = _dense_block(p_l, h, cfg, positions, cache=c_l,
                                 cache_len=cache_len, mesh=mesh, dp=dp,
                                 kind=kind, sp=sp)
        h = _constrain_act(h, mesh, dp, seq=(sp == "full"))
        ys = (aux,) if mode == "train" else (aux, c)
        return h, ys

    body = _maybe_remat(body, cfg)
    xs = params["blocks"] if cache is None else (params["blocks"],
                                                 cache["layers"])
    x, ys = _scan_or_unroll(body, x, xs, cfg.scan_layers)
    aux_total += ys[0].sum()
    if mode != "train":
        new_cache["layers"] = ys[1]
    return x, new_cache, aux_total


def _run_ssm_stack(cfg, params, x, positions, *, mode, cache, cache_len,
                   new_cache, mesh=None, dp=("data",), sp="off"):
    """Mamba2 stack; zamba2 interleaves one *shared* attention block every
    ``attn_every`` layers (its own KV cache per application).

    * train:   no caches carried at all;
    * prefill: attention runs causal (cache=None path) and its fresh (k, v)
      is written into the per-application cache carry;
    * decode:  attention reads/updates the application's cache slice.
    """
    L = cfg.n_layers
    hybrid = cfg.family == "hybrid"
    n_apps = cfg.n_attn_applications if hybrid else 0
    decode = mode == "decode" and x.shape[1] == 1
    ssm_mode = "decode" if decode else "train"

    def body(carry, xs):
        if hybrid:
            h, attn_cache, app_idx = carry
        else:
            h = carry
        if cache is None:
            p_l, i = xs
            s_l = None
        else:
            p_l, i, s_l = xs
        h, s_new = _ssm_res_block(p_l, h, cfg, mode=ssm_mode, state=s_l)

        if hybrid:
            apply = (i % cfg.attn_every) == (cfg.attn_every - 1)

            def do_attn(h, attn_cache, app_idx):
                if decode:
                    c_a = jax.tree.map(lambda a: a[app_idx], attn_cache)
                    h2, c_new, _ = _dense_block(
                        params["shared_attn"], h, cfg, positions, cache=c_a,
                        cache_len=cache_len, kind="dense")
                else:
                    h2, c_new, _ = _dense_block(
                        params["shared_attn"], h, cfg, positions, cache=None,
                        kind="dense", mesh=mesh, dp=dp, sp=sp)
                if mode != "train":
                    attn_cache = jax.tree.map(
                        lambda full, one: jax.lax.dynamic_update_index_in_dim(
                            full, one.astype(full.dtype), app_idx, 0),
                        attn_cache, c_new)
                return h2, attn_cache

            def no_attn(h, attn_cache, app_idx):
                return h, attn_cache

            h, attn_cache = jax.lax.cond(apply, do_attn, no_attn,
                                         h, attn_cache, app_idx)
            app_idx = app_idx + apply.astype(jnp.int32)
            carry = (_constrain_act(h, mesh, dp), attn_cache, app_idx)
        else:
            carry = _constrain_act(h, mesh, dp)
        ys = s_new if mode != "train" else None
        return carry, ys

    body = _maybe_remat(body, cfg)
    idx = jnp.arange(L)
    if cache is None:
        xs = (params["blocks"], idx)
    else:
        xs = (params["blocks"], idx, cache["ssm"])

    if hybrid:
        if mode == "train":
            # dummy 0-size carry keeps the pytree structure without memory
            attn_cache0 = (jnp.zeros((n_apps, 0), BF16),
                           jnp.zeros((n_apps, 0), BF16))
        elif cache is not None:
            attn_cache0 = cache["attn"]
        else:
            attn_cache0 = _hybrid_attn_cache(cfg, x.shape[0], x.shape[1],
                                             n_apps)
        carry0 = (x, attn_cache0, jnp.zeros((), jnp.int32))
        (x, attn_cache, _), ys = _scan_or_unroll(body, carry0, xs,
                                                 cfg.scan_layers)
        if mode != "train":
            new_cache["attn"] = attn_cache
    else:
        x, ys = _scan_or_unroll(body, x, xs, cfg.scan_layers)
    if mode != "train":
        new_cache["ssm"] = ys
    return x, new_cache, jnp.zeros((), F32)


def _hybrid_attn_cache(cfg, B, T, n_apps):
    shape = (n_apps, B, T, cfg.n_kv_heads, cfg.d_head)
    return (jnp.zeros(shape, BF16), jnp.zeros(shape, BF16))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def forward_train(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
                  mesh=None, dp: tuple = ("data",)):
    """Returns (hidden [B,S,d], aux_loss)."""
    x = _assemble_input(params, batch, cfg)
    S = x.shape[1]
    sp = _sp_mode(cfg, mesh, S, decode=False)
    x = _constrain_act(x, mesh, dp, seq=(sp == "full"))
    positions = jnp.arange(S)
    x, _, aux = _run_stack(cfg, params, x, positions, mode="train",
                           mesh=mesh, dp=dp)
    x = _constrain_act(x, mesh, dp)       # loss chunks want S unsharded
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any],
            mesh=None, dp: tuple = ("data",)):
    """Returns (last-position logits [B,Vp], cache, seq_len)."""
    x = _assemble_input(params, batch, cfg)
    S = x.shape[1]
    sp = _sp_mode(cfg, mesh, S, decode=False)
    x = _constrain_act(x, mesh, dp, seq=(sp == "full"))
    positions = jnp.arange(S)
    x, cache, _ = _run_stack(cfg, params, x, positions, mode="prefill",
                             mesh=mesh, dp=dp)
    x = _constrain_act(x, mesh, dp)
    h_last = rmsnorm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = (h_last.astype(BF16) @ params["unembed"].astype(BF16)
              ).astype(F32)
    return logits, cache, S


def decode_step(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                cache, cache_len: jnp.ndarray, mesh=None,
                dp: tuple = ("data",)):
    """One decode step.  tokens [B,1] -> (logits [B,Vp], cache')."""
    x = _constrain_act(embed_tokens(params, tokens, cfg), mesh, dp)
    positions = cache_len + jnp.arange(x.shape[1])
    x, new_cache, _ = _run_stack(cfg, params, x, positions, mode="decode",
                                 mesh=mesh, dp=dp, cache=cache,
                                 cache_len=cache_len)
    h = rmsnorm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = (h.astype(BF16) @ params["unembed"].astype(BF16)).astype(F32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Decode-cache construction (shapes only — dry-run uses eval_shape)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """Empty decode cache sized for ``max_len`` positions."""
    first_kind, kind, n_first = _block_kinds(cfg)
    n_scan = cfg.n_layers - n_first
    cache: Dict[str, Any] = {}

    def attn_cache(n):
        if cfg.use_mla:
            return (jnp.zeros((n, batch_size, max_len, cfg.kv_lora_rank),
                              BF16),
                    jnp.zeros((n, batch_size, max_len, cfg.qk_rope_dim),
                              BF16))
        shape = (n, batch_size, max_len, cfg.n_kv_heads, cfg.d_head)
        return (jnp.zeros(shape, BF16), jnp.zeros(shape, BF16))

    if cfg.family in ("ssm", "hybrid"):
        H, Pd, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        ch = cfg.d_inner + 2 * cfg.ssm_state
        cache["ssm"] = (
            jnp.zeros((cfg.n_layers, batch_size, H, Pd, N), F32),
            jnp.zeros((cfg.n_layers, batch_size, cfg.conv_width - 1, ch),
                      F32),
        )
        if cfg.family == "hybrid":
            cache["attn"] = _hybrid_attn_cache(cfg, batch_size, max_len,
                                               cfg.n_attn_applications)
        return cache

    cache["layers"] = attn_cache(n_scan)
    if n_first:
        cache["first"] = attn_cache(n_first)
    return cache
