"""Shared model building blocks: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

All functions are pure; parameters are plain dicts of jnp arrays.  Matmul
inputs are cast to bf16 (MXU-native) while reductions (softmax, norms, loss)
run in f32.  Attention has two implementations selected by config:
``xla`` (einsum reference, used for CPU dry-runs and as the kernel oracle)
and ``pallas`` (the flash-attention kernel in ``repro/kernels``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

BF16 = jnp.bfloat16
F32 = jnp.float32
NEG_INF = -1e30


def init_dense(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), F32) * scale


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions: jnp.ndarray, dim: int,
                theta: float = 10000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for ``positions`` [**shape**] -> [..., dim//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    ang = positions.astype(F32)[..., None] * inv           # [..., dim/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x [..., S, H, D]; cos/sin [S, D/2] (broadcast over batch/heads).

    Returns x.dtype: the f32 cos/sin multiply must NOT leak f32 q/k into
    attention — that doubles every attention byte moved (HLO-verified:
    6 GiB f32 [B,H,S,dk] gathers in the deepseek dry-run before this cast).
    """
    d_half = x.shape[-1] // 2
    x1, x2 = x[..., :d_half], x[..., d_half:]
    c = cos[..., None, :]                                   # [S, 1, D/2]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA via n_kv_heads)
# ---------------------------------------------------------------------------
def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  q_offset: jnp.ndarray | int = 0,
                  kv_valid_len: Optional[jnp.ndarray] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention.  q [B,Sq,Hq,Dk], k [B,Skv,Hkv,Dk], v [B,Skv,Hkv,Dv].

    * ``q_offset``: absolute position of q[0] (decode: cache length).
    * ``kv_valid_len``: mask out cache slots >= this length.
    """
    B, Sq, Hq, Dk = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)

    qg = q.reshape(B, Sq, Hkv, G, Dk).astype(BF16)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(BF16),
                        preferred_element_type=F32) * scale

    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if kv_valid_len is not None:
        mask &= kv_pos[None, :] < kv_valid_len
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)

    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", att.astype(BF16), v.astype(BF16),
                     preferred_element_type=F32)
    return out.reshape(B, Sq, Hq, v.shape[-1])


def attention(q, k, v, *, impl: str = "xla", causal: bool = True,
              q_offset=0, kv_valid_len=None, scale=None):
    if impl == "pallas" and q.shape[1] > 1 and kv_valid_len is None:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, scale=scale)
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                         kv_valid_len=kv_valid_len, scale=scale)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff),
        "w_up": init_dense(k2, d_model, d_ff),
        "w_down": init_dense(k3, d_ff, d_model),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    xb = x.astype(BF16)
    g = xb @ params["w_gate"].astype(BF16)
    u = xb @ params["w_up"].astype(BF16)
    h = jax.nn.silu(g.astype(F32)).astype(BF16) * u
    return (h @ params["w_down"].astype(BF16)).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (params + forward, cache-aware)
# ---------------------------------------------------------------------------
def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
              qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d_model, n_heads * d_head),
        "wk": init_dense(ks[1], d_model, n_kv_heads * d_head),
        "wv": init_dense(ks[2], d_model, n_kv_heads * d_head),
        "wo": init_dense(ks[3], n_heads * d_head, d_model),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), F32)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), F32)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), F32)
    return p


def attn_qkv(params, x, n_heads, n_kv_heads, d_head):
    B, S, _ = x.shape
    xb = x.astype(BF16)
    q = xb @ params["wq"].astype(BF16)
    k = xb @ params["wk"].astype(BF16)
    v = xb @ params["wv"].astype(BF16)
    if "bq" in params:
        q = q + params["bq"].astype(BF16)
        k = k + params["bk"].astype(BF16)
        v = v + params["bv"].astype(BF16)
    return (q.reshape(B, S, n_heads, d_head),
            k.reshape(B, S, n_kv_heads, d_head),
            v.reshape(B, S, n_kv_heads, d_head))


def attn_block(params, x, *, n_heads, n_kv_heads, d_head, rope_theta,
               positions, impl="xla", cache_kv=None, cache_len=None):
    """Full GQA attention with RoPE.

    * train/prefill: ``cache_kv`` None -> causal self-attention over x;
      returns (out, (k, v)) so prefill can persist the cache.
    * decode: ``cache_kv`` = (k_cache [B,T,Hkv,D], v_cache) with ``cache_len``
      valid entries; x is the new token(s); returns (out, (k', v')).
    """
    B, S, _ = x.shape
    q, k, v = attn_qkv(params, x, n_heads, n_kv_heads, d_head)
    cos, sin = rope_angles(positions, d_head, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache_kv is None:
        out = attention(q, k, v, impl=impl, causal=True)
        new_cache = (k.astype(BF16), v.astype(BF16))
    else:
        k_cache, v_cache = cache_kv
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        out = attention(q, k_cache, v_cache, impl=impl, causal=False,
                        kv_valid_len=cache_len + S)
        new_cache = (k_cache, v_cache)

    out = out.reshape(B, S, n_heads * d_head).astype(BF16)
    return (out @ params["wo"].astype(BF16)).astype(x.dtype), new_cache
