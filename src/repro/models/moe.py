"""Mixture-of-Experts layer with an explicit expert-parallel (EP) path.

Router runs under plain pjit; dispatch/compute/combine run under
``shard_map``:

* tokens are sharded over the data axes and *replicated* over ``model``;
* experts are sharded over ``model`` (E_l = E / |model| per shard) with their
  weights FSDP-sharded over ``data`` (gathered per layer inside the shard —
  the all_gather's AD transpose is the reduce-scatter of expert grads);
* each shard scatter-packs the tokens routed to ITS experts into a
  fixed-capacity buffer [E_l, C, d] (GShard-style capacity drop), runs the
  grouped SwiGLU, scatters results back weighted, and a single
  ``psum('model')`` combines partial token outputs.

This avoids the classic [T, E, C] one-hot dispatch einsum, whose FLOPs are
quadratic in tokens and would drown the roofline's useful-compute ratio.

A dense "oracle" path (every expert on every token, one-hot combine) exists
for tiny smoke tests and as the correctness reference.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import SM_NOCHECK as _SM_NOCHECK, shard_map

from repro.models.layers import BF16, F32, init_dense

MODEL_AXIS = "model"


def init_moe(key, cfg):
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    p = {
        "router": init_dense(ks[0], d, E, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (E, d, f), F32) * (d ** -0.5),
        "w_up": jax.random.normal(ks[2], (E, d, f), F32) * (d ** -0.5),
        "w_down": jax.random.normal(ks[3], (E, f, d), F32) * (f ** -0.5),
    }
    if cfg.n_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * f)
    return p


def router_topk(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k routing probabilities.  Returns (weights [B,S,k], idx [B,S,k],
    aux_loss scalar) — aux is the standard load-balancing loss."""
    logits = (x.astype(BF16) @ params["router"].astype(BF16)).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)                     # [B,S,E]
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_i f_i * p_i
    E = cfg.n_experts
    onehot = jax.nn.one_hot(topi, E, dtype=F32).sum(-2)         # [B,S,E]
    f = onehot.mean((0, 1)) / cfg.top_k
    p_mean = probs.mean((0, 1))
    aux = E * jnp.sum(f * p_mean)
    return topw, topi, aux


def _capacity(tokens_per_shard: int, cfg) -> int:
    c = int(tokens_per_shard * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts)
    return max(8, ((c + 7) // 8) * 8)


def _ep_shard(x, topw, topi, w_gate, w_up, w_down, *, cfg, n_model: int,
              data_axes: tuple):
    """Per-(data, model)-shard body.  x [b,S,d] local tokens (replicated over
    model); w_* [E_l, d/|data|, f] local expert shards."""
    b, S, d = x.shape
    T = b * S
    E_l = cfg.n_experts // n_model
    C = _capacity(T, cfg)

    # FSDP: gather this layer's expert weights over the FSDP axis.
    # Cast to bf16 FIRST so the all-gather moves half the bytes (its AD
    # transpose reduce-scatters bf16 grads, cast up afterwards).  Weights
    # are sharded P(model, data, ...): only 'data' is gathered — on the
    # multi-pod mesh they are REPLICATED over 'pod' (gathering there would
    # duplicate the tensor).
    w_gate, w_up, w_down = (w_gate.astype(BF16), w_up.astype(BF16),
                            w_down.astype(BF16))
    for ax in ("data",):
        w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)

    xt = x.reshape(T, d)
    wk = topw.reshape(T * cfg.top_k)
    ek = topi.reshape(T * cfg.top_k)
    tok = jnp.repeat(jnp.arange(T), cfg.top_k)

    shard = jax.lax.axis_index(MODEL_AXIS)
    lo = shard * E_l
    e_loc = ek - lo
    in_range = (e_loc >= 0) & (e_loc < E_l)
    e_bucket = jnp.where(in_range, e_loc, E_l)                 # E_l = dump

    # rank of each assignment within its expert (stable arrival order)
    onehot = jax.nn.one_hot(e_bucket, E_l + 1, dtype=jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                               e_bucket[:, None], axis=1)[:, 0]
    keep = in_range & (rank < C)
    slot = jnp.where(keep, e_loc * C + rank, E_l * C)          # OOB -> drop

    buf = jnp.zeros((E_l * C, d), BF16)
    buf = buf.at[slot].add(xt[tok].astype(BF16) * keep[:, None], mode="drop")
    buf = buf.reshape(E_l, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(BF16))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(BF16))
    h = jax.nn.silu(g.astype(F32)).astype(BF16) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(BF16))
    y_buf = y_buf.reshape(E_l * C, d)

    vals = y_buf[jnp.clip(slot, 0, E_l * C - 1)]
    vals = vals * (wk * keep).astype(BF16)[:, None]
    y = jnp.zeros((T, d), BF16).at[tok].add(vals)
    y = jax.lax.psum(y, MODEL_AXIS)
    return y.reshape(b, S, d)


def _ep_a2a_shard(x, topw, topi, w_gate, w_up, w_down, *, cfg,
                  n_model: int, data_axes: tuple):
    """All-to-all EP body (cfg.moe_impl='a2a').  x [b, S_l, d]: tokens
    SEQUENCE-SHARDED over the model axis (no replication), experts sharded
    over model.  Each shard routes its own tokens, exchanges them with the
    shard owning the chosen expert via all_to_all, computes, and exchanges
    back — no [T, d] psum, no 16x redundant dispatch.
    """
    b, S_l, d = x.shape
    T = b * S_l
    E, k = cfg.n_experts, cfg.top_k
    E_l = E // n_model

    # gather expert weights over the FSDP axis (bf16; transpose = RS
    # grads).  'data' only — weights are pod-replicated (see _ep_shard).
    w_gate, w_up, w_down = (w_gate.astype(BF16), w_up.astype(BF16),
                            w_down.astype(BF16))
    for ax in ("data",):
        w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)

    xt = x.reshape(T, d)
    wk = topw.reshape(T * k)
    ek = topi.reshape(T * k)                         # global expert ids
    tok = jnp.repeat(jnp.arange(T), k)

    # ---- send side: pack assignments by destination shard ----------------
    dest = ek // E_l                                 # [A] target shard
    c = int(T * k * cfg.capacity_factor / n_model)   # per-destination slots
    C_send = max(8, ((c + 7) // 8) * 8)
    onehot_d = jax.nn.one_hot(dest, n_model, dtype=jnp.int32)
    rank_d = jnp.take_along_axis(jnp.cumsum(onehot_d, axis=0) - 1,
                                 dest[:, None], axis=1)[:, 0]
    keep = rank_d < C_send
    slot = jnp.where(keep, dest * C_send + rank_d, n_model * C_send)

    send_x = jnp.zeros((n_model * C_send, d), BF16)
    send_x = send_x.at[slot].add(xt[tok].astype(BF16) * keep[:, None],
                                 mode="drop")
    # payload metadata: local expert id at the destination (-1 = empty)
    send_e = jnp.full((n_model * C_send,), E_l, jnp.int32)
    send_e = send_e.at[slot].set(jnp.where(keep, ek % E_l, E_l),
                                 mode="drop")

    recv_x = jax.lax.all_to_all(send_x.reshape(n_model, C_send, d),
                                MODEL_AXIS, split_axis=0, concat_axis=0,
                                tiled=False)         # [n_model, C_send, d]
    recv_e = jax.lax.all_to_all(send_e.reshape(n_model, C_send),
                                MODEL_AXIS, split_axis=0, concat_axis=0,
                                tiled=False)
    R = n_model * C_send
    rx = recv_x.reshape(R, d)
    re = recv_e.reshape(R)

    # ---- receiver: pack by local expert, grouped matmul ------------------
    C_exp = _capacity(T * n_model, cfg)
    onehot_e = jax.nn.one_hot(re, E_l + 1, dtype=jnp.int32)
    rank_e = jnp.take_along_axis(jnp.cumsum(onehot_e, axis=0) - 1,
                                 re[:, None], axis=1)[:, 0]
    ok = (re < E_l) & (rank_e < C_exp)
    eslot = jnp.where(ok, re * C_exp + rank_e, E_l * C_exp)

    buf = jnp.zeros((E_l * C_exp, d), BF16)
    buf = buf.at[eslot].add(rx * ok[:, None], mode="drop")
    buf = buf.reshape(E_l, C_exp, d)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(BF16) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_l * C_exp, d)

    # ---- route results back ----------------------------------------------
    y_recv = y_buf[jnp.clip(eslot, 0, E_l * C_exp - 1)] * ok[:, None]
    y_send = jax.lax.all_to_all(y_recv.reshape(n_model, C_send, d),
                                MODEL_AXIS, split_axis=0, concat_axis=0,
                                tiled=False).reshape(n_model * C_send, d)
    vals = y_send[jnp.clip(slot, 0, n_model * C_send - 1)]
    vals = vals * (wk * keep).astype(BF16)[:, None]
    y = jnp.zeros((T, d), BF16).at[tok].add(vals)
    return y.reshape(b, S_l, d)


def moe_layer_ep(params, x, cfg, mesh, data_axes: tuple):
    """Expert-parallel MoE layer.  x [B,S,d] sharded over ``data_axes``."""
    topw, topi, aux = router_topk(params, x, cfg)
    a2a = cfg.moe_impl == "a2a" and x.shape[1] % mesh.shape[MODEL_AXIS] == 0
    if a2a:
        # tokens sequence-sharded over the model axis inside the layer
        tok_spec = P(data_axes, MODEL_AXIS, None)
        fn = functools.partial(_ep_a2a_shard, cfg=cfg,
                               n_model=mesh.shape[MODEL_AXIS],
                               data_axes=data_axes)
    else:
        tok_spec = P(data_axes, None, None)
        fn = functools.partial(_ep_shard, cfg=cfg,
                               n_model=mesh.shape[MODEL_AXIS],
                               data_axes=data_axes)
    y = shard_map(
        fn, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P(MODEL_AXIS, "data", None),
                  P(MODEL_AXIS, "data", None),
                  P(MODEL_AXIS, None, "data")),
        out_specs=tok_spec,
        **_SM_NOCHECK,
    )(x, topw.astype(x.dtype), topi,
      params["w_gate"], params["w_up"], params["w_down"])
    y = y.astype(x.dtype)

    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        y = y + mlp(params["shared"], x)
    return y, aux


def moe_layer_dense(params, x, cfg):
    """Dense oracle: run every expert on every token, combine by gate.
    O(E) compute — tiny configs/tests only."""
    topw, topi, aux = router_topk(params, x, cfg)
    gates = jnp.sum(jax.nn.one_hot(topi, cfg.n_experts, dtype=F32)
                    * topw[..., None], axis=-2)                # [B,S,E]
    xb = x.astype(BF16)
    g = jnp.einsum("bsd,edf->bsef", xb, params["w_gate"].astype(BF16))
    u = jnp.einsum("bsd,edf->bsef", xb, params["w_up"].astype(BF16))
    h = jax.nn.silu(g.astype(F32)).astype(BF16) * u
    y_e = jnp.einsum("bsef,efd->bsed", h, params["w_down"].astype(BF16))
    y = jnp.einsum("bsed,bse->bsd", y_e, gates.astype(BF16)).astype(x.dtype)
    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        y = y + mlp(params["shared"], x)
    return y, aux


def moe_layer(params, x, cfg, mesh=None, data_axes: tuple = ("data",)):
    if mesh is not None and cfg.n_experts % mesh.shape[MODEL_AXIS] == 0:
        return moe_layer_ep(params, x, cfg, mesh, data_axes)
    return moe_layer_dense(params, x, cfg)
