"""Mamba2 SSD (state-space duality, arXiv:2405.21060) — chunked form.

The sequence is split into chunks of length Q.  Within a chunk the quadratic
"attention-like" dual form runs on the MXU; across chunks a tiny recurrence
carries the SSM state h [B, H, P, N].  Decode is the O(1) recurrent update.

    h_t = a_t * h_{t-1} + dt_t * B_t ⊗ x_t          a_t = exp(-exp(A_log)*dt_t)
    y_t = C_t · h_t + D * x_t

``ssd_chunked_ref`` is the pure-jnp oracle; ``impl='pallas'`` routes the
intra-chunk quadratic term through ``repro/kernels/ssd_scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import BF16, F32, init_dense, rmsnorm


def init_ssm(key, cfg):
    ks = jax.random.split(key, 5)
    d, di, st, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_ch = di + 2 * st
    return {
        "in_proj": init_dense(ks[0], d, 2 * di + 2 * st + H),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch), F32) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), F32),
        "A_log": jnp.zeros((H,), F32),            # A = -exp(A_log) = -1
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.zeros((H,), F32),
        "norm_w": jnp.ones((di,), F32),
        "out_proj": init_dense(ks[2], di, d),
    }


def _split_proj(params, x, cfg):
    """in_proj -> gate z [.., di], conv channels (xs, B, C), dt [.., H]."""
    di, st, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = (x.astype(BF16) @ params["in_proj"].astype(BF16)).astype(F32)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * st]
    dt_raw = zxbcdt[..., di + di + 2 * st:]
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])
    return z, xBC, dt


def _causal_conv(params, xBC, cfg, conv_state=None):
    """Depthwise causal conv over the (xs|B|C) channels.

    train/prefill: conv_state None, pads with zeros on the left.
    decode: conv_state [B, W-1, ch] holds the trailing context; returns the
    rolled state.
    """
    W = cfg.conv_width
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
        ctx = jnp.concatenate([pad, xBC], axis=1)
        new_state = ctx[:, -(W - 1):]
    else:
        ctx = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
        new_state = ctx[:, -(W - 1):]
    out = sum(ctx[:, i:i + xBC.shape[1]] * params["conv_w"][i]
              for i in range(W))
    return jax.nn.silu(out + params["conv_b"]), new_state


def ssd_chunked_ref(xs, Bm, Cm, dt, A_log, Q: int, h0=None):
    """Chunked SSD.  xs [B,S,H,P], Bm/Cm [B,S,N], dt [B,S,H], A_log [H].

    Returns (y [B,S,H,P], h_final [B,H,P,N]).  Sequences not divisible by
    the chunk are zero-padded (dt=0 => decay 1, update 0: a no-op suffix).
    """
    B, S, H, Pd = xs.shape
    N = Bm.shape[-1]
    Q = min(Q, S)
    if S % Q:
        pad = Q - S % Q
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        y, h = ssd_chunked_ref(zpad(xs), zpad(Bm), zpad(Cm), zpad(dt),
                               A_log, Q, h0=h0)
        return y[:, :S], h
    Cn = S // Q

    a_log = -jnp.exp(A_log)[None, None] * dt                  # [B,S,H] (<=0)
    xs_c = xs.reshape(B, Cn, Q, H, Pd)
    B_c = Bm.reshape(B, Cn, Q, N)
    C_c = Cm.reshape(B, Cn, Q, N)
    dt_c = dt.reshape(B, Cn, Q, H)
    al_c = a_log.reshape(B, Cn, Q, H)
    cum = jnp.cumsum(al_c, axis=2)                            # [B,Cn,Q,H]

    # ---- intra-chunk quadratic (dual) term --------------------------------
    G = jnp.einsum("bcqn,bcsn->bcqs", C_c.astype(BF16), B_c.astype(BF16),
                   preferred_element_type=F32)                # [B,Cn,Q,Q]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,Cn,Q,S,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(decay), 0.0)                        # [B,Cn,Q,Q,H]
    M = G[..., None] * L * dt_c[:, :, None, :, :]             # [B,Cn,Q,Q,H]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M.astype(BF16),
                         xs_c.astype(BF16), preferred_element_type=F32)

    # ---- chunk states + inter-chunk recurrence ----------------------------
    total = cum[:, :, -1:, :]                                 # [B,Cn,1,H]
    w_state = jnp.exp(total - cum) * dt_c                     # [B,Cn,Q,H]
    S_c = jnp.einsum("bcsn,bcsh,bcshp->bchpn", B_c.astype(BF16),
                     w_state.astype(BF16), xs_c.astype(BF16),
                     preferred_element_type=F32)              # [B,Cn,H,P,N]
    chunk_decay = jnp.exp(total[:, :, 0, :])                  # [B,Cn,H]

    h_init = (jnp.zeros((B, H, Pd, N), F32) if h0 is None
              else h0.astype(F32))

    def body(h, inp):
        s_c, dec = inp                                        # [B,H,P,N],[B,H]
        h_next = dec[:, :, None, None] * h + s_c
        return h_next, h                                      # emit h_prev

    (h_fin, h_prevs) = jax.lax.scan(
        body, h_init,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # [B,Cn,H,P,N]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", C_c.astype(BF16),
                         jnp.exp(cum).astype(BF16), h_prevs.astype(BF16),
                         preferred_element_type=F32)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, h_fin


def ssm_block(params, x, cfg, mode: str = "train", state=None,
              impl: str = "xla"):
    """Full Mamba2 block.  state = (h [B,H,P,N], conv [B,W-1,ch]) for decode.

    Returns (out [B,S,d], new_state).
    """
    B, S, d = x.shape
    di, st, H, Pd = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                     cfg.ssm_head_dim)
    z, xBC, dt = _split_proj(params, x, cfg)

    h0 = conv_state = None
    if state is not None:
        h0, conv_state = state
    xBC, new_conv = _causal_conv(params, xBC, cfg, conv_state)
    xs = xBC[..., :di].reshape(B, S, H, Pd)
    Bm = xBC[..., di:di + st]
    Cm = xBC[..., di + st:]

    if mode == "decode" and S == 1:
        # O(1) recurrent step
        a = jnp.exp(-jnp.exp(params["A_log"])[None, None] * dt)  # [B,1,H]
        h = h0.astype(F32) if h0 is not None else jnp.zeros((B, H, Pd, st), F32)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0], xs[:, 0])
        h = a[:, 0, :, None, None] * h + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)[:, None]   # [B,1,H,P]
        h_fin = h
    else:
        if impl == "pallas":
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, h_fin = ssd_ops.ssd_chunked(xs, Bm, Cm, dt, params["A_log"],
                                           cfg.ssm_chunk, h0=h0)
        else:
            y, h_fin = ssd_chunked_ref(xs, Bm, Cm, dt, params["A_log"],
                                       cfg.ssm_chunk, h0=h0)

    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y.astype(x.dtype), params["norm_w"], cfg.norm_eps)
    out = (y.astype(BF16) @ params["out_proj"].astype(BF16)).astype(x.dtype)
    return out, (h_fin, new_conv)
