"""Model / shape configuration for the assigned architecture pool.

``ModelConfig`` is a frozen dataclass (hashable -> usable as a jit static
argument).  One exact instance per assigned architecture lives in
``repro/configs/<id>.py``; each also exposes a ``reduced()`` variant for CPU
smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0     # deepseek: leading layers use a dense MLP
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    d_inner: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (zamba2): shared attention block applied every k layers ---
    attn_every: int = 0
    # --- modality frontends (STUBS: input_specs provides embeddings) ---
    frontend: str = "none"   # none | patch_embeds | frame_embeds
    n_prefix: int = 0        # vlm: image-patch positions at sequence start
    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "xla"   # xla | pallas (TPU flash kernel)
    ssm_impl: str = "xla"    # xla | pallas
    logit_chunk: int = 512   # sequence chunk for the cross-entropy loss
    vocab_pad: int = 256
    # --- distribution strategy (hillclimbed; see EXPERIMENTS.md §Perf) ---
    # off:  activations replicated over the model axis outside TP regions
    # attn: shard the *sequence* over the model axis inside attention only
    #       (kills the S^2-logit replication when heads don't divide the
    #       model axis)
    # full: residual stream stays sequence-sharded between blocks
    #       (Megatron-SP: TP consumers all-gather fwd / reduce-scatter bwd
    #       instead of psum-ing full f32 cotangents)
    seq_parallel: str = "off"
    moe_impl: str = "psum"   # psum: token-replicated EP | a2a: all-to-all EP

    @property
    def vocab_padded(self) -> int:
        return _pad_to(self.vocab, self.vocab_pad)

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.d_inner else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch hold a 524k context (O(1)-ish state)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def n_attn_applications(self) -> int:
        """How many attention KV caches a decode step needs."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return self.n_layers // self.attn_every
        return self.n_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        n = 2 * self.vocab_padded * d            # embed + unembed
        if self.family in ("ssm", "hybrid"):
            di, st, H = self.d_inner, self.ssm_state, self.n_ssm_heads
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D + norms
            ssm_block = (d * (2 * di + 2 * st + H) + di * d
                         + self.conv_width * (di + 2 * st) + 2 * H + 2 * d)
            n += L * ssm_block
            if self.family == "hybrid":
                # one shared attention+MLP block (+ per-slot LN)
                n += 4 * d * d + 3 * d * self.d_ff + 2 * d
            return n
        if self.use_mla:
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads
                    * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * (self.n_heads * self.d_head) * 2 \
                + d * (self.n_kv_heads * self.d_head) * 2
        n += L * (attn + 2 * d)
        n_moe = L - self.first_dense if self.n_experts else 0
        n_dense = L - n_moe
        n += n_dense * 3 * d * self.d_ff
        if self.n_experts:
            per_expert = 3 * d * self.d_ff_expert
            n += n_moe * (self.n_experts * per_expert
                          + self.n_shared_experts * per_expert
                          + d * self.n_experts)  # router
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        n_moe = self.n_layers - self.first_dense
        per_expert = 3 * self.d_model * self.d_ff_expert
        inactive = n_moe * (self.n_experts - self.top_k) * per_expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Dry-run cell applicability (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: full quadratic attention at 524k context; "
                       "long_500k runs only for SSM/hybrid archs")
    return True, ""
