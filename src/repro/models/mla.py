"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV activations are compressed into a rank-``kv_lora_rank`` latent ``c_kv``
plus a single shared RoPE key ``k_rope``; the decode cache stores only
``[c_kv | k_rope]`` (576 dims/token for the 236B config) instead of
2 * n_heads * d_head.  Queries come from their own low-rank path.

Two execution modes:
* prefill/train — decompress c_kv to per-head K/V and run standard MHA;
* decode       — *absorbed* form: fold W_uk into the query and W_uv into the
  output projection so attention runs directly in the latent space (MQA-like:
  one 512-dim "value head" shared by all heads).  This is the paper's
  inference trick and is what makes the 32k/500k caches small.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import BF16, F32, NEG_INF, apply_rope, init_dense, rope_angles


def init_mla(key, cfg):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "w_dq": init_dense(ks[0], cfg.d_model, cfg.q_lora_rank),
        "w_uq": init_dense(ks[1], cfg.q_lora_rank, H * qk),
        "w_dkv": init_dense(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_dim),
        "w_uk": init_dense(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_dim),
        "w_uv": init_dense(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim),
        "wo": init_dense(ks[5], H * cfg.v_head_dim, cfg.d_model),
    }


def _queries(params, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (x.astype(BF16) @ params["w_dq"].astype(BF16)
         ) @ params["w_uq"].astype(BF16)
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    return q_nope, apply_rope(q_rope, cos, sin)


def _latent(params, x, cfg, positions):
    """c_kv [B,S,R] and rope'd shared key k_rope [B,S,dr]."""
    B, S, _ = x.shape
    R, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckr = x.astype(BF16) @ params["w_dkv"].astype(BF16)
    c_kv, k_rope = ckr[..., :R], ckr[..., R:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_prefill(params, x, cfg, positions, impl="xla", mesh=None,
                dp=("data",)):
    """Standard (decompressed) MHA over the latent KV; returns latent cache.

    q/k/v are explicitly pinned head-sharded over the model axis: without
    the constraint, a sequence-sharded residual stream makes GSPMD
    replicate heads and shuttle full [B,H,S,dk] tensors between S- and
    H-sharded layouts (8 GiB all-to-alls observed; EXPERIMENTS.md §Perf).
    """
    from repro.models.layers import attention

    B, S, _ = x.shape
    H, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_kv, k_rope = _latent(params, x, cfg, positions)

    k_nope = (c_kv @ params["w_uk"].astype(BF16)).reshape(B, S, H, dn)
    v = (c_kv @ params["w_uv"].astype(BF16)).reshape(B, S, H, dv)
    # shared rope key broadcast to all heads; fold into one attention call
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)

    if mesh is not None and mesh.size > 1:
        msize = dict(mesh.shape).get("model", 1)
        if msize > 1 and H % msize == 0:
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = NamedSharding(mesh, P(dp, None, "model", None))
            q = jax.lax.with_sharding_constraint(q, spec)
            k = jax.lax.with_sharding_constraint(k, spec)
            v = jax.lax.with_sharding_constraint(v, spec)

    scale = 1.0 / math.sqrt(dn + cfg.qk_rope_dim)
    out = attention(q, k, v, impl=impl, causal=True, scale=scale)
    out = out.reshape(B, S, H * dv).astype(BF16)
    if mesh is not None and mesh.size > 1:
        msize = dict(mesh.shape).get("model", 1)
        if msize > 1 and H % msize == 0:
            from jax.sharding import NamedSharding, PartitionSpec as P
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, P(dp, None, "model")))
    return ((out @ params["wo"].astype(BF16)).astype(x.dtype),
            (c_kv, k_rope))


def mla_decode(params, x, cfg, positions, cache, cache_len):
    """Absorbed-matrix decode: attention directly over the latent cache.

    cache = (c_kv [B,T,R], k_rope [B,T,dr]); scores
        q_nope W_uk^T c_kv  +  q_rope k_rope
    and values are the latent itself, expanded once after the weighted sum.
    """
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    R = cfg.kv_lora_rank

    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_new, kr_new = _latent(params, x, cfg, positions)
    c_cache, kr_cache = cache
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        c_cache, c_new.astype(c_cache.dtype), cache_len, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, kr_new.astype(kr_cache.dtype), cache_len, axis=1)

    # absorb W_uk into q:  q_lat [B,S,H,R]
    w_uk = params["w_uk"].astype(BF16).reshape(R, H, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)

    scale = 1.0 / math.sqrt(dn + dr)
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, c_cache.astype(BF16),
                         preferred_element_type=F32)
              + jnp.einsum("bshd,btd->bhst", q_rope, kr_cache.astype(BF16),
                           preferred_element_type=F32)) * scale
    T = c_cache.shape[1]
    valid = jnp.arange(T)[None, :] < (cache_len + S)
    logits = jnp.where(valid[None, None], logits, NEG_INF)
    att = jax.nn.softmax(logits, axis=-1)

    # weighted latent sum, then expand through W_uv (absorbed output)
    o_lat = jnp.einsum("bhst,btr->bshr", att.astype(BF16),
                       c_cache.astype(BF16))          # [B,S,H,R]
    w_uv = params["w_uv"].astype(BF16).reshape(R, H, dv)
    out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)
    out = out.reshape(B, S, H * dv)
    return ((out @ params["wo"].astype(BF16)).astype(x.dtype),
            (c_cache, kr_cache))
