"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Policy (DESIGN.md §5):
* ``model`` axis carries tensor parallelism (attention heads, d_ff, experts,
  vocab) whenever the dimension divides evenly; otherwise that tensor falls
  back to FSDP-only storage sharding.
* ``data`` axis carries FSDP (parameters + optimizer states sharded on their
  largest non-TP dim) and the batch.
* ``pod`` axis (multi-pod mesh) is pure data parallelism: parameters are
  replicated across pods, so the only cross-pod (DCN) traffic is the gradient
  all-reduce — batch specs use ``(("pod", "data"), ...)``.

Everything is divisibility-checked against the actual mesh, so the same code
serves the 16x16 production mesh and the 1-device CPU smoke mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.axis_names else 0


def _ok(mesh: Mesh, dim: int, axis) -> Any:
    """axis if ``dim`` divides evenly over it on this mesh, else None."""
    n = _axis_size(mesh, axis)
    return axis if n and dim % n == 0 and dim >= n else None


def param_specs(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree mirroring ``params`` (works on ShapeDtypeStructs)."""

    def leaf_spec(path: Tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1]
        stacked = ("blocks" in names or "first_blocks" in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        pre = (None,) if stacked else ()

        def spec(*axes):
            out = []
            for dim, ax in zip(shape, axes):
                out.append(_ok(mesh, dim, ax) if ax else None)
            return P(*pre, *out)

        if name in ("ln1", "ln2", "final_norm", "norm_w", "A_log", "D",
                    "dt_bias", "conv_b", "bq", "bk", "bv"):
            return P(*pre, *([None] * len(shape)))
        if name == "embed":
            return spec("model", "data")
        if name == "unembed":
            return spec("data", "model")
        if name == "conv_w":
            return P(*pre, None, None)
        if name == "router":
            return spec("data", None)
        if name in ("w_gate", "w_up"):
            if len(shape) == 3:                      # experts [E, d, f]
                return spec("model", "data", None)
            return spec("data", "model")             # dense MLP [d, ff]
        if name == "w_down":
            if len(shape) == 3:                      # experts [E, f, d]
                return spec("model", None, "data")
            return spec("model", "data")             # dense MLP [ff, d]
        if name in ("wq", "wk", "wv"):
            return spec("data", "model")
        if name == "wo":
            return spec("model", "data")
        if name in ("w_dq", "w_dkv"):
            return spec("data", "model")
        if name in ("w_uq", "w_uk", "w_uv"):
            return spec("data", "model")
        if name == "in_proj":
            return spec("data", "model")
        if name == "out_proj":
            return spec("model", "data")
        return P(*pre, *([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Dict[str, P]:
    """PartitionSpecs for every input the shape's step consumes."""
    dp = data_axes(mesh)
    B = shape.global_batch
    bspec = _ok(mesh, B, dp) or _ok(mesh, B, "data")
    out: Dict[str, P] = {}
    if cfg.frontend == "patch_embeds":
        out["patch_embeds"] = P(bspec, None, None)
        out["tokens"] = P(bspec, None)
        out["labels"] = P(bspec, None)
    elif cfg.frontend == "frame_embeds":
        out["frame_embeds"] = P(bspec, None, None)
        out["labels"] = P(bspec, None)
    else:
        out["tokens"] = P(bspec, None)
        out["labels"] = P(bspec, None)
    if shape.kind == "decode":
        out = {"tokens": P(bspec, None)}
    return out


def cache_specs(cfg: ModelConfig, cache: Any, mesh: Mesh,
                batch_size: int) -> Any:
    """Decode-cache specs: batch over data axes; heads over ``model`` when
    divisible, else the time axis over ``model`` (flash-decoding style)."""
    dp = data_axes(mesh)
    bax = _ok(mesh, batch_size, dp) or _ok(mesh, batch_size, "data")

    def leaf_spec(path: Tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        shape = leaf.shape
        if "ssm" in names:
            if len(shape) == 5:      # h [L, B, H, P, N]
                return P(None, bax, _ok(mesh, shape[2], "model"), None, None)
            return P(None, bax, None, None)       # conv [L, B, W-1, ch]
        # attention caches: [n, B, T, Hkv, dh] or MLA [n, B, T, R]
        if len(shape) == 5:
            hax = _ok(mesh, shape[3], "model")
            tax = None if hax else _ok(mesh, shape[2], "model")
            return P(None, bax, tax, hax, None)
        if len(shape) == 4:          # MLA latent [n, B, T, R]
            return P(None, bax, _ok(mesh, shape[2], "model"), None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that no-ops on a 1-device CPU mesh."""
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
