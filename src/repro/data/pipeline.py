"""Deterministic sharded data pipeline.

Every batch is a pure function of (seed, step) — after a failure the
restored step index replays the exact same batches, which is what makes
checkpoint/restart bitwise-reproducible (distributed/fault.py relies on
this).  Two sources:

* ``SyntheticLM``   — deterministic zipf-ish token stream (benchmarks/tests);
* ``FileDataset``   — memory-mapped token file with per-step strided reads.

Batches come out as numpy; the launcher device_puts them against the batch
shardings (on multi-host this is ``jax.make_array_from_process_local_data``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    frontend: str = "none"     # none | patch_embeds | frame_embeds
    n_prefix: int = 0
    d_model: int = 0


class SyntheticLM:
    """Zipf-distributed tokens; labels = next-token shift."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        if cfg.frontend == "patch_embeds":
            s_text = S - cfg.n_prefix
            toks = self._tokens(rng, B, s_text + 1)
            return {
                "patch_embeds": rng.standard_normal(
                    (B, cfg.n_prefix, cfg.d_model)).astype(np.float32),
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
            }
        if cfg.frontend == "frame_embeds":
            toks = self._tokens(rng, B, S + 1)
            return {
                "frame_embeds": rng.standard_normal(
                    (B, S, cfg.d_model)).astype(np.float32),
                "labels": toks[:, 1:],
            }
        toks = self._tokens(rng, B, S + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _tokens(self, rng, B, S):
        z = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        return np.clip(z - 1, 0, self.cfg.vocab - 1).astype(np.int32)

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class FileDataset:
    """Flat binary token file (int32), strided deterministic batches."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        idx = rng.integers(0, self.n_windows, size=cfg.global_batch)
        starts = idx * cfg.seq_len
        rows = np.stack([self.tokens[s:s + cfg.seq_len + 1] for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def make_dataset(cfg: DataConfig, path: Optional[str] = None):
    return FileDataset(path, cfg) if path else SyntheticLM(cfg)
