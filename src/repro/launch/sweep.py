"""Sweep driver: policy x scenario x seed in ONE compiled program.

The paper's headline use case is comparing scheduling strategies under
varying network conditions (Figs 4-10).  With policies as weight vectors
and runtime parameters as data (``PolicyParams``/``RunParams``), the whole
evaluation grid is one ``vmap`` over ONE flattened batch axis of P*S*N
cells, jitted exactly once — and that single axis is sharded across every
available device with a ``NamedSharding`` (each device integrates its
slice of cells independently; there is no cross-cell communication):

    policies [P] --+
    scenarios [S] --+--> flatten [P*S*N] --vmap--> jit --> [P, S, N]
    seeds     [N] --+         |
                              +-- NamedSharding over the 'grid' mesh axis

    PYTHONPATH=src python -m repro.launch.sweep --policies all \\
        --seeds 2 --horizon 120 --table avg_runtime --out sweep.json
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import (SimConfig, get_policy, list_policies,
                        sweep_summaries, sweep_table)
from repro.core import stats
from repro.core.engine import (resolve_plan, simulate, simulate_chunk,
                               simulate_telescoped)
from repro.core.scenario import (ScenarioSpec, build_scenarios,
                                 default_scenarios)
from repro.core.scheduling import validate_weights
from repro.core.types import (ExecPlan, OnlineSummary, PolicyParams,
                              RunParams, SimState, TickMetrics)
from repro.launch.execargs import add_exec_args
from repro.launch.mesh import compat_mesh

I32 = jnp.int32

# SimState leaves that are TOPOLOGY, not state: identical across every
# sweep cell by construction (build_scenarios builds one network and every
# host mix assigns leaves as arange % n_leaf; a ScenarioSpec cannot vary
# topology).  They stay UNBATCHED through the grid vmap (in_axes=None):
# the delay-refresh and ECMP-path gathers then keep unbatched *indices*,
# which XLA:CPU lowers on its fast path — batching the index operand of a
# gather was measured at 2.6x per cell on the periodic refresh alone.
STATIC_TOPOLOGY_LEAVES = frozenset({
    ("hosts", "leaf"),
    ("net", "link_u"), ("net", "link_v"),
    ("net", "path_links"), ("net", "path_nlinks"),
})


def _leaf_path_names(path) -> tuple:
    return tuple(p.name for p in path if hasattr(p, "name"))


def _is_static_leaf(path) -> bool:
    names = _leaf_path_names(path)
    return any(names[-len(s):] == s for s in STATIC_TOPOLOGY_LEAVES)


def stack_policies(names_or_params: Sequence) -> PolicyParams:
    """[P]-batched PolicyParams from registered names (or ready-made
    ``PolicyParams``).  Validates every vector against the canonical weight
    length up front — a ragged batch would fail deep inside a trace."""
    pols = [p if isinstance(p, PolicyParams) else get_policy(p)
            for p in names_or_params]
    for i, p in enumerate(pols):
        validate_weights(p.weights, f"stack_policies entry {i}: ")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pols)


def grid_mesh(devices=None) -> Mesh | None:
    """1-axis device mesh for the flattened sweep batch.

    ``devices``: None = all addressable devices, an int = that many, or an
    explicit device sequence.  Returns None for a single device — the
    unsharded sweep needs no mesh at all.  Defaults to
    ``jax.local_devices()`` (not ``jax.devices()``): under
    ``jax.distributed`` the global list contains other processes'
    non-addressable devices, and the sweep fabric's cross-host story is
    slab-per-process with a host-side reduction (``repro.launch.dist``),
    never a global-SPMD program.  Built through ``mesh.compat_mesh`` —
    the repo's one AxisType-compat mesh constructor.
    """
    if devices is None:
        devices = jax.local_devices()
    elif isinstance(devices, int):
        devices = jax.local_devices()[:devices]
    devices = list(devices)
    if len(devices) <= 1:
        return None
    return compat_mesh((len(devices),), ("grid",), devices=devices)


def make_sweep_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                  devices=None):
    """The compiled sweep: (sims [S,N], policies [P], params [S]) ->
    (finals, metrics) with [P, S, N] leading axes.

    One jit over the SAME ``engine.simulate`` trace standalone ``run_sim``
    jits — so each cell is bit-for-bit a standalone run, and the whole grid
    costs exactly one XLA compilation (asserted in ``tests/test_sweep.py``
    via the jit cache-miss counter).

    The grid rides ONE ``vmap``: the three axes are broadcast and
    flattened to a single [P*S*N] batch inside the jitted function
    (branch-free scoring makes the policy axis pure data like the others —
    no ``lax.switch`` evaluating every branch per cell).  With more than
    one device the flattened axis carries a ``NamedSharding`` over the
    1-axis ``grid`` mesh, padded to a device multiple by repeating cells
    (the pad cells are sliced off before reshaping back to [P, S, N]);
    cells are independent, so sharded == unsharded bit-for-bit
    (``tests/test_sweep_sharded.py``).
    """
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    grid = _make_grid(cfg, n_hosts, n_nodes, horizon, mesh, n_dev)
    jitted = jax.jit(grid)

    def fn(sims, pols, rps):
        _check_topology_uniform(sims)
        return jitted(sims, pols, rps)

    fn._cache_size = jitted._cache_size
    fn.n_devices = n_dev
    return fn


def _make_grid(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
               mesh, n_dev: int):
    """The un-jitted [P, S, N]-grid function both ``make_sweep_fn`` (jit)
    and ``make_grad_fn`` (jit of ``value_and_grad`` through it) trace —
    one definition, so the differentiated sweep IS the stacked sweep."""
    jtu = jax.tree_util

    def cell(sim: SimState, pol: PolicyParams, rp: RunParams):
        return simulate(sim, cfg, pol, n_hosts, n_nodes, horizon, rp)

    def grid(sims, pols, rps):
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N

        def flat(x, bshape):                     # bshape -> [B, ...]
            shape = (P, S, N) + x.shape[len(bshape):]
            x = x.reshape(tuple(d if ax in bshape else 1
                                for ax, d in zip("PSN", (P, S, N)))
                          + x.shape[len(bshape):])
            return jnp.broadcast_to(x, shape).reshape((B,) + shape[3:])

        args = (jax.tree.map(lambda x: flat(x, "SN"), sims),
                jax.tree.map(lambda x: flat(x, "P"), pols),
                jax.tree.map(lambda x: flat(x, "S"), rps))
        # Pad to a device multiple by repeating cells round-robin.  The pad
        # cells RECOMPUTE real cells and their results are sliced off —
        # deliberate waste: under vmap+SPMD every lane executes the same
        # ops regardless of data, so "masking" a pad cell's workload to
        # near-zero saves nothing, while zeroed/degenerate states would
        # fork the tick's branches.  The measured cost is the pad fraction
        # itself (<= (n_dev-1)/B of the grid; numbers in docs/sweeps.md).
        pad = (-B) % n_dev
        if pad:
            idx = jnp.arange(B + pad) % B
            args = jax.tree.map(lambda x: x[idx], args)
        if mesh is not None:
            args = jax.lax.with_sharding_constraint(
                args, NamedSharding(mesh, PartitionSpec("grid")))
        # de-batch the topology leaves (every cell carries the same
        # tables; uniformity is checked host-side in fn below) and build
        # the matching in_axes tree: 0 everywhere, None at the statics.
        flat_sims, treedef = jtu.tree_flatten_with_path(args[0])
        sim_arg = jtu.tree_unflatten(
            treedef, [x[0] if _is_static_leaf(p) else x
                      for p, x in flat_sims])
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0
                      for p, x in flat_sims])
        out = jax.vmap(cell, in_axes=(sim_axes, 0, 0))(
            sim_arg, args[1], args[2])
        if pad:
            out = jax.tree.map(lambda x: x[:B], out)
        return jax.tree.map(
            lambda x: x.reshape((P, S, N) + x.shape[1:]), out)

    return grid


def _check_topology_uniform(sims) -> None:
    """Every cell of one grid must share the network topology — the static
    leaves are de-batched through the vmap (``STATIC_TOPOLOGY_LEAVES``)."""
    for p, x in jax.tree_util.tree_flatten_with_path(sims)[0]:
        if _is_static_leaf(p):
            x = np.asarray(x)
            ref = x.reshape((-1,) + x.shape[2:])[0]
            if not (x == ref).all():
                names = ".".join(_leaf_path_names(p))
                raise ValueError(
                    f"sweep cells disagree on topology leaf {names!r}; "
                    "all scenarios of one grid must share the network "
                    "topology (build_scenarios builds exactly one)")


def make_grad_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                 objective: str = "soft_blend", chunk: int | None = None,
                 devices=None):
    """The differentiated sweep: ``fn(sims, pols, rps) -> (obj [P],
    grad [P, NUM_POLICY_WEIGHTS])`` — the per-policy mean surrogate
    objective over the [S, N] scenario/seed cells, and its gradient in
    ``PolicyParams.weights`` (docs/autodiff.md).

    Requires ``cfg.soft_placement``: the objective is the softmax
    expected-cost surrogate accumulated by the soft admit/migration
    rounds (``stats.soft_objective``); the simulated dynamics stay the
    hard argmin, so gradients flow through the per-decision score rows.
    Almost every state-mediated path crosses an integer decision and
    carries exact zero cotangent — the one exception is the periodic
    delay refresh, which bakes ``weights[util]``/``weights[cross_leaf]``
    into the persistent ``net.comm_cost`` cache (a continuous w -> state
    path, docs/autodiff.md).

    ``chunk=None`` differentiates the SAME grid function ``make_sweep_fn``
    jits — one ``jax.jit(value_and_grad(...))`` over the whole stacked
    grid, weights riding the policy batch axis, sharded over ``devices``
    exactly like the forward sweep.  A ``chunk`` streams the horizon
    instead (the ``make_stream_fn`` regime): a host loop drives ONE jitted
    ``value_and_grad`` chunk step (+ one tail compile when ``chunk`` does
    not divide ``horizon`` — never more, asserted in
    ``tests/test_autodiff.py``) whose value is the chunk's surrogate
    NUMERATOR sum; per-cell numerator gradients are summed host-side in
    f64 and scaled by the final count denominator (piecewise-constant in
    the weights, so this is the exact objective gradient), memory
    O(cells x state) at any horizon.  Values match the stacked path at
    any chunk size; gradients match to f32 summation order EXCEPT the
    comm_cost-carried ``util``/``cross_leaf`` components, which are
    truncated-BPTT at chunk boundaries that land while decisions are
    still being made (boundaries past the admit window see no truncation
    — pinned exactly in ``tests/test_autodiff.py``).
    """
    if not cfg.soft_placement:
        raise ValueError(
            "make_grad_fn requires cfg.soft_placement=True — with it off "
            "the surrogate sums are constant 0.0 and every gradient "
            "vanishes identically")
    if objective not in stats.SOFT_OBJECTIVES:
        raise KeyError(f"unknown soft objective {objective!r}; known: "
                       f"{list(stats.SOFT_OBJECTIVES)}")
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    jtu = jax.tree_util

    if chunk is None:
        grid = _make_grid(cfg, n_hosts, n_nodes, horizon, mesh, n_dev)

        def value(w, sims, rps):
            _, metrics = grid(sims, PolicyParams(weights=w), rps)
            num, den = stats.soft_num_den(metrics, objective)
            per_pol = (num / jnp.maximum(den, 1.0)).mean(axis=(1, 2))
            # policies are independent cells: d(sum)/dw is the [P, W]
            # per-policy gradient stack, no cross terms
            return per_pol.sum(), per_pol

        vg = jax.jit(jax.value_and_grad(value, has_aux=True))

        def fn(sims, pols, rps):
            _check_topology_uniform(sims)
            (_, per_pol), g = vg(pols.weights, sims, rps)
            return per_pol, g

        fn._cache_size = vg._cache_size
        fn.n_devices = n_dev
        return fn

    stats.check_chunk(chunk, cfg.n_containers)

    def gstep(w, sims, accs, rps, t0, csz):
        flat, treedef = jtu.tree_flatten_with_path(sims)
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0 for p, _ in flat])

        def chunk_num(w):
            def cell(sim, acc, pol, rp):
                return simulate_chunk(sim, acc, t0, cfg, pol, n_hosts,
                                      n_nodes, csz, rp)
            sims2, accs2 = jax.vmap(
                cell, in_axes=(sim_axes, 0, 0, 0),
                out_axes=(sim_axes, 0))(sims, accs,
                                        PolicyParams(weights=w), rps)
            num, _ = stats.soft_num_den(accs2, objective)   # [B]
            return num.sum(), (sims2, accs2)

        (_, (sims2, accs2)), g = jax.value_and_grad(
            chunk_num, has_aux=True)(w)
        return sims2, accs2, g

    jstep = jax.jit(gstep, static_argnames=("csz",))

    def fn(sims, pols, rps):
        _check_topology_uniform(sims)
        P, W = pols.weights.shape
        S, N = sims.t.shape
        B = P * S * N
        idx = np.arange(B)
        p_i, s_i, n_i = idx // (S * N), (idx // N) % S, idx % N
        flat_sims, sims_def = jtu.tree_flatten_with_path(sims)
        sim_flat = jtu.tree_unflatten(
            sims_def, [x[0, 0] if _is_static_leaf(p) else x[s_i, n_i]
                       for p, x in flat_sims])
        w = pols.weights[p_i]                               # [B, W]
        rp_flat = jax.tree.map(lambda x: x[s_i], rps)
        online = stats.online_init((B,))
        gnum = np.zeros((B, W), np.float64)
        t0 = 0
        while t0 < horizon:
            sz = min(chunk, horizon - t0)
            accs = jax.tree.map(lambda x: jnp.zeros((B,), x.dtype),
                                stats.acc_init())
            sim_flat, accs, g = jstep(w, sim_flat, accs, rp_flat,
                                      jnp.asarray(t0, I32), csz=sz)
            online = stats.online_fold(online, accs)
            gnum += np.asarray(g, np.float64)
            t0 += sz
        num, den = stats.soft_num_den(online, objective)
        den = np.maximum(den, 1.0)
        obj = (num / den).reshape(P, S * N)
        gobj = (gnum / den[:, None]).reshape(P, S * N, W)
        return (jnp.asarray(obj.mean(axis=1), jnp.float32),
                jnp.asarray(gobj.mean(axis=1), jnp.float32))

    fn._cache_size = jstep._cache_size
    fn.n_devices = 1          # chunked grads run unsharded (single process)
    return fn


def make_stream_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                   chunk: int, slab: int | None = None, devices=None,
                   overlap: bool = True, telescope: bool = False):
    """The streaming sweep: the same [P, S, N] grid as ``make_sweep_fn``,
    but iterated in device-multiple SLABS of cells through ONE compiled
    slab-chunk step, with per-tick metrics folded into ``SummaryAcc``
    carries instead of stacked — so peak memory is O(slab x state), never
    O(cells x horizon).

    Returns ``fn(sims, pols, rps) -> (finals, summary)`` where ``finals``
    has [P, S, N] leading axes (numpy; bit-for-bit the stacked sweep's
    finals) and ``summary`` is a [P, S, N] ``stats.OnlineSummary``.

    Chunking the horizon and slabbing the grid compose in one loop nest:

        for each slab of cells:                # wrap-padded start offsets
            enqueue every chunk step           # ONE jitted function, async
            gather the PREVIOUS slab's finals + accs   # one device_get
            fold its accs into the host f64/i64 summary

    The jitted step is compiled once for the main chunk size (+ one tail
    compile when ``chunk`` does not divide ``horizon``): ``t0`` is traced,
    the per-cell link-param application rides a ``t0 == 0`` cond, and the
    static topology leaves stay unbatched through the vmap in BOTH
    directions (``in_axes``/``out_axes`` None) so every slab re-enters the
    same compiled program.  On non-CPU backends the (state, accumulator)
    carry is donated, so a slab's device footprint never doubles.

    The driver is OVERLAPPED (PR 8): jax dispatch is asynchronous, so the
    loop never blocks between chunks — per-chunk accumulators are kept as
    device arrays and the whole slab (every finals leaf + every chunk's
    ``SummaryAcc``) comes back in ONE batched ``jax.device_get``, issued
    only after the NEXT slab's steps are already enqueued
    (``overlap=True``).  The host-side fold and slice-write of slab *k*
    then runs while the device integrates slab *k+1*; peak footprint is
    two slabs (the in-flight one plus the one being gathered).
    ``overlap=False`` keeps the gather synchronous (slab *k* is fetched
    before slab *k+1* is touched) — the PR 7 behavior, minus its per-leaf
    ``np.asarray`` and per-chunk host-fold stalls, kept as the bench
    comparison arm.

    ``fn.iter_slabs(sims, pols, rps, slab_starts)`` exposes the runner
    itself — a generator of ``(s0, finals_leaves, slab_summary)`` per
    start offset — so the distributed launcher (``repro.launch.dist``)
    can drive the SAME compiled step from a coordinator-fed slab queue
    instead of ``range(0, B, Bs)``.
    """
    stats.check_chunk(chunk, cfg.n_containers)
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    jtu = jax.tree_util
    # the telescoped cell is signature-identical to simulate_chunk — the
    # macro-tick engine slots into the SAME slab/chunk/overlap machinery,
    # each vmapped lane telescoping independently (per-cell dt; the inner
    # while_loop runs until every lane's horizon, select-masked per lane)
    cell_fn = simulate_telescoped if telescope else simulate_chunk

    def step(sims, accs, pols, rps, t0, csz):
        if mesh is not None:
            spec = NamedSharding(mesh, PartitionSpec("grid"))
            shard = lambda x: jax.lax.with_sharding_constraint(x, spec)
            flat, treedef = jtu.tree_flatten_with_path(sims)
            sims = jtu.tree_unflatten(
                treedef, [x if _is_static_leaf(p) else shard(x)
                          for p, x in flat])
            accs, pols, rps = jax.tree.map(shard, (accs, pols, rps))

        def cell(sim, acc, pol, rp):
            return cell_fn(sim, acc, t0, cfg, pol, n_hosts, n_nodes,
                           csz, rp)

        flat, treedef = jtu.tree_flatten_with_path(sims)
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0 for p, _ in flat])
        return jax.vmap(cell, in_axes=(sim_axes, 0, 0, 0),
                        out_axes=(sim_axes, 0))(sims, accs, pols, rps)

    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    jstep = jax.jit(step, static_argnames=("csz",), donate_argnums=donate)

    def slab_cells(B: int) -> int:
        """Wrap-padded device-multiple slab size for a B-cell grid."""
        Bs = B if slab is None else min(slab, B)
        return Bs + (-Bs) % n_dev

    def iter_slabs(sims, pols, rps, slab_starts):
        """Run the wrap-padded slab at each start offset; yield
        ``(s0, finals_leaves, slab_summary)`` — finals as host numpy per
        flattened ``SimState`` leaf (statics de-batched), summary a [Bs]
        ``OnlineSummary``.  ``slab_starts`` may be any iterable (a lazy
        coordinator queue included); each start owns cells
        ``(s0 + arange(Bs)) % B`` of which the first ``min(Bs, B - s0)``
        are real."""
        _check_topology_uniform(sims)
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N
        Bs = slab_cells(B)
        flat_sims, sims_def = jtu.tree_flatten_with_path(sims)
        statics = {i for i, (p, _) in enumerate(flat_sims)
                   if _is_static_leaf(p)}
        if mesh is not None:
            # pre-place slab inputs in their final layout: the FIRST jstep
            # call then compiles for grid-sharded carries, the same
            # signature every later chunk re-enters — without this the
            # unsharded first call costs a third compilation per process
            gspec = NamedSharding(mesh, PartitionSpec("grid"))
            repl = NamedSharding(mesh, PartitionSpec())
            place = lambda x, s: jax.device_put(x, s)
        else:
            gspec = repl = None
            place = lambda x, s: x
        zero_accs = lambda: jax.tree.map(
            lambda x: place(jnp.zeros((Bs,), x.dtype), gspec),
            stats.acc_init())

        def enqueue(s0):
            idx = (s0 + np.arange(Bs)) % B       # wrap-pad the last slab
            p_i, s_i, n_i = idx // (S * N), (idx // N) % S, idx % N
            sim_slab = jtu.tree_unflatten(
                sims_def, [place(x[0, 0], repl) if i in statics
                           else place(x[s_i, n_i], gspec)
                           for i, (_, x) in enumerate(flat_sims)])
            pol_slab = jax.tree.map(lambda x: place(x[p_i], gspec), pols)
            rp_slab = jax.tree.map(lambda x: place(x[s_i], gspec), rps)
            accs = []
            t0 = 0
            while t0 < horizon:
                sz = min(chunk, horizon - t0)    # tail: one extra compile
                # the accumulator RESETS every chunk (the i32 bound and the
                # f32 precision argument are per-chunk properties); the
                # host fold in finish() carries the running 64-bit totals
                sim_slab, acc = jstep(sim_slab, zero_accs(), pol_slab,
                                      rp_slab, jnp.asarray(t0, I32),
                                      csz=sz)
                accs.append(acc)
                t0 += sz
            return s0, sim_slab, accs

        def finish(pend):
            s0, sim_slab, accs = pend
            # ONE host transfer for the whole slab: every finals leaf and
            # every chunk's SummaryAcc in a single batched device_get
            # (PR 7 issued one blocking np.asarray per leaf per slab plus
            # one per-chunk sync inside the fold loop)
            host_leaves, host_accs = jax.device_get(
                (jtu.tree_leaves(sim_slab), accs))
            slab_sum = stats.online_init((Bs,))
            for a in host_accs:
                slab_sum = stats.online_fold(slab_sum, a)
            return s0, host_leaves, slab_sum

        pending = None
        for s0 in slab_starts:
            cur = enqueue(s0)                    # async: nothing blocks yet
            if not overlap:
                yield finish(cur)
                continue
            if pending is not None:              # gather k AFTER k+1 is in
                yield finish(pending)
            pending = cur
        if pending is not None:
            yield finish(pending)

    def fn(sims, pols, rps):
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N
        Bs = slab_cells(B)
        flat_sims, sims_def = jtu.tree_flatten_with_path(sims)
        statics = {i for i, (p, _) in enumerate(flat_sims)
                   if _is_static_leaf(p)}
        summary = stats.online_init((B,))
        finals_flat = None                       # host [B, ...] per leaf
        for s0, host_slab, slab_sum in iter_slabs(sims, pols, rps,
                                                  range(0, B, Bs)):
            real = min(Bs, B - s0)               # wrap rows are duplicates
            if finals_flat is None:
                finals_flat = [
                    x if i in statics
                    else np.empty((B,) + x.shape[1:], x.dtype)
                    for i, x in enumerate(host_slab)]
            for i, x in enumerate(host_slab):
                if i not in statics:
                    finals_flat[i][s0:s0 + real] = x[:real]
            for h, a in zip(summary, slab_sum):
                h[s0:s0 + real] = a[:real]

        leaves = [np.broadcast_to(x, (P, S, N) + x.shape).copy()
                  if i in statics               # restore the batched shape
                  else x.reshape((P, S, N) + x.shape[1:])
                  for i, x in enumerate(finals_flat)]
        finals = jtu.tree_unflatten(sims_def, leaves)
        summary = OnlineSummary(*(x.reshape((P, S, N)) for x in summary))
        return finals, summary

    fn._cache_size = jstep._cache_size
    fn.n_devices = n_dev
    fn.iter_slabs = iter_slabs
    fn.slab_cells = slab_cells
    return fn


@dataclasses.dataclass
class SweepResult:
    policies: list[str]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    finals: SimState          # [P, S, N, ...]
    metrics: TickMetrics | None   # [P, S, N, T, ...]; None when streamed
    wall_s: float
    compile_cache_misses: int  # jit cache entries the sweep call created
    n_devices: int = 1         # devices the flattened grid axis spans
    summary: OnlineSummary | None = None  # [P, S, N] streaming fold
    worker_meta: list | None = None  # per-process slabs/walls (launch.dist)
    _rows: list | None = dataclasses.field(default=None, repr=False)

    def summaries(self) -> list[dict[str, Any]]:
        if self._rows is None:  # per-cell summarize is host-side O(cells)
            self._rows = sweep_summaries(
                self.finals,
                self.metrics if self.metrics is not None else self.summary,
                self.policies,
                [s.name for s in self.scenarios], self.seeds)
        return self._rows

    def table(self, value: str = "avg_runtime") -> str:
        return sweep_table(self.summaries(), value=value)


def run_sweep(policies: Sequence[str] | None = None,
              scenarios: Sequence[ScenarioSpec] | None = None,
              seeds: Sequence[int] = (0,), cfg: SimConfig | None = None,
              n_hosts: int = 20, n_spine: int = 2,
              n_leaf: int = 4, devices=None, chunk: int | None = None,
              slab: int | None = None, overlap: bool | None = None,
              plan: ExecPlan | None = None) -> SweepResult:
    """Build the grid and run it as one compiled call.

    Execution options ride in ``plan`` (:class:`~repro.core.types.ExecPlan`
    — the bare ``devices``/``chunk``/``slab``/``overlap`` kwargs are
    deprecated, one cycle).  ``plan.devices`` shards the flattened grid
    (default: every local device).  A ``plan.chunk`` switches to the
    STREAMING sweep (``make_stream_fn``): the horizon runs in chunks with
    online summary folds and the grid is iterated in slabs of
    ``plan.slab`` cells (default: the whole grid) through one compiled
    step — [P, S, N] summaries without ever holding [P, S, N, T] metrics.
    Cell results are bit-identical either way.  ``plan.overlap``
    (streaming only) gathers each slab's results one slab behind the
    dispatch so host transfers hide under device compute.
    ``plan.telescope`` swaps the streaming cell for the macro-tick engine
    (``engine.simulate_telescoped``, docs/events.md): each lane advances
    dt >= 1 ticks per step over quiescent intervals with closed-form
    summary folds — finals stay bit-identical, summaries exact to the
    documented fold precision; without a ``plan.chunk`` the whole horizon
    runs as one chunk.  The plan's kernel selectors fold into ``cfg``
    before compilation.
    """
    policies = list(policies if policies is not None else list_policies())
    scenarios = list(scenarios if scenarios is not None
                     else default_scenarios())
    cfg = cfg or SimConfig()
    plan, cfg = resolve_plan(plan, cfg, devices=devices, chunk=chunk,
                             slab=slab, overlap=overlap)
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    pol = stack_policies(policies)
    if plan.chunk is not None or plan.telescope:
        # telescoping rides the streaming path (there is no per-tick series
        # to stack); without an explicit chunk the whole horizon is one
        # macro-stepped chunk
        fn = make_stream_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                            cfg.horizon, chunk=plan.chunk or cfg.horizon,
                            slab=plan.slab, devices=plan.devices,
                            overlap=plan.overlap, telescope=plan.telescope)
        t0 = time.time()
        finals, summary = fn(sims, pol, rps)
        return SweepResult(policies=policies, scenarios=scenarios,
                           seeds=tuple(seeds), finals=finals, metrics=None,
                           summary=summary,
                           wall_s=round(time.time() - t0, 2),
                           compile_cache_misses=fn._cache_size(),
                           n_devices=fn.n_devices)
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                       devices=plan.devices)
    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)
    jax.tree.leaves(finals)[0].block_until_ready()
    return SweepResult(policies=policies, scenarios=scenarios,
                       seeds=tuple(seeds), finals=finals, metrics=metrics,
                       wall_s=round(time.time() - t0, 2),
                       compile_cache_misses=fn._cache_size(),
                       n_devices=fn.n_devices)


@functools.partial(jax.jit, static_argnames=("cfg", "n_hosts", "n_nodes",
                                             "horizon"))
def _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                         horizon):
    return jax.vmap(lambda s: simulate(s, cfg, policy, n_hosts, n_nodes,
                                       horizon, params))(sims)


@functools.lru_cache(maxsize=None)
def _vmapped_chunk_step_jit(telescope: bool = False):
    """Jitted seed-batched chunk step (lazy: the donation decision reads
    the backend, exactly like ``engine._chunk_step_jit``)."""
    fn = simulate_telescoped if telescope else simulate_chunk

    def step(sims, accs, t0, policy, params, cfg, n_hosts, n_nodes, chunk):
        return jax.vmap(
            lambda s, a: fn(s, a, t0, cfg, policy, n_hosts,
                            n_nodes, chunk, params))(sims, accs)
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(step, static_argnames=("cfg", "n_hosts", "n_nodes",
                                          "chunk"),
                   donate_argnums=donate), bool(donate)


def run_sim_vmapped(sims: SimState, cfg: SimConfig, policy: PolicyParams,
                    n_hosts: int, n_nodes: int, horizon: int,
                    params: RunParams | None = None,
                    chunk: int | None = None, telescope: bool = False):
    """Seed-batched single-policy run (leading axis on every SimState leaf)
    — the degenerate 1x1xN sweep, kept as a convenience for benchmarks.
    Jitted at module level so repeat calls hit the warm cache (keyed on
    config/shapes, like ``run_sim``; policies are data, never cache keys).

    ``chunk`` streams the batch through per-chunk steps with online
    summary folds — (finals, [N] ``OnlineSummary``) instead of
    (finals, [N, T] stacked metrics), O(batch x state) memory at any
    horizon.  ``t0`` stays unbatched through the vmap, so the periodic
    delay-refresh cond survives exactly as in the stacked path.

    ``telescope`` swaps the chunk cell for the macro-tick engine
    (``engine.simulate_telescoped``, docs/events.md) — per-lane dt,
    finals bit-identical; implies the streaming path (whole horizon as
    one chunk when ``chunk`` is None).
    """
    params = cfg.run_params() if params is None else params
    if chunk is None and not telescope:
        return _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts,
                                    n_nodes, horizon)
    chunk = chunk or horizon
    N = sims.t.shape[0]
    stats.check_chunk(chunk, int(sims.containers.status.shape[-1]))
    step, donated = _vmapped_chunk_step_jit(telescope)
    cur = jax.tree.map(jnp.array, sims) if donated else sims
    online = stats.online_init((N,))
    t0 = 0
    while t0 < horizon:
        sz = min(chunk, horizon - t0)
        accs = jax.tree.map(lambda x: jnp.zeros((N,), x.dtype),
                            stats.acc_init())
        cur, accs = step(cur, accs, jnp.asarray(t0, I32), policy, params,
                         cfg=cfg, n_hosts=n_hosts, n_nodes=n_nodes,
                         chunk=sz)
        online = stats.online_fold(online, accs)
        t0 += sz
    return cur, online


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="all",
                    help=f"comma-separated subset of {list_policies()} "
                         "or 'all'")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--table", default="avg_runtime",
                    help="summary metric for the grouped table")
    ap.add_argument("--out", default=None,
                    help="write per-cell summary rows as JSON")
    ap.add_argument("--delay-mode", default="path", choices=["path", "fw"],
                    help="delay refresh: ECMP path sum or full APSP")
    add_exec_args(ap)
    return ap


def main() -> None:
    args = build_parser().parse_args()

    policies = (list_policies() if args.policies == "all"
                else args.policies.split(","))
    cfg = SimConfig(horizon=args.horizon, delay_mode=args.delay_mode)
    plan = ExecPlan.from_args(args)
    cfg = plan.apply_to_config(cfg)
    n_leaf = max(4, args.hosts // 5)
    res = run_sweep(policies=policies, seeds=range(args.seeds), cfg=cfg,
                    n_hosts=args.hosts, n_spine=max(2, n_leaf // 4),
                    n_leaf=n_leaf, plan=plan)
    cells = len(res.policies) * len(res.scenarios) * len(res.seeds)
    from repro.kernels import kernel_backend, resolve_kernel
    backend = kernel_backend()
    kernel_note = (f"delay={args.delay_mode}/{cfg.delay_kernel}"
                   f"(-> {'kernel' if resolve_kernel(cfg.delay_kernel) else 'ref'}), "
                   f"waterfill={cfg.waterfill_kernel}"
                   f"(-> {'kernel' if resolve_kernel(cfg.waterfill_kernel) else 'ref'})")
    print(f"# {cells} cells ({len(res.policies)} policies x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s), "
          f"{res.n_devices} device(s), backend={backend}, {kernel_note}")
    print(res.table(args.table))
    if args.out:
        from repro.core.report import json_clean
        rows = res.summaries()
        for row in rows:   # self-describing rows: backend + kernel dispatch
            row["backend"] = backend
            row["delay_mode"] = args.delay_mode
            row["delay_kernel"] = cfg.delay_kernel
            row["waterfill_kernel"] = cfg.waterfill_kernel
        with open(args.out, "w") as f:
            json.dump(json_clean(rows), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
