"""Sweep driver: policy x scenario x seed in ONE compiled program.

The paper's headline use case is comparing scheduling strategies under
varying network conditions (Figs 4-10).  With policies as weight vectors
and runtime parameters as data (``PolicyParams``/``RunParams``), the whole
evaluation grid is one ``vmap`` over ONE flattened batch axis of P*S*N
cells, jitted exactly once — and that single axis is sharded across every
available device with a ``NamedSharding`` (each device integrates its
slice of cells independently; there is no cross-cell communication):

    policies [P] --+
    scenarios [S] --+--> flatten [P*S*N] --vmap--> jit --> [P, S, N]
    seeds     [N] --+         |
                              +-- NamedSharding over the 'grid' mesh axis

    PYTHONPATH=src python -m repro.launch.sweep --policies all \\
        --seeds 2 --horizon 120 --table avg_runtime --out sweep.json
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import (SimConfig, get_policy, list_policies,
                        sweep_summaries, sweep_table)
from repro.core.engine import simulate
from repro.core.scenario import (ScenarioSpec, build_scenarios,
                                 default_scenarios)
from repro.core.scheduling import validate_weights
from repro.core.types import PolicyParams, RunParams, SimState, TickMetrics

# SimState leaves that are TOPOLOGY, not state: identical across every
# sweep cell by construction (build_scenarios builds one network and every
# host mix assigns leaves as arange % n_leaf; a ScenarioSpec cannot vary
# topology).  They stay UNBATCHED through the grid vmap (in_axes=None):
# the delay-refresh and ECMP-path gathers then keep unbatched *indices*,
# which XLA:CPU lowers on its fast path — batching the index operand of a
# gather was measured at 2.6x per cell on the periodic refresh alone.
STATIC_TOPOLOGY_LEAVES = frozenset({
    ("hosts", "leaf"),
    ("net", "link_u"), ("net", "link_v"),
    ("net", "path_links"), ("net", "path_nlinks"),
})


def _leaf_path_names(path) -> tuple:
    return tuple(p.name for p in path if hasattr(p, "name"))


def _is_static_leaf(path) -> bool:
    names = _leaf_path_names(path)
    return any(names[-len(s):] == s for s in STATIC_TOPOLOGY_LEAVES)


def stack_policies(names_or_params: Sequence) -> PolicyParams:
    """[P]-batched PolicyParams from registered names (or ready-made
    ``PolicyParams``).  Validates every vector against the canonical weight
    length up front — a ragged batch would fail deep inside a trace."""
    pols = [p if isinstance(p, PolicyParams) else get_policy(p)
            for p in names_or_params]
    for i, p in enumerate(pols):
        validate_weights(p.weights, f"stack_policies entry {i}: ")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pols)


def grid_mesh(devices=None) -> Mesh | None:
    """1-axis device mesh for the flattened sweep batch.

    ``devices``: None = all local devices, an int = that many, or an
    explicit device sequence.  Returns None for a single device — the
    unsharded sweep needs no mesh at all.
    """
    if devices is None:
        devices = jax.devices()
    elif isinstance(devices, int):
        devices = jax.devices()[:devices]
    devices = list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.asarray(devices), ("grid",))


def make_sweep_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                  devices=None):
    """The compiled sweep: (sims [S,N], policies [P], params [S]) ->
    (finals, metrics) with [P, S, N] leading axes.

    One jit over the SAME ``engine.simulate`` trace standalone ``run_sim``
    jits — so each cell is bit-for-bit a standalone run, and the whole grid
    costs exactly one XLA compilation (asserted in ``tests/test_sweep.py``
    via the jit cache-miss counter).

    The grid rides ONE ``vmap``: the three axes are broadcast and
    flattened to a single [P*S*N] batch inside the jitted function
    (branch-free scoring makes the policy axis pure data like the others —
    no ``lax.switch`` evaluating every branch per cell).  With more than
    one device the flattened axis carries a ``NamedSharding`` over the
    1-axis ``grid`` mesh, padded to a device multiple by repeating cells
    (the pad cells are sliced off before reshaping back to [P, S, N]);
    cells are independent, so sharded == unsharded bit-for-bit
    (``tests/test_sweep_sharded.py``).
    """
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    jtu = jax.tree_util

    def cell(sim: SimState, pol: PolicyParams, rp: RunParams):
        return simulate(sim, cfg, pol, n_hosts, n_nodes, horizon, rp)

    def grid(sims, pols, rps):
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N

        def flat(x, bshape):                     # bshape -> [B, ...]
            shape = (P, S, N) + x.shape[len(bshape):]
            x = x.reshape(tuple(d if ax in bshape else 1
                                for ax, d in zip("PSN", (P, S, N)))
                          + x.shape[len(bshape):])
            return jnp.broadcast_to(x, shape).reshape((B,) + shape[3:])

        args = (jax.tree.map(lambda x: flat(x, "SN"), sims),
                jax.tree.map(lambda x: flat(x, "P"), pols),
                jax.tree.map(lambda x: flat(x, "S"), rps))
        pad = (-B) % n_dev
        if pad:                                  # repeat cells round-robin
            idx = jnp.arange(B + pad) % B
            args = jax.tree.map(lambda x: x[idx], args)
        if mesh is not None:
            args = jax.lax.with_sharding_constraint(
                args, NamedSharding(mesh, PartitionSpec("grid")))
        # de-batch the topology leaves (every cell carries the same
        # tables; uniformity is checked host-side in fn below) and build
        # the matching in_axes tree: 0 everywhere, None at the statics.
        flat_sims, treedef = jtu.tree_flatten_with_path(args[0])
        sim_arg = jtu.tree_unflatten(
            treedef, [x[0] if _is_static_leaf(p) else x
                      for p, x in flat_sims])
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0
                      for p, x in flat_sims])
        out = jax.vmap(cell, in_axes=(sim_axes, 0, 0))(
            sim_arg, args[1], args[2])
        if pad:
            out = jax.tree.map(lambda x: x[:B], out)
        return jax.tree.map(
            lambda x: x.reshape((P, S, N) + x.shape[1:]), out)

    jitted = jax.jit(grid)

    def fn(sims, pols, rps):
        for p, x in jtu.tree_flatten_with_path(sims)[0]:
            if _is_static_leaf(p):
                x = np.asarray(x)
                ref = x.reshape((-1,) + x.shape[2:])[0]
                if not (x == ref).all():
                    names = ".".join(_leaf_path_names(p))
                    raise ValueError(
                        f"sweep cells disagree on topology leaf {names!r}; "
                        "all scenarios of one grid must share the network "
                        "topology (build_scenarios builds exactly one)")
        return jitted(sims, pols, rps)

    fn._cache_size = jitted._cache_size
    fn.n_devices = n_dev
    return fn


@dataclasses.dataclass
class SweepResult:
    policies: list[str]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    finals: SimState          # [P, S, N, ...]
    metrics: TickMetrics      # [P, S, N, T, ...]
    wall_s: float
    compile_cache_misses: int  # jit cache entries the sweep call created
    n_devices: int = 1         # devices the flattened grid axis spans
    _rows: list | None = dataclasses.field(default=None, repr=False)

    def summaries(self) -> list[dict[str, Any]]:
        if self._rows is None:  # per-cell summarize is host-side O(cells)
            self._rows = sweep_summaries(
                self.finals, self.metrics, self.policies,
                [s.name for s in self.scenarios], self.seeds)
        return self._rows

    def table(self, value: str = "avg_runtime") -> str:
        return sweep_table(self.summaries(), value=value)


def run_sweep(policies: Sequence[str] | None = None,
              scenarios: Sequence[ScenarioSpec] | None = None,
              seeds: Sequence[int] = (0,), cfg: SimConfig | None = None,
              n_hosts: int = 20, n_spine: int = 2,
              n_leaf: int = 4, devices=None) -> SweepResult:
    """Build the grid and run it as one compiled call (sharded over
    ``devices`` — default: every local device)."""
    policies = list(policies if policies is not None else list_policies())
    scenarios = list(scenarios if scenarios is not None
                     else default_scenarios())
    cfg = cfg or SimConfig()
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    pol = stack_policies(policies)
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                       devices=devices)
    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)
    jax.tree.leaves(finals)[0].block_until_ready()
    return SweepResult(policies=policies, scenarios=scenarios,
                       seeds=tuple(seeds), finals=finals, metrics=metrics,
                       wall_s=round(time.time() - t0, 2),
                       compile_cache_misses=fn._cache_size(),
                       n_devices=fn.n_devices)


@functools.partial(jax.jit, static_argnames=("cfg", "n_hosts", "n_nodes",
                                             "horizon"))
def _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                         horizon):
    return jax.vmap(lambda s: simulate(s, cfg, policy, n_hosts, n_nodes,
                                       horizon, params))(sims)


def run_sim_vmapped(sims: SimState, cfg: SimConfig, policy: PolicyParams,
                    n_hosts: int, n_nodes: int, horizon: int,
                    params: RunParams | None = None):
    """Seed-batched single-policy run (leading axis on every SimState leaf)
    — the degenerate 1x1xN sweep, kept as a convenience for benchmarks.
    Jitted at module level so repeat calls hit the warm cache (keyed on
    config/shapes, like ``run_sim``; policies are data, never cache keys)."""
    params = cfg.run_params() if params is None else params
    return _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                                horizon)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="all",
                    help=f"comma-separated subset of {list_policies()} "
                         "or 'all'")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the flattened grid over this many devices "
                         "(default: all local devices)")
    ap.add_argument("--table", default="avg_runtime",
                    help="summary metric for the grouped table")
    ap.add_argument("--out", default=None,
                    help="write per-cell summary rows as JSON")
    ap.add_argument("--delay-mode", default="path", choices=["path", "fw"],
                    help="delay refresh: ECMP path sum or full APSP")
    ap.add_argument("--delay-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fw APSP Pallas kernel (auto: compiled on TPU/GPU, "
                         "jnp ref on CPU)")
    ap.add_argument("--waterfill-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused waterfilling Pallas kernel (same semantics)")
    args = ap.parse_args()

    policies = (list_policies() if args.policies == "all"
                else args.policies.split(","))
    cfg = SimConfig(horizon=args.horizon, delay_mode=args.delay_mode,
                    delay_kernel=args.delay_kernel,
                    waterfill_kernel=args.waterfill_kernel)
    n_leaf = max(4, args.hosts // 5)
    res = run_sweep(policies=policies, seeds=range(args.seeds), cfg=cfg,
                    n_hosts=args.hosts, n_spine=max(2, n_leaf // 4),
                    n_leaf=n_leaf, devices=args.devices)
    cells = len(res.policies) * len(res.scenarios) * len(res.seeds)
    from repro.kernels import kernel_backend, resolve_kernel
    backend = kernel_backend()
    kernel_note = (f"delay={args.delay_mode}/{args.delay_kernel}"
                   f"(-> {'kernel' if resolve_kernel(args.delay_kernel) else 'ref'}), "
                   f"waterfill={args.waterfill_kernel}"
                   f"(-> {'kernel' if resolve_kernel(args.waterfill_kernel) else 'ref'})")
    print(f"# {cells} cells ({len(res.policies)} policies x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s), "
          f"{res.n_devices} device(s), backend={backend}, {kernel_note}")
    print(res.table(args.table))
    if args.out:
        from repro.core.report import json_clean
        rows = res.summaries()
        for row in rows:   # self-describing rows: backend + kernel dispatch
            row["backend"] = backend
            row["delay_mode"] = args.delay_mode
            row["delay_kernel"] = args.delay_kernel
            row["waterfill_kernel"] = args.waterfill_kernel
        with open(args.out, "w") as f:
            json.dump(json_clean(rows), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
