"""Sweep driver: policy x scenario x seed in ONE compiled program.

The paper's headline use case is comparing scheduling strategies under
varying network conditions (Figs 4-10).  With policies as weight vectors
and runtime parameters as data (``PolicyParams``/``RunParams``), the whole
evaluation grid is one ``vmap`` over ONE flattened batch axis of P*S*N
cells, jitted exactly once — and that single axis is sharded across every
available device with a ``NamedSharding`` (each device integrates its
slice of cells independently; there is no cross-cell communication):

    policies [P] --+
    scenarios [S] --+--> flatten [P*S*N] --vmap--> jit --> [P, S, N]
    seeds     [N] --+         |
                              +-- NamedSharding over the 'grid' mesh axis

    PYTHONPATH=src python -m repro.launch.sweep --policies all \\
        --seeds 2 --horizon 120 --table avg_runtime --out sweep.json
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import (SimConfig, get_policy, list_policies,
                        sweep_summaries, sweep_table)
from repro.core import stats
from repro.core.engine import simulate, simulate_chunk
from repro.core.scenario import (ScenarioSpec, build_scenarios,
                                 default_scenarios)
from repro.core.scheduling import validate_weights
from repro.core.types import (OnlineSummary, PolicyParams, RunParams,
                              SimState, TickMetrics)
from repro.launch.mesh import compat_mesh

I32 = jnp.int32

# SimState leaves that are TOPOLOGY, not state: identical across every
# sweep cell by construction (build_scenarios builds one network and every
# host mix assigns leaves as arange % n_leaf; a ScenarioSpec cannot vary
# topology).  They stay UNBATCHED through the grid vmap (in_axes=None):
# the delay-refresh and ECMP-path gathers then keep unbatched *indices*,
# which XLA:CPU lowers on its fast path — batching the index operand of a
# gather was measured at 2.6x per cell on the periodic refresh alone.
STATIC_TOPOLOGY_LEAVES = frozenset({
    ("hosts", "leaf"),
    ("net", "link_u"), ("net", "link_v"),
    ("net", "path_links"), ("net", "path_nlinks"),
})


def _leaf_path_names(path) -> tuple:
    return tuple(p.name for p in path if hasattr(p, "name"))


def _is_static_leaf(path) -> bool:
    names = _leaf_path_names(path)
    return any(names[-len(s):] == s for s in STATIC_TOPOLOGY_LEAVES)


def stack_policies(names_or_params: Sequence) -> PolicyParams:
    """[P]-batched PolicyParams from registered names (or ready-made
    ``PolicyParams``).  Validates every vector against the canonical weight
    length up front — a ragged batch would fail deep inside a trace."""
    pols = [p if isinstance(p, PolicyParams) else get_policy(p)
            for p in names_or_params]
    for i, p in enumerate(pols):
        validate_weights(p.weights, f"stack_policies entry {i}: ")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pols)


def grid_mesh(devices=None) -> Mesh | None:
    """1-axis device mesh for the flattened sweep batch.

    ``devices``: None = all addressable devices, an int = that many, or an
    explicit device sequence.  Returns None for a single device — the
    unsharded sweep needs no mesh at all.  Defaults to
    ``jax.local_devices()`` (not ``jax.devices()``): under
    ``jax.distributed`` the global list contains other processes'
    non-addressable devices, and the sweep fabric's cross-host story is
    slab-per-process with a host-side reduction (``repro.launch.dist``),
    never a global-SPMD program.  Built through ``mesh.compat_mesh`` —
    the repo's one AxisType-compat mesh constructor.
    """
    if devices is None:
        devices = jax.local_devices()
    elif isinstance(devices, int):
        devices = jax.local_devices()[:devices]
    devices = list(devices)
    if len(devices) <= 1:
        return None
    return compat_mesh((len(devices),), ("grid",), devices=devices)


def make_sweep_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                  devices=None):
    """The compiled sweep: (sims [S,N], policies [P], params [S]) ->
    (finals, metrics) with [P, S, N] leading axes.

    One jit over the SAME ``engine.simulate`` trace standalone ``run_sim``
    jits — so each cell is bit-for-bit a standalone run, and the whole grid
    costs exactly one XLA compilation (asserted in ``tests/test_sweep.py``
    via the jit cache-miss counter).

    The grid rides ONE ``vmap``: the three axes are broadcast and
    flattened to a single [P*S*N] batch inside the jitted function
    (branch-free scoring makes the policy axis pure data like the others —
    no ``lax.switch`` evaluating every branch per cell).  With more than
    one device the flattened axis carries a ``NamedSharding`` over the
    1-axis ``grid`` mesh, padded to a device multiple by repeating cells
    (the pad cells are sliced off before reshaping back to [P, S, N]);
    cells are independent, so sharded == unsharded bit-for-bit
    (``tests/test_sweep_sharded.py``).
    """
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    jtu = jax.tree_util

    def cell(sim: SimState, pol: PolicyParams, rp: RunParams):
        return simulate(sim, cfg, pol, n_hosts, n_nodes, horizon, rp)

    def grid(sims, pols, rps):
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N

        def flat(x, bshape):                     # bshape -> [B, ...]
            shape = (P, S, N) + x.shape[len(bshape):]
            x = x.reshape(tuple(d if ax in bshape else 1
                                for ax, d in zip("PSN", (P, S, N)))
                          + x.shape[len(bshape):])
            return jnp.broadcast_to(x, shape).reshape((B,) + shape[3:])

        args = (jax.tree.map(lambda x: flat(x, "SN"), sims),
                jax.tree.map(lambda x: flat(x, "P"), pols),
                jax.tree.map(lambda x: flat(x, "S"), rps))
        # Pad to a device multiple by repeating cells round-robin.  The pad
        # cells RECOMPUTE real cells and their results are sliced off —
        # deliberate waste: under vmap+SPMD every lane executes the same
        # ops regardless of data, so "masking" a pad cell's workload to
        # near-zero saves nothing, while zeroed/degenerate states would
        # fork the tick's branches.  The measured cost is the pad fraction
        # itself (<= (n_dev-1)/B of the grid; numbers in docs/sweeps.md).
        pad = (-B) % n_dev
        if pad:
            idx = jnp.arange(B + pad) % B
            args = jax.tree.map(lambda x: x[idx], args)
        if mesh is not None:
            args = jax.lax.with_sharding_constraint(
                args, NamedSharding(mesh, PartitionSpec("grid")))
        # de-batch the topology leaves (every cell carries the same
        # tables; uniformity is checked host-side in fn below) and build
        # the matching in_axes tree: 0 everywhere, None at the statics.
        flat_sims, treedef = jtu.tree_flatten_with_path(args[0])
        sim_arg = jtu.tree_unflatten(
            treedef, [x[0] if _is_static_leaf(p) else x
                      for p, x in flat_sims])
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0
                      for p, x in flat_sims])
        out = jax.vmap(cell, in_axes=(sim_axes, 0, 0))(
            sim_arg, args[1], args[2])
        if pad:
            out = jax.tree.map(lambda x: x[:B], out)
        return jax.tree.map(
            lambda x: x.reshape((P, S, N) + x.shape[1:]), out)

    jitted = jax.jit(grid)

    def fn(sims, pols, rps):
        _check_topology_uniform(sims)
        return jitted(sims, pols, rps)

    fn._cache_size = jitted._cache_size
    fn.n_devices = n_dev
    return fn


def _check_topology_uniform(sims) -> None:
    """Every cell of one grid must share the network topology — the static
    leaves are de-batched through the vmap (``STATIC_TOPOLOGY_LEAVES``)."""
    for p, x in jax.tree_util.tree_flatten_with_path(sims)[0]:
        if _is_static_leaf(p):
            x = np.asarray(x)
            ref = x.reshape((-1,) + x.shape[2:])[0]
            if not (x == ref).all():
                names = ".".join(_leaf_path_names(p))
                raise ValueError(
                    f"sweep cells disagree on topology leaf {names!r}; "
                    "all scenarios of one grid must share the network "
                    "topology (build_scenarios builds exactly one)")


def make_stream_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int,
                   chunk: int, slab: int | None = None, devices=None,
                   overlap: bool = True):
    """The streaming sweep: the same [P, S, N] grid as ``make_sweep_fn``,
    but iterated in device-multiple SLABS of cells through ONE compiled
    slab-chunk step, with per-tick metrics folded into ``SummaryAcc``
    carries instead of stacked — so peak memory is O(slab x state), never
    O(cells x horizon).

    Returns ``fn(sims, pols, rps) -> (finals, summary)`` where ``finals``
    has [P, S, N] leading axes (numpy; bit-for-bit the stacked sweep's
    finals) and ``summary`` is a [P, S, N] ``stats.OnlineSummary``.

    Chunking the horizon and slabbing the grid compose in one loop nest:

        for each slab of cells:                # wrap-padded start offsets
            enqueue every chunk step           # ONE jitted function, async
            gather the PREVIOUS slab's finals + accs   # one device_get
            fold its accs into the host f64/i64 summary

    The jitted step is compiled once for the main chunk size (+ one tail
    compile when ``chunk`` does not divide ``horizon``): ``t0`` is traced,
    the per-cell link-param application rides a ``t0 == 0`` cond, and the
    static topology leaves stay unbatched through the vmap in BOTH
    directions (``in_axes``/``out_axes`` None) so every slab re-enters the
    same compiled program.  On non-CPU backends the (state, accumulator)
    carry is donated, so a slab's device footprint never doubles.

    The driver is OVERLAPPED (PR 8): jax dispatch is asynchronous, so the
    loop never blocks between chunks — per-chunk accumulators are kept as
    device arrays and the whole slab (every finals leaf + every chunk's
    ``SummaryAcc``) comes back in ONE batched ``jax.device_get``, issued
    only after the NEXT slab's steps are already enqueued
    (``overlap=True``).  The host-side fold and slice-write of slab *k*
    then runs while the device integrates slab *k+1*; peak footprint is
    two slabs (the in-flight one plus the one being gathered).
    ``overlap=False`` keeps the gather synchronous (slab *k* is fetched
    before slab *k+1* is touched) — the PR 7 behavior, minus its per-leaf
    ``np.asarray`` and per-chunk host-fold stalls, kept as the bench
    comparison arm.

    ``fn.iter_slabs(sims, pols, rps, slab_starts)`` exposes the runner
    itself — a generator of ``(s0, finals_leaves, slab_summary)`` per
    start offset — so the distributed launcher (``repro.launch.dist``)
    can drive the SAME compiled step from a coordinator-fed slab queue
    instead of ``range(0, B, Bs)``.
    """
    stats.check_chunk(chunk, cfg.n_containers)
    mesh = grid_mesh(devices)
    n_dev = 1 if mesh is None else mesh.devices.size
    jtu = jax.tree_util

    def step(sims, accs, pols, rps, t0, csz):
        if mesh is not None:
            spec = NamedSharding(mesh, PartitionSpec("grid"))
            shard = lambda x: jax.lax.with_sharding_constraint(x, spec)
            flat, treedef = jtu.tree_flatten_with_path(sims)
            sims = jtu.tree_unflatten(
                treedef, [x if _is_static_leaf(p) else shard(x)
                          for p, x in flat])
            accs, pols, rps = jax.tree.map(shard, (accs, pols, rps))

        def cell(sim, acc, pol, rp):
            return simulate_chunk(sim, acc, t0, cfg, pol, n_hosts, n_nodes,
                                  csz, rp)

        flat, treedef = jtu.tree_flatten_with_path(sims)
        sim_axes = jtu.tree_unflatten(
            treedef, [None if _is_static_leaf(p) else 0 for p, _ in flat])
        return jax.vmap(cell, in_axes=(sim_axes, 0, 0, 0),
                        out_axes=(sim_axes, 0))(sims, accs, pols, rps)

    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    jstep = jax.jit(step, static_argnames=("csz",), donate_argnums=donate)

    def slab_cells(B: int) -> int:
        """Wrap-padded device-multiple slab size for a B-cell grid."""
        Bs = B if slab is None else min(slab, B)
        return Bs + (-Bs) % n_dev

    def iter_slabs(sims, pols, rps, slab_starts):
        """Run the wrap-padded slab at each start offset; yield
        ``(s0, finals_leaves, slab_summary)`` — finals as host numpy per
        flattened ``SimState`` leaf (statics de-batched), summary a [Bs]
        ``OnlineSummary``.  ``slab_starts`` may be any iterable (a lazy
        coordinator queue included); each start owns cells
        ``(s0 + arange(Bs)) % B`` of which the first ``min(Bs, B - s0)``
        are real."""
        _check_topology_uniform(sims)
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N
        Bs = slab_cells(B)
        flat_sims, sims_def = jtu.tree_flatten_with_path(sims)
        statics = {i for i, (p, _) in enumerate(flat_sims)
                   if _is_static_leaf(p)}
        if mesh is not None:
            # pre-place slab inputs in their final layout: the FIRST jstep
            # call then compiles for grid-sharded carries, the same
            # signature every later chunk re-enters — without this the
            # unsharded first call costs a third compilation per process
            gspec = NamedSharding(mesh, PartitionSpec("grid"))
            repl = NamedSharding(mesh, PartitionSpec())
            place = lambda x, s: jax.device_put(x, s)
        else:
            gspec = repl = None
            place = lambda x, s: x
        zero_accs = lambda: jax.tree.map(
            lambda x: place(jnp.zeros((Bs,), x.dtype), gspec),
            stats.acc_init())

        def enqueue(s0):
            idx = (s0 + np.arange(Bs)) % B       # wrap-pad the last slab
            p_i, s_i, n_i = idx // (S * N), (idx // N) % S, idx % N
            sim_slab = jtu.tree_unflatten(
                sims_def, [place(x[0, 0], repl) if i in statics
                           else place(x[s_i, n_i], gspec)
                           for i, (_, x) in enumerate(flat_sims)])
            pol_slab = jax.tree.map(lambda x: place(x[p_i], gspec), pols)
            rp_slab = jax.tree.map(lambda x: place(x[s_i], gspec), rps)
            accs = []
            t0 = 0
            while t0 < horizon:
                sz = min(chunk, horizon - t0)    # tail: one extra compile
                # the accumulator RESETS every chunk (the i32 bound and the
                # f32 precision argument are per-chunk properties); the
                # host fold in finish() carries the running 64-bit totals
                sim_slab, acc = jstep(sim_slab, zero_accs(), pol_slab,
                                      rp_slab, jnp.asarray(t0, I32),
                                      csz=sz)
                accs.append(acc)
                t0 += sz
            return s0, sim_slab, accs

        def finish(pend):
            s0, sim_slab, accs = pend
            # ONE host transfer for the whole slab: every finals leaf and
            # every chunk's SummaryAcc in a single batched device_get
            # (PR 7 issued one blocking np.asarray per leaf per slab plus
            # one per-chunk sync inside the fold loop)
            host_leaves, host_accs = jax.device_get(
                (jtu.tree_leaves(sim_slab), accs))
            slab_sum = stats.online_init((Bs,))
            for a in host_accs:
                slab_sum = stats.online_fold(slab_sum, a)
            return s0, host_leaves, slab_sum

        pending = None
        for s0 in slab_starts:
            cur = enqueue(s0)                    # async: nothing blocks yet
            if not overlap:
                yield finish(cur)
                continue
            if pending is not None:              # gather k AFTER k+1 is in
                yield finish(pending)
            pending = cur
        if pending is not None:
            yield finish(pending)

    def fn(sims, pols, rps):
        P = pols.weights.shape[0]
        S, N = sims.t.shape
        B = P * S * N
        Bs = slab_cells(B)
        flat_sims, sims_def = jtu.tree_flatten_with_path(sims)
        statics = {i for i, (p, _) in enumerate(flat_sims)
                   if _is_static_leaf(p)}
        summary = stats.online_init((B,))
        finals_flat = None                       # host [B, ...] per leaf
        for s0, host_slab, slab_sum in iter_slabs(sims, pols, rps,
                                                  range(0, B, Bs)):
            real = min(Bs, B - s0)               # wrap rows are duplicates
            if finals_flat is None:
                finals_flat = [
                    x if i in statics
                    else np.empty((B,) + x.shape[1:], x.dtype)
                    for i, x in enumerate(host_slab)]
            for i, x in enumerate(host_slab):
                if i not in statics:
                    finals_flat[i][s0:s0 + real] = x[:real]
            for h, a in zip(summary, slab_sum):
                h[s0:s0 + real] = a[:real]

        leaves = [np.broadcast_to(x, (P, S, N) + x.shape).copy()
                  if i in statics               # restore the batched shape
                  else x.reshape((P, S, N) + x.shape[1:])
                  for i, x in enumerate(finals_flat)]
        finals = jtu.tree_unflatten(sims_def, leaves)
        summary = OnlineSummary(*(x.reshape((P, S, N)) for x in summary))
        return finals, summary

    fn._cache_size = jstep._cache_size
    fn.n_devices = n_dev
    fn.iter_slabs = iter_slabs
    fn.slab_cells = slab_cells
    return fn


@dataclasses.dataclass
class SweepResult:
    policies: list[str]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    finals: SimState          # [P, S, N, ...]
    metrics: TickMetrics | None   # [P, S, N, T, ...]; None when streamed
    wall_s: float
    compile_cache_misses: int  # jit cache entries the sweep call created
    n_devices: int = 1         # devices the flattened grid axis spans
    summary: OnlineSummary | None = None  # [P, S, N] streaming fold
    worker_meta: list | None = None  # per-process slabs/walls (launch.dist)
    _rows: list | None = dataclasses.field(default=None, repr=False)

    def summaries(self) -> list[dict[str, Any]]:
        if self._rows is None:  # per-cell summarize is host-side O(cells)
            self._rows = sweep_summaries(
                self.finals,
                self.metrics if self.metrics is not None else self.summary,
                self.policies,
                [s.name for s in self.scenarios], self.seeds)
        return self._rows

    def table(self, value: str = "avg_runtime") -> str:
        return sweep_table(self.summaries(), value=value)


def run_sweep(policies: Sequence[str] | None = None,
              scenarios: Sequence[ScenarioSpec] | None = None,
              seeds: Sequence[int] = (0,), cfg: SimConfig | None = None,
              n_hosts: int = 20, n_spine: int = 2,
              n_leaf: int = 4, devices=None, chunk: int | None = None,
              slab: int | None = None, overlap: bool = True) -> SweepResult:
    """Build the grid and run it as one compiled call (sharded over
    ``devices`` — default: every local device).

    ``chunk`` switches to the STREAMING sweep (``make_stream_fn``): the
    horizon runs in chunks with online summary folds and the grid is
    iterated in slabs of ``slab`` cells (default: the whole grid) through
    one compiled step — [P, S, N] summaries without ever holding
    [P, S, N, T] metrics.  Cell results are bit-identical either way.
    ``overlap`` (streaming only) gathers each slab's results one slab
    behind the dispatch so host transfers hide under device compute.
    """
    policies = list(policies if policies is not None else list_policies())
    scenarios = list(scenarios if scenarios is not None
                     else default_scenarios())
    cfg = cfg or SimConfig()
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    pol = stack_policies(policies)
    if chunk is not None:
        fn = make_stream_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                            cfg.horizon, chunk=chunk, slab=slab,
                            devices=devices, overlap=overlap)
        t0 = time.time()
        finals, summary = fn(sims, pol, rps)
        return SweepResult(policies=policies, scenarios=scenarios,
                           seeds=tuple(seeds), finals=finals, metrics=None,
                           summary=summary,
                           wall_s=round(time.time() - t0, 2),
                           compile_cache_misses=fn._cache_size(),
                           n_devices=fn.n_devices)
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                       devices=devices)
    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)
    jax.tree.leaves(finals)[0].block_until_ready()
    return SweepResult(policies=policies, scenarios=scenarios,
                       seeds=tuple(seeds), finals=finals, metrics=metrics,
                       wall_s=round(time.time() - t0, 2),
                       compile_cache_misses=fn._cache_size(),
                       n_devices=fn.n_devices)


@functools.partial(jax.jit, static_argnames=("cfg", "n_hosts", "n_nodes",
                                             "horizon"))
def _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                         horizon):
    return jax.vmap(lambda s: simulate(s, cfg, policy, n_hosts, n_nodes,
                                       horizon, params))(sims)


@functools.lru_cache(maxsize=None)
def _vmapped_chunk_step_jit():
    """Jitted seed-batched chunk step (lazy: the donation decision reads
    the backend, exactly like ``engine._chunk_step_jit``)."""
    def step(sims, accs, t0, policy, params, cfg, n_hosts, n_nodes, chunk):
        return jax.vmap(
            lambda s, a: simulate_chunk(s, a, t0, cfg, policy, n_hosts,
                                        n_nodes, chunk, params))(sims, accs)
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(step, static_argnames=("cfg", "n_hosts", "n_nodes",
                                          "chunk"),
                   donate_argnums=donate), bool(donate)


def run_sim_vmapped(sims: SimState, cfg: SimConfig, policy: PolicyParams,
                    n_hosts: int, n_nodes: int, horizon: int,
                    params: RunParams | None = None,
                    chunk: int | None = None):
    """Seed-batched single-policy run (leading axis on every SimState leaf)
    — the degenerate 1x1xN sweep, kept as a convenience for benchmarks.
    Jitted at module level so repeat calls hit the warm cache (keyed on
    config/shapes, like ``run_sim``; policies are data, never cache keys).

    ``chunk`` streams the batch through per-chunk steps with online
    summary folds — (finals, [N] ``OnlineSummary``) instead of
    (finals, [N, T] stacked metrics), O(batch x state) memory at any
    horizon.  ``t0`` stays unbatched through the vmap, so the periodic
    delay-refresh cond survives exactly as in the stacked path.
    """
    params = cfg.run_params() if params is None else params
    if chunk is None:
        return _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts,
                                    n_nodes, horizon)
    N = sims.t.shape[0]
    stats.check_chunk(chunk, int(sims.containers.status.shape[-1]))
    step, donated = _vmapped_chunk_step_jit()
    cur = jax.tree.map(jnp.array, sims) if donated else sims
    online = stats.online_init((N,))
    t0 = 0
    while t0 < horizon:
        sz = min(chunk, horizon - t0)
        accs = jax.tree.map(lambda x: jnp.zeros((N,), x.dtype),
                            stats.acc_init())
        cur, accs = step(cur, accs, jnp.asarray(t0, I32), policy, params,
                         cfg=cfg, n_hosts=n_hosts, n_nodes=n_nodes,
                         chunk=sz)
        online = stats.online_fold(online, accs)
        t0 += sz
    return cur, online


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="all",
                    help=f"comma-separated subset of {list_policies()} "
                         "or 'all'")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the flattened grid over this many devices "
                         "(default: all local devices)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="stream the horizon in chunks of this many ticks "
                         "with online summaries (O(state) memory; default: "
                         "stacked per-tick metrics)")
    ap.add_argument("--slab", type=int, default=None,
                    help="with --chunk: iterate the grid in slabs of this "
                         "many cells through one compiled step (default: "
                         "the whole grid at once)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="with --chunk: gather each slab synchronously "
                         "instead of one slab behind the async dispatch")
    ap.add_argument("--table", default="avg_runtime",
                    help="summary metric for the grouped table")
    ap.add_argument("--out", default=None,
                    help="write per-cell summary rows as JSON")
    ap.add_argument("--delay-mode", default="path", choices=["path", "fw"],
                    help="delay refresh: ECMP path sum or full APSP")
    ap.add_argument("--delay-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fw APSP Pallas kernel (auto: compiled on TPU/GPU, "
                         "jnp ref on CPU)")
    ap.add_argument("--waterfill-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused waterfilling Pallas kernel (same semantics)")
    args = ap.parse_args()

    policies = (list_policies() if args.policies == "all"
                else args.policies.split(","))
    cfg = SimConfig(horizon=args.horizon, delay_mode=args.delay_mode,
                    delay_kernel=args.delay_kernel,
                    waterfill_kernel=args.waterfill_kernel)
    n_leaf = max(4, args.hosts // 5)
    res = run_sweep(policies=policies, seeds=range(args.seeds), cfg=cfg,
                    n_hosts=args.hosts, n_spine=max(2, n_leaf // 4),
                    n_leaf=n_leaf, devices=args.devices, chunk=args.chunk,
                    slab=args.slab, overlap=not args.no_overlap)
    cells = len(res.policies) * len(res.scenarios) * len(res.seeds)
    from repro.kernels import kernel_backend, resolve_kernel
    backend = kernel_backend()
    kernel_note = (f"delay={args.delay_mode}/{args.delay_kernel}"
                   f"(-> {'kernel' if resolve_kernel(args.delay_kernel) else 'ref'}), "
                   f"waterfill={args.waterfill_kernel}"
                   f"(-> {'kernel' if resolve_kernel(args.waterfill_kernel) else 'ref'})")
    print(f"# {cells} cells ({len(res.policies)} policies x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s), "
          f"{res.n_devices} device(s), backend={backend}, {kernel_note}")
    print(res.table(args.table))
    if args.out:
        from repro.core.report import json_clean
        rows = res.summaries()
        for row in rows:   # self-describing rows: backend + kernel dispatch
            row["backend"] = backend
            row["delay_mode"] = args.delay_mode
            row["delay_kernel"] = args.delay_kernel
            row["waterfill_kernel"] = args.waterfill_kernel
        with open(args.out, "w") as f:
            json.dump(json_clean(rows), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
