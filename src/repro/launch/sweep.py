"""Sweep driver: policy x scenario x seed in ONE compiled program.

The paper's headline use case is comparing scheduling strategies under
varying network conditions (Figs 4-10).  With policies and runtime
parameters as data (``PolicyParams``/``RunParams``), the whole evaluation
grid is three nested ``vmap``s over one ``engine.simulate`` trace, jitted
exactly once:

    policies [P]  --vmap--+
    scenarios [S] --vmap--+--> jax.jit(...)  ->  finals/metrics [P, S, N]
    seeds     [N] --vmap--+

    PYTHONPATH=src python -m repro.launch.sweep --policies all \\
        --seeds 2 --horizon 120 --table avg_runtime --out sweep.json
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import (SimConfig, get_policy, list_policies,
                        sweep_summaries, sweep_table)
from repro.core import scheduling
from repro.core.engine import simulate
from repro.core.scenario import (ScenarioSpec, build_scenarios,
                                 default_scenarios)
from repro.core.types import PolicyParams, RunParams, SimState, TickMetrics


def stack_policies(names: Sequence[str]) -> PolicyParams:
    """[P]-batched PolicyParams for a list of registered policy names."""
    pols = [get_policy(n) for n in names]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pols)


def make_sweep_fn(cfg: SimConfig, n_hosts: int, n_nodes: int, horizon: int):
    """The compiled sweep: (sims [S,N], policies [P], params [S]) ->
    (finals, metrics) with [P, S, N] leading axes.

    One jit over the SAME ``engine.simulate`` trace standalone ``run_sim``
    jits — so each cell is bit-for-bit a standalone run, and the whole grid
    costs exactly one XLA compilation (asserted in ``tests/test_sweep.py``
    via the jit cache-miss counter).

    ALL THREE axes ride ``vmap`` — one data-parallel batch of P*S*N cells.
    The scatter-free tick made this possible (docs/sweeps.md): the PR 3
    tick's state-update scatters hit XLA:CPU's slow *batched*-scatter
    lowering (~1.6x per cell measured), so only the seed axis vmapped and
    policies/scenarios paid a serializing ``lax.map``.  With the updates as
    where-masks and segment reductions, batching the tick is ordinary
    elementwise work.  Under a policy-batched ``vmap`` the ``lax.switch``
    hook dispatch evaluates every registered branch and selects per cell —
    that is the price of one compiled program over the policy axis, and it
    is bounded by the most expensive branch (measured in the
    ``vmap_cell_tax`` bench entry, BENCH_engine.json).
    """
    def cell(sim: SimState, pol: PolicyParams, rp: RunParams):
        return simulate(sim, cfg, pol, n_hosts, n_nodes, horizon, rp)

    seeds_f = jax.vmap(cell, in_axes=(0, None, None))      # seeds     [N]
    scen_f = jax.vmap(seeds_f, in_axes=(0, None, 0))       # scenarios [S]
    grid = jax.vmap(scen_f, in_axes=(None, 0, None))       # policies  [P]
    jitted = jax.jit(grid)
    # the registered branch tables are baked into the compiled grid; a
    # policy registered after this point would be silently clamped onto the
    # old last branch by lax.switch — fail loudly instead (run_sim keys its
    # jit cache the same way, via scheduling.registry_version()).
    version = scheduling.registry_version()

    def checked(sims, pols, rps):
        if scheduling.registry_version() != version:
            raise RuntimeError(
                "policy registry changed since make_sweep_fn(); rebuild the "
                "sweep function to compile the new branch table in")
        return jitted(sims, pols, rps)

    checked._cache_size = jitted._cache_size
    return checked


@dataclasses.dataclass
class SweepResult:
    policies: list[str]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    finals: SimState          # [P, S, N, ...]
    metrics: TickMetrics      # [P, S, N, T, ...]
    wall_s: float
    compile_cache_misses: int  # jit cache entries the sweep call created
    _rows: list | None = dataclasses.field(default=None, repr=False)

    def summaries(self) -> list[dict[str, Any]]:
        if self._rows is None:  # per-cell summarize is host-side O(cells)
            self._rows = sweep_summaries(
                self.finals, self.metrics, self.policies,
                [s.name for s in self.scenarios], self.seeds)
        return self._rows

    def table(self, value: str = "avg_runtime") -> str:
        return sweep_table(self.summaries(), value=value)


def run_sweep(policies: Sequence[str] | None = None,
              scenarios: Sequence[ScenarioSpec] | None = None,
              seeds: Sequence[int] = (0,), cfg: SimConfig | None = None,
              n_hosts: int = 20, n_spine: int = 2,
              n_leaf: int = 4) -> SweepResult:
    """Build the grid and run it as one compiled call."""
    policies = list(policies if policies is not None else list_policies())
    scenarios = list(scenarios if scenarios is not None
                     else default_scenarios())
    cfg = cfg or SimConfig()
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    pol = stack_policies(policies)
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon)
    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)
    jax.tree.leaves(finals)[0].block_until_ready()
    return SweepResult(policies=policies, scenarios=scenarios,
                       seeds=tuple(seeds), finals=finals, metrics=metrics,
                       wall_s=round(time.time() - t0, 2),
                       compile_cache_misses=fn._cache_size())


@functools.partial(jax.jit, static_argnames=("cfg", "n_hosts", "n_nodes",
                                             "horizon", "registry"))
def _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                         horizon, registry):
    return jax.vmap(lambda s: simulate(s, cfg, policy, n_hosts, n_nodes,
                                       horizon, params))(sims)


def run_sim_vmapped(sims: SimState, cfg: SimConfig, policy: PolicyParams,
                    n_hosts: int, n_nodes: int, horizon: int,
                    params: RunParams | None = None):
    """Seed-batched single-policy run (leading axis on every SimState leaf)
    — the degenerate 1x1xN sweep, kept as a convenience for benchmarks.
    Jitted at module level so repeat calls hit the warm cache (keyed on
    config/shapes + the policy-registry version, like ``run_sim``)."""
    params = cfg.run_params() if params is None else params
    return _run_sim_vmapped_jit(sims, cfg, policy, params, n_hosts, n_nodes,
                                horizon,
                                registry=scheduling.registry_version())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="all",
                    help=f"comma-separated subset of {list_policies()} "
                         "or 'all'")
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--table", default="avg_runtime",
                    help="summary metric for the grouped table")
    ap.add_argument("--out", default=None,
                    help="write per-cell summary rows as JSON")
    args = ap.parse_args()

    policies = (list_policies() if args.policies == "all"
                else args.policies.split(","))
    cfg = SimConfig(horizon=args.horizon)
    n_leaf = max(4, args.hosts // 5)
    res = run_sweep(policies=policies, seeds=range(args.seeds), cfg=cfg,
                    n_hosts=args.hosts, n_spine=max(2, n_leaf // 4),
                    n_leaf=n_leaf)
    cells = len(res.policies) * len(res.scenarios) * len(res.seeds)
    print(f"# {cells} cells ({len(res.policies)} policies x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s)")
    print(res.table(args.table))
    if args.out:
        from repro.core.report import json_clean
        with open(args.out, "w") as f:
            json.dump(json_clean(res.summaries()), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
