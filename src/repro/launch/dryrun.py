import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, with zero device allocation
(all inputs are ShapeDtypeStructs carrying NamedShardings).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Outputs per cell: compiled memory analysis (proves the program fits),
cost analysis (FLOPs/bytes for the roofline), and the parsed collective
wire bytes.  Results accumulate in experiments/dryrun_results.json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig, ShapeSpec
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.optimizer import OptimizerConfig
from repro.train.step import StepConfig, init_train_state, make_train_step

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/dryrun_results.json")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, weak-type-correct, shardable)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs with NamedShardings for the given cell."""
    specs = shd.batch_specs(cfg, shape, mesh)
    B, S = shape.global_batch, shape.seq_len

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, spec))

    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        out["tokens"] = sds((B, 1), jnp.int32, specs["tokens"])
        return out
    if cfg.frontend == "patch_embeds":
        s_text = S - cfg.n_prefix
        out["patch_embeds"] = sds((B, cfg.n_prefix, cfg.d_model),
                                  jnp.bfloat16, specs["patch_embeds"])
        out["tokens"] = sds((B, s_text), jnp.int32, specs["tokens"])
        out["labels"] = sds((B, s_text), jnp.int32, specs["labels"])
    elif cfg.frontend == "frame_embeds":
        out["frame_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16,
                                  specs["frame_embeds"])
        out["labels"] = sds((B, S), jnp.int32, specs["labels"])
    else:
        out["tokens"] = sds((B, S), jnp.int32, specs["tokens"])
        out["labels"] = sds((B, S), jnp.int32, specs["labels"])
    if shape.kind == "prefill":
        out.pop("labels", None)
    return out


def _with_sharding(tree_shapes, tree_specs, mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree_shapes, tree_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def state_specs(cfg: ModelConfig, mesh):
    """TrainState ShapeDtypeStructs with shardings (no allocation)."""
    shapes = jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    p_spec = shd.param_specs(cfg, shapes.params, mesh)
    m_spec = shd.param_specs(cfg, shapes.opt.m, mesh)
    v_spec = shd.param_specs(cfg, shapes.opt.v, mesh)
    specs = type(shapes)(params=p_spec,
                         opt=type(shapes.opt)(m=m_spec, v=v_spec,
                                              step=P()))
    return _with_sharding(shapes, specs, mesh), specs


def params_specs_only(cfg: ModelConfig, mesh):
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    spec = shd.param_specs(cfg, shapes, mesh)
    return _with_sharding(shapes, spec, mesh), spec


def cache_specs_in(cfg: ModelConfig, mesh, B: int, T: int):
    shapes = jax.eval_shape(lambda: transformer.init_cache(cfg, B, T))
    spec = shd.cache_specs(cfg, shapes, mesh, B)
    return _with_sharding(shapes, spec, mesh), spec


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------
def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               step_cfg: StepConfig = StepConfig()):
    """Returns (lowered, n_devices)."""
    dp = shd.data_axes(mesh)
    if shape.kind == "train":
        state_sds, _ = state_specs(cfg, mesh)
        batch_sds = input_specs(cfg, shape, mesh)
        step = make_train_step(cfg, OptimizerConfig(), step_cfg,
                               mesh=mesh, dp=dp)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(0,)).lower(
                state_sds, batch_sds)
        return lowered

    params_sds, _ = params_specs_only(cfg, mesh)
    if shape.kind == "prefill":
        batch_sds = input_specs(cfg, shape, mesh)
        prefill_step = make_prefill_step(cfg, mesh=mesh, dp=dp)
        with mesh:
            lowered = jax.jit(prefill_step).lower(params_sds, batch_sds)
        return lowered

    # decode: one token against a seq_len-deep cache
    batch_sds = input_specs(cfg, shape, mesh)
    cache_sds, _ = cache_specs_in(cfg, mesh, shape.global_batch,
                                  shape.seq_len)
    decode = make_decode_step(cfg, mesh=mesh, dp=dp)
    clen = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    with mesh:
        lowered = jax.jit(decode, donate_argnums=(2,)).lower(
            params_sds, batch_sds["tokens"], cache_sds, clen)
    return lowered


def probe_depths(cfg: ModelConfig) -> tuple:
    """(k1, k2) unrolled probe depths for cost extrapolation (see
    roofline.from_probes).  Chosen so the scanned-stack pattern repeats an
    integer number of times where possible."""
    if cfg.family == "hybrid":
        return (cfg.attn_every, 2 * cfg.attn_every)
    if cfg.first_dense:
        return (cfg.first_dense + 2, cfg.first_dense + 4)
    return (2, 4)


def probe_costs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                step_cfg: StepConfig) -> roofline.RooflineTerms:
    """Two shallow unrolled lowerings -> depth-extrapolated roofline terms."""
    k1, k2 = probe_depths(cfg)
    costs = []
    for k in (k1, k2):
        cfg_k = dataclasses.replace(cfg, n_layers=k, scan_layers=False)
        compiled = lower_cell(cfg_k, shape, mesh, step_cfg).compile()
        costs.append(roofline.raw_costs(compiled, compiled.as_text()))
        del compiled
    return roofline.from_probes(costs[0], costs[1], k1, k2, cfg.n_layers,
                                mesh.size,
                                roofline.model_flops_for(cfg, shape))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             step_cfg: StepConfig = StepConfig(),
             cfg: ModelConfig | None = None) -> Dict[str, Any]:
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    # 1) deploy lowering: full depth, scanned layers -> compile proof +
    #    memory analysis (the "it fits and it shards" evidence)
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, step_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # 2) probe lowerings: shallow unrolled -> cost-exact roofline terms
    hlo = compiled.as_text()
    terms = probe_costs(cfg, shape, mesh, step_cfg)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        }
    except Exception as e:                       # pragma: no cover
        mem_info = {"error": str(e)}

    n_dev = mesh.size
    per_dev_gb = ((mem_info.get("argument_size_bytes", 0)
                   + mem_info.get("temp_size_bytes", 0)) / 2 ** 30)
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": terms.flops, "hbm_bytes": terms.hbm_bytes,
        "coll_bytes_per_dev": terms.coll_bytes,
        "coll_breakdown": terms.coll_breakdown,
        "t_compute": terms.t_compute, "t_memory": terms.t_memory,
        "t_collective": terms.t_collective,
        "bottleneck": terms.bottleneck,
        "model_flops": terms.model_flops,
        "useful_ratio": round(terms.useful_ratio, 4),
        "memory_analysis": mem_info,
        "approx_bytes_per_device_gb": round(per_dev_gb, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sp", default=None, choices=["off", "attn", "full"],
                    help="seq_parallel override (EXPERIMENTS.md §Perf)")
    ap.add_argument("--moe", default=None, choices=["psum", "a2a"],
                    help="MoE dispatch override")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    step_cfg = StepConfig(n_microbatches=args.microbatches)

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "multi" if mp else "single")
                if key in done:
                    print(f"[cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    cfg = get_config(arch)
                    if args.sp:
                        cfg = dataclasses.replace(cfg, seq_parallel=args.sp)
                    if args.moe:
                        cfg = dataclasses.replace(cfg, moe_impl=args.moe)
                    r = run_cell(arch, shape_name, mp, step_cfg, cfg=cfg)
                    if args.sp or args.moe:
                        r["overrides"] = {"sp": args.sp, "moe": args.moe}
                except Exception as e:
                    r = {"arch": arch, "shape": shape_name,
                         "mesh": key[2], "status": "error",
                         "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                results = [x for x in results
                           if (x["arch"], x["shape"], x["mesh"]) != key]
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = r["status"]
                extra = (f" bottleneck={r.get('bottleneck')} "
                         f"t=({r.get('t_compute', 0):.4f},"
                         f"{r.get('t_memory', 0):.4f},"
                         f"{r.get('t_collective', 0):.4f})s "
                         f"useful={r.get('useful_ratio')}"
                         if status == "ok" else
                         r.get("reason", r.get("error", "")))
                print(f"[{status}] {key} {extra}", flush=True)
                jax.clear_caches()

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
