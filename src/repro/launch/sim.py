"""DCSim CLI: run the paper's container-scheduling simulation.

    PYTHONPATH=src python -m repro.launch.sim --policy jobgroup --horizon 120
    PYTHONPATH=src python -m repro.launch.sim --policy netaware --bw 200
    PYTHONPATH=src python -m repro.launch.sim --policy all --bw 200 --loss 0.02
    PYTHONPATH=src python -m repro.launch.sim --policy all --hosts 500 \\
        --containers 3000 --horizon 40 --out reports.json

With policies as weight vectors, ``--policy all`` is six runs of ONE
compiled program over ONE prebuilt state — no per-policy rebuild, no
per-policy compile — and ``--weights name=value,...`` runs a by-name
weight variant (``types.WEIGHT_NAMES``) through the same executable:

    PYTHONPATH=src python -m repro.launch.sim --policy netaware \\
        --weights cross_leaf=0.5,row_coloc=0.3

The full policy x scenario x seed grid lives in ``repro.launch.sweep``;
weight *search* lives in ``repro.launch.tune``.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (ExecPlan, SimConfig, build_paper_hosts,
                        build_paper_network, get_policy, init_sim,
                        list_policies, paper_workload, run_sim, scaled_hosts,
                        summarize, to_csv, trace_workload)
from repro.core.report import json_clean
from repro.launch.execargs import add_exec_args


def build_once(cfg: SimConfig, bw=None, loss=None, seed=0, workload="paper",
               n_hosts=20):
    """Hosts + network + workload + initial state, built ONCE and reused
    for every policy: the policy is data, the state is shared.  The bw/loss
    overrides ride the RunParams (applied at t=0 inside the run) instead of
    mutating the built network per policy."""
    # same domain checks as set_link_params/ScenarioSpec: values inside the
    # RunParams keep-sentinel range must fail loudly, not silently no-op
    if bw is not None and bw <= 0:
        raise ValueError(f"--bw must be > 0 Mbps, got {bw}")
    if loss is not None and loss < 0:
        raise ValueError(f"--loss must be >= 0, got {loss}")
    hosts = (build_paper_hosts() if n_hosts == 20
             else scaled_hosts(n_hosts, max(4, n_hosts // 5)))
    spec, net = build_paper_network(cfg, n_hosts=n_hosts,
                                    n_leaf=max(4, n_hosts // 5))
    gen = paper_workload if workload == "paper" else trace_workload
    sim0 = init_sim(hosts, gen(cfg, seed=seed), net, seed=seed)
    params = cfg.run_params()._replace(
        **{k: v for k, v in
           (("bw_mbps", bw), ("loss", loss)) if v is not None})
    return spec, sim0, params


def parse_weights(arg: str | None) -> dict[str, float] | None:
    """``"cross_leaf=0.5,row_coloc=0.3"`` -> by-name override dict
    (validated against ``types.WEIGHT_NAMES`` by ``get_policy``)."""
    if not arg:
        return None
    out = {}
    for item in arg.split(","):
        name, _, val = item.partition("=")
        if not _:
            raise ValueError(f"--weights items must be name=value, "
                             f"got {item!r}")
        out[name.strip()] = float(val)
    return out


def run_one(policy_name: str, cfg: SimConfig, spec, sim0, params, csv=None,
            weights=None, plan: ExecPlan | None = None):
    from repro.kernels import kernel_backend, resolve_kernel
    plan = ExecPlan() if plan is None else plan
    if csv and plan.chunk is not None:
        raise ValueError("--csv needs the stacked per-tick series; "
                         "drop --chunk to export one")
    if csv and plan.telescope:
        raise ValueError("--csv needs the stacked per-tick series; "
                         "telescoping skips quiescent ticks and keeps only "
                         "online summaries — drop --telescope to export one")
    t0 = time.time()
    final, metrics = run_sim(sim0, cfg, get_policy(policy_name, weights),
                             spec.n_hosts, spec.n_nodes, cfg.horizon,
                             params=params, plan=plan)
    final.t.block_until_ready()
    rep = summarize(final, metrics)   # metrics: stack OR OnlineSummary
    rep["policy"] = policy_name
    rep["wall_s"] = round(time.time() - t0, 2)
    # self-describing rows: which backend ran this, and whether the delay /
    # waterfill hot paths went through their Pallas kernels (flag + what it
    # resolved to on this backend)
    rep["backend"] = kernel_backend()
    rep["delay_mode"] = cfg.delay_mode
    rep["delay_kernel"] = cfg.delay_kernel
    rep["delay_kernel_active"] = (cfg.delay_mode == "fw"
                                  and resolve_kernel(cfg.delay_kernel))
    rep["waterfill_kernel"] = cfg.waterfill_kernel
    rep["waterfill_kernel_active"] = (cfg.sparse_flows
                                      and resolve_kernel(cfg.waterfill_kernel))
    if csv:
        to_csv(metrics, csv)
    return rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="all",
                    help=f"one of {list_policies()} or 'all'")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20,
                    help="fleet size (paper Table 5 mix, scaled)")
    ap.add_argument("--containers", type=int, default=None,
                    help="workload size (containers; jobs/tasks scale along)")
    ap.add_argument("--bw", type=float, default=None, help="link Mbps")
    ap.add_argument("--loss", type=float, default=None,
                    help="link loss fraction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workload", default="paper",
                    choices=["paper", "trace"])
    ap.add_argument("--csv", default=None, help="per-tick metrics CSV path "
                    "(stacked mode only — incompatible with --chunk)")
    ap.add_argument("--out", default=None,
                    help="write the summary reports as a JSON list")
    ap.add_argument("--sequential", action="store_true",
                    help="run the sequential reference placement path "
                         "instead of the batched round")
    ap.add_argument("--delay-mode", default="path", choices=["path", "fw"],
                    help="delay refresh: ECMP path sum or full APSP "
                         "(the fw_minplus kernel's algebra)")
    # one run = no grid: the slab/devices/dist ExecPlan flags don't apply
    # (argparse rejects them loudly); --chunk + kernel selectors do
    add_exec_args(ap, slab=False, devices=False, overlap=False)
    ap.add_argument("--weights", default=None,
                    help="by-name weight overrides for the chosen policy, "
                         "e.g. 'cross_leaf=0.5,row_coloc=0.3' "
                         "(types.WEIGHT_NAMES; not valid with --policy all)")
    args = ap.parse_args()

    weights = parse_weights(args.weights)
    if weights and args.policy == "all":
        raise SystemExit("--weights needs a single --policy to override")

    wl = ({} if args.containers is None else
          dict(n_containers=args.containers, n_tasks=args.containers,
               n_jobs=max(10, args.containers // 3)))
    cfg = SimConfig(horizon=args.horizon,
                    batched_placement=not args.sequential,
                    delay_mode=args.delay_mode, **wl)
    plan = ExecPlan.from_args(args)
    cfg = plan.apply_to_config(cfg)
    spec, sim0, params = build_once(cfg, bw=args.bw, loss=args.loss,
                                    seed=args.seed, workload=args.workload,
                                    n_hosts=args.hosts)
    policies = list_policies() if args.policy == "all" else [args.policy]
    reports = []
    for p in policies:
        rep = json_clean(run_one(p, cfg, spec, sim0, params, csv=args.csv,
                                 weights=weights, plan=plan))
        reports.append(rep)
        print(json.dumps(rep, indent=None, sort_keys=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)


if __name__ == "__main__":
    main()
