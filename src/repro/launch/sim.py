"""DCSim CLI: run the paper's container-scheduling simulation.

    PYTHONPATH=src python -m repro.launch.sim --policy jobgroup --horizon 120
    PYTHONPATH=src python -m repro.launch.sim --policy netaware --bw 200
    PYTHONPATH=src python -m repro.launch.sim --policy all --bw 200 --loss 0.02
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, list_policies, paper_workload,
                        run_sim, summarize, to_csv, trace_workload)
from repro.core.network import set_link_params


def run_one(policy_name: str, cfg: SimConfig, bw=None, loss=None, seed=0,
            workload="paper", n_hosts=20, csv=None):
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg, n_hosts=n_hosts)
    if bw is not None or loss is not None:
        net = set_link_params(net, bw=bw, loss=loss)
    gen = paper_workload if workload == "paper" else trace_workload
    sim0 = init_sim(hosts, gen(cfg, seed=seed), net, seed=seed)
    t0 = time.time()
    final, metrics = run_sim(sim0, cfg, get_policy(policy_name),
                             spec.n_hosts, spec.n_nodes, cfg.horizon)
    final.t.block_until_ready()
    rep = summarize(final, metrics)
    rep["policy"] = policy_name
    rep["wall_s"] = round(time.time() - t0, 2)
    if csv:
        to_csv(metrics, csv)
    return rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="all",
                    help=f"one of {list_policies()} or 'all'")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--bw", type=float, default=None, help="link Mbps")
    ap.add_argument("--loss", type=float, default=None,
                    help="link loss fraction")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workload", default="paper",
                    choices=["paper", "trace"])
    ap.add_argument("--csv", default=None, help="per-tick metrics CSV path")
    ap.add_argument("--sequential", action="store_true",
                    help="run the sequential reference placement path "
                         "instead of the batched round")
    args = ap.parse_args()

    cfg = SimConfig(horizon=args.horizon,
                    batched_placement=not args.sequential)
    policies = list_policies() if args.policy == "all" else [args.policy]
    for p in policies:
        rep = run_one(p, cfg, bw=args.bw, loss=args.loss, seed=args.seed,
                      workload=args.workload, csv=args.csv)
        print(json.dumps(rep, indent=None, sort_keys=True))


if __name__ == "__main__":
    main()
