"""Weight search: learn scheduling-policy weights with the compiled sweep.

With branch-free scoring a policy IS a point in weight space
(``PolicyParams.weights``), so "learning a policy" degenerates to search:
sample W weight vectors, stack them on the sweep's policy axis, and run
the whole W x scenario x seed population as ONE jit — the same
``make_sweep_fn`` program the policy sweep uses, with weights instead of
named policies on the batch axis (and the same ``NamedSharding`` across
devices).  This is the ROADMAP "learned netaware weights" item in its
simplest honest form: random (or per-dimension grid) search, one
compilation, a ranked best-weights table via ``report.tune_table``.

    PYTHONPATH=src python -m repro.launch.tune --samples 16 --seeds 2 \\
        --objective avg_runtime --out tune.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimConfig, get_policy, sweep_summaries, tune_table
from repro.core import stats
from repro.core.engine import resolve_plan
from repro.core.scenario import ScenarioSpec, build_scenarios
from repro.core.scheduling import validate_weights, weight_index
from repro.core.types import (NUM_POLICY_WEIGHTS, WEIGHT_NAMES, ExecPlan,
                              PolicyParams)
from repro.launch.execargs import add_exec_args
from repro.launch.sweep import make_grad_fn, make_stream_fn, make_sweep_fn

# Default search space: the cost-model weights of the network-aware score
# plus the co-location / consolidation trade-off — the knobs the paper's
# comparison says matter.  Everything not named here keeps the base
# policy's value (FIFO selection, migration rule, ...).
DEFAULT_SPACE: dict[str, tuple[float, float]] = {
    "util": (0.0, 4.0),
    "cross_leaf": (0.0, 1.0),
    "row_comm": (0.0, 2.0),
    "row_coloc": (0.0, 2.0),
    "row_fallback_worst": (0.0, 2.0),
    "row_worst_fit": (0.0, 1.0),
    "row_cross_leaf": (0.0, 1.0),
}

# summary metrics where bigger is better — negated so "lower = better"
# holds for every objective
MAXIMIZE = {"completion_rate", "n_completed", "peak_running",
            "peak_deployed"}


def sample_weights(n: int, seed: int = 0, base: str = "netaware",
                   space: dict[str, tuple[float, float]] | None = None,
                   grid: bool = False) -> np.ndarray:
    """[n, NUM_POLICY_WEIGHTS] search population around a registered base.

    Random mode draws each searched dimension uniformly from its range;
    grid mode sweeps ONE dimension at a time over ``(n - 1) // len(space)``
    evenly spaced points per dimension (coordinate profile, not a full
    product — the honest grid at small budgets).  The grid points span
    ``(lo, hi]`` from the top: the lower bound is excluded (it is 0 =
    "feature off" for most ranges and often the base value itself), so a
    1-point-per-dimension budget tests ``hi``, not a duplicate of the
    incumbent.  Sample 0 is always the untouched base vector, so the
    incumbent appears in every ranking.
    """
    space = DEFAULT_SPACE if space is None else space
    idx = {name: weight_index(name) for name in space}   # loud on unknowns
    base_w = np.asarray(get_policy(base).weights, np.float32)
    W = np.tile(base_w, (n, 1))
    rng = np.random.default_rng(seed)
    if grid:
        names = list(space)
        per = max(1, (n - 1) // len(names))
        i = 1
        for name in names:
            lo, hi = space[name]
            for v in np.linspace(lo, hi, per + 1)[1:]:
                if i < n:
                    W[i, idx[name]] = v
                    i += 1
    else:
        for name, (lo, hi) in space.items():
            W[1:, idx[name]] = rng.uniform(lo, hi, n - 1)
    return W


@dataclasses.dataclass
class TuneResult:
    weights: np.ndarray       # [W, NUM_POLICY_WEIGHTS]
    scores: np.ndarray        # [W] TRUE objective values (NaN = failed)
    objective: str
    minimize: bool            # ranking direction (False for MAXIMIZE)
    rows: list[dict[str, Any]]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    wall_s: float             # first (cold: compile + run) call
    steady_s: float | None    # min warm repeat of the same compiled call
    compile_cache_misses: int
    n_devices: int

    def ranking(self) -> np.ndarray:
        """Sample indices best-first (NaN scores last either way)."""
        return np.argsort(self.scores if self.minimize else -self.scores)

    @property
    def best(self) -> int:
        return int(self.ranking()[0])

    def best_weights(self) -> dict[str, float]:
        return {name: float(v)
                for name, v in zip(WEIGHT_NAMES, self.weights[self.best])}

    def table(self, top: int = 10) -> str:
        return tune_table(self.weights, self.scores, self.objective,
                          top=top, minimize=self.minimize)


def _default_scenarios() -> list[ScenarioSpec]:
    return [ScenarioSpec("baseline"),
            ScenarioSpec("slow_net", bw=200.0),
            ScenarioSpec("bursty", arrival="bursty")]


def _mean_scores(fn, sims, W, rps, scenarios, seeds, objective):
    """Oracle-score a weight population: run the compiled sweep with the
    weights on the policy axis and mean the summary ``objective`` over
    every (scenario, seed) cell — (scores [W], summary rows)."""
    n = W.shape[0]
    finals, metrics = fn(sims, PolicyParams(weights=jnp.asarray(W)), rps)
    names = [f"w{i:03d}" for i in range(n)]
    rows = sweep_summaries(finals, metrics, names,
                           [s.name for s in scenarios], seeds)
    per = {name: [] for name in names}
    for r in rows:
        per[r["policy"]].append(float(r[objective]))
    return np.asarray([np.mean(per[name]) for name in names]), rows


def run_tune(n_samples: int = 16, seeds: Sequence[int] = (0,),
             scenarios: Sequence[ScenarioSpec] | None = None,
             cfg: SimConfig | None = None, n_hosts: int = 20,
             n_spine: int = 2, n_leaf: int = 4,
             objective: str = "avg_runtime", base: str = "netaware",
             space: dict[str, tuple[float, float]] | None = None,
             grid: bool = False, seed: int = 0,
             devices=None, reps: int = 1, chunk: int | None = None,
             slab: int | None = None, overlap: bool | None = None,
             procs: int | None = None, devices_per_proc: int | None = None,
             plan: ExecPlan | None = None) -> TuneResult:
    """One compiled call over the whole search population.

    The per-sample score is the objective's plain mean over every
    (scenario, seed) cell, reported in the metric's TRUE sign (the
    ranking direction comes from ``MAXIMIZE``) — a sample that fails the
    objective anywhere (e.g. completes nothing, NaN ``avg_runtime``)
    scores NaN and ranks last, deliberately NOT nan-skipped.

    ``reps > 1`` re-runs the SAME compiled call warm and records the
    minimum as ``steady_s`` — the runtime-dominated number the bench
    regression gate tracks (the first call's ``wall_s`` is mostly XLA
    compile on small grids).

    ``chunk`` streams the search through ``make_stream_fn`` — [W, S, N]
    summaries via online folds, never a [W, S, N, T] metrics stack, with
    the population optionally slabbed ``slab`` cells at a time (and, with
    ``overlap``, gathered one slab behind the async dispatch).  Scores
    match the stacked search to float precision (integer objectives
    exactly).

    A ``plan.procs > 1`` runs the streamed search MULTI-PROCESS through
    the distributed sweep fabric (``repro.launch.dist``): the weight
    population rides the same slab-per-process handout as a policy sweep
    (weights are just the policy batch axis), each process owning
    ``plan.devices_per_proc`` forced CPU devices locally or one
    accelerator process slot on a real fleet, and the partial summaries
    reduced with ``stats.online_merge``.  Requires ``plan.chunk``; scores
    are bit-identical to the single-process streamed search.

    Execution options ride in ``plan``; the bare ``devices``/``chunk``/
    ``slab``/``overlap``/``procs``/``devices_per_proc`` kwargs are
    deprecated (one cycle).
    """
    cfg = cfg or SimConfig()
    plan, cfg = resolve_plan(plan, cfg, devices=devices, chunk=chunk,
                             slab=slab, overlap=overlap, procs=procs,
                             devices_per_proc=devices_per_proc)
    scenarios = list(scenarios if scenarios is not None
                     else _default_scenarios())
    W = sample_weights(n_samples, seed=seed, base=base, space=space,
                       grid=grid)
    validate_weights(W, "tune samples: ")
    pol = PolicyParams(weights=jnp.asarray(W))
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    if plan.procs > 1:
        if plan.chunk is None:
            raise ValueError("procs > 1 requires chunk (the distributed "
                             "fabric streams slabs; there is no stacked "
                             "multi-process path)")
        if plan.telescope:
            raise ValueError("telescope is not threaded through the "
                             "multi-process fabric yet — drop procs or "
                             "telescope")
        from repro.launch.dist import make_dist_fn
        fn = make_dist_fn(cfg, scenarios, seeds, weights=W,
                          n_hosts=n_hosts, n_spine=n_spine, n_leaf=n_leaf,
                          plan=plan)
    elif plan.chunk is not None or plan.telescope:
        fn = make_stream_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                            cfg.horizon, chunk=plan.chunk or cfg.horizon,
                            slab=plan.slab, devices=plan.devices,
                            overlap=plan.overlap, telescope=plan.telescope)
    else:
        fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                           cfg.horizon, devices=plan.devices)
    def ready(x):   # streaming finals are already host-side numpy
        leaf = jax.tree.leaves(x)[0]
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()

    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)   # streaming: OnlineSummary
    ready(finals)
    wall = time.time() - t0
    steady = None
    if reps > 1:
        reruns = []
        for _ in range(reps - 1):
            t0 = time.time()
            ready(fn(sims, pol, rps)[0])
            reruns.append(time.time() - t0)
        steady = round(min(reruns), 2)

    names = [f"w{i:03d}" for i in range(n_samples)]
    rows = sweep_summaries(finals, metrics, names,
                           [s.name for s in scenarios], seeds)
    per = {n: [] for n in names}
    for r in rows:
        per[r["policy"]].append(float(r[objective]))
    scores = np.asarray([np.mean(per[n]) for n in names])
    return TuneResult(weights=W, scores=scores, objective=objective,
                      minimize=objective not in MAXIMIZE,
                      rows=rows, scenarios=scenarios, seeds=tuple(seeds),
                      wall_s=round(wall, 2), steady_s=steady,
                      compile_cache_misses=fn._cache_size(),
                      n_devices=fn.n_devices)


@dataclasses.dataclass
class GradTuneResult(TuneResult):
    """A :class:`TuneResult` (final population + ORACLE scores — the
    ranking/table surface is unchanged) plus the optimizer's trajectory:
    the overall-best oracle-scored candidate (never worse than the
    incumbent: the initial population, incumbent row 0 included, is
    oracle-scored before the first step) and the per-step history of the
    surrogate/oracle values — the honest view of how well descending the
    soft surrogate tracks the hard objective (docs/autodiff.md)."""

    method: str = "grad"
    surrogate: np.ndarray | None = None   # [M] final surrogate per candidate
    surrogate_name: str | None = None
    best_oracle: float = float("nan")     # best oracle score ever seen
    best_oracle_weights: np.ndarray | None = None
    history: list | None = None           # per-step dicts (step, tau, ...)
    surrogate_evals: int = 0              # candidate-evals spent on grad steps
    oracle_evals: int = 0                 # candidate-evals spent on re-scoring


def _space_bounds(space: dict[str, tuple[float, float]]):
    """(searched index array, mask [W], lo [W], hi [W]) — the gradient /
    sampling machinery only touches the searched dimensions."""
    idx = np.asarray([weight_index(name) for name in space], np.int64)
    mask = np.zeros((NUM_POLICY_WEIGHTS,), np.float32)
    lo = np.full((NUM_POLICY_WEIGHTS,), -np.inf, np.float32)
    hi = np.full((NUM_POLICY_WEIGHTS,), np.inf, np.float32)
    mask[idx] = 1.0
    for name, (a, b) in space.items():
        lo[weight_index(name)] = a
        hi[weight_index(name)] = b
    return idx, mask, lo, hi


def _make_oracle(cfg: SimConfig, net_spec, horizon: int, plan: ExecPlan):
    """The hard-placement scorer the grad/CEM loops re-score against —
    ``soft_placement`` OFF, so every score is the true simulator's."""
    hard = dataclasses.replace(cfg, soft_placement=False)
    if plan.chunk is not None or plan.telescope:
        # soft placement is OFF here, so the oracle may telescope even
        # though the surrogate descent itself stays per-tick (while_loop
        # has no reverse-mode autodiff — docs/events.md)
        return make_stream_fn(hard, net_spec.n_hosts, net_spec.n_nodes,
                              horizon, chunk=plan.chunk or horizon,
                              slab=plan.slab, devices=plan.devices,
                              overlap=plan.overlap,
                              telescope=plan.telescope)
    return make_sweep_fn(hard, net_spec.n_hosts, net_spec.n_nodes, horizon,
                         devices=plan.devices)


def run_tune_grad(steps: int = 24, batch: int = 8, lr: float = 0.1,
                  tau0: float = 1.0, tau_decay: float = 0.85,
                  tau_min: float = 0.05, eval_every: int = 6,
                  seeds: Sequence[int] = (0,),
                  scenarios: Sequence[ScenarioSpec] | None = None,
                  cfg: SimConfig | None = None, n_hosts: int = 20,
                  n_spine: int = 2, n_leaf: int = 4,
                  objective: str = "avg_runtime",
                  surrogate: str = "soft_blend", base: str = "netaware",
                  space: dict[str, tuple[float, float]] | None = None,
                  seed: int = 0,
                  plan: ExecPlan | None = None) -> GradTuneResult:
    """Gradient search: descend the DIFFERENTIABLE soft-placement
    surrogate, trust only the hard oracle.

    A batch of ``batch`` candidates (row 0 = the untouched ``base``
    incumbent) rides the policy axis of ONE compiled
    ``jax.value_and_grad`` sweep (``sweep.make_grad_fn``, built from a
    ``soft_placement=True`` twin of ``cfg``); each step applies plain
    gradient descent on the searched dimensions only (masked to
    ``space``, clipped to its bounds).  The softmax temperature anneals
    ``tau0 -> tau_min`` by ``tau_decay`` per step — ``tau`` is a traced
    ``RunParams`` field, so annealing never recompiles.

    The surrogate is a guide, not the objective: every ``eval_every``
    steps (and before the first, and after the last) the CURRENT
    candidates are re-scored on the hard oracle (``soft_placement=False``
    — bit-for-bit the production simulator) under the TRUE ``objective``,
    and the best-ever oracle candidate is tracked.  Because the incumbent
    is oracle-scored up front, the result never ranks below it.  Both
    trajectories land in ``history``; ``scores`` is the final
    population's oracle score so ``table()`` ranks real numbers.
    """
    cfg = cfg or SimConfig()
    plan = ExecPlan() if plan is None else plan
    cfg = plan.apply_to_config(cfg)
    if plan.procs > 1:
        raise ValueError("grad mode is single-process (the oracle rides "
                         "plan.chunk/devices; procs is random/grid only)")
    scenarios = list(scenarios if scenarios is not None
                     else _default_scenarios())
    space = DEFAULT_SPACE if space is None else space
    idx, mask, lo, hi = _space_bounds(space)
    minimize = objective not in MAXIMIZE
    better = (lambda a, b: a < b) if minimize else (lambda a, b: a > b)

    W = sample_weights(batch, seed=seed, base=base, space=space)
    validate_weights(W, "tune grad candidates: ")
    soft = dataclasses.replace(cfg, soft_placement=True)
    net_spec, sims, rps = build_scenarios(scenarios, soft, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    gfn = make_grad_fn(soft, net_spec.n_hosts, net_spec.n_nodes,
                       cfg.horizon, objective=surrogate, chunk=plan.chunk,
                       devices=plan.devices)
    ofn = _make_oracle(cfg, net_spec, cfg.horizon, plan)

    t_start = time.time()
    history: list[dict[str, Any]] = []
    surrogate_evals = 0
    scores, rows = _mean_scores(ofn, sims, W, rps, scenarios, seeds,
                                objective)
    oracle_evals = batch
    k = int(np.nanargmin(scores) if minimize else np.nanargmax(scores))
    best_score, best_w = float(scores[k]), W[k].copy()
    tau = float(tau0)
    for step in range(steps):
        rps_t = rps._replace(tau=jnp.full_like(rps.tau, tau))
        obj_s, g = gfn(sims, PolicyParams(weights=jnp.asarray(W)), rps_t)
        surrogate_evals += batch
        g = np.asarray(g, np.float32) * mask[None, :]
        W = np.clip(W - lr * g, lo[None, :], hi[None, :]).astype(np.float32)
        rec = {"step": step, "tau": round(tau, 6),
               "surrogate_mean": float(np.mean(np.asarray(obj_s))),
               "grad_norm": float(np.linalg.norm(g) / max(batch, 1))}
        if (step + 1) % eval_every == 0 or step == steps - 1:
            scores, rows = _mean_scores(ofn, sims, W, rps, scenarios,
                                        seeds, objective)
            oracle_evals += batch
            finite = np.isfinite(scores)
            if finite.any():
                k = int(np.nanargmin(scores) if minimize
                        else np.nanargmax(scores))
                if better(scores[k], best_score):
                    best_score, best_w = float(scores[k]), W[k].copy()
            rec["oracle_best"] = (float(np.nanmin(scores)) if minimize
                                  else float(np.nanmax(scores)))
        history.append(rec)
        tau = max(tau * tau_decay, tau_min)

    rps_t = rps._replace(tau=jnp.full_like(rps.tau, tau))
    final_sur, _ = gfn(sims, PolicyParams(weights=jnp.asarray(W)), rps_t)
    surrogate_evals += batch
    return GradTuneResult(
        weights=W, scores=scores, objective=objective, minimize=minimize,
        rows=rows, scenarios=scenarios, seeds=tuple(seeds),
        wall_s=round(time.time() - t_start, 2), steady_s=None,
        compile_cache_misses=gfn._cache_size() + ofn._cache_size(),
        n_devices=gfn.n_devices, method="grad",
        surrogate=np.asarray(final_sur), surrogate_name=surrogate,
        best_oracle=best_score, best_oracle_weights=best_w,
        history=history, surrogate_evals=surrogate_evals,
        oracle_evals=oracle_evals)


def run_tune_cem(steps: int = 6, batch: int = 16, elite_frac: float = 0.25,
                 init_std_frac: float = 0.3, seeds: Sequence[int] = (0,),
                 scenarios: Sequence[ScenarioSpec] | None = None,
                 cfg: SimConfig | None = None, n_hosts: int = 20,
                 n_spine: int = 2, n_leaf: int = 4,
                 objective: str = "avg_runtime", base: str = "netaware",
                 space: dict[str, tuple[float, float]] | None = None,
                 seed: int = 0,
                 plan: ExecPlan | None = None) -> GradTuneResult:
    """Cross-entropy search on the HARD oracle (no surrogate): iterate
    sample -> score -> refit a diagonal Gaussian to the elite fraction.
    Every population re-enters the one compiled sweep (same shapes), the
    incumbent is re-injected as row 0 each round, and the best-ever
    oracle candidate is tracked — the derivative-free arm the grad mode
    is compared against."""
    cfg = cfg or SimConfig()
    plan = ExecPlan() if plan is None else plan
    cfg = plan.apply_to_config(cfg)
    scenarios = list(scenarios if scenarios is not None
                     else _default_scenarios())
    space = DEFAULT_SPACE if space is None else space
    idx, _, lo, hi = _space_bounds(space)
    minimize = objective not in MAXIMIZE
    better = (lambda a, b: a < b) if minimize else (lambda a, b: a > b)

    base_w = np.asarray(get_policy(base).weights, np.float32)
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    ofn = _make_oracle(cfg, net_spec, cfg.horizon, plan)
    rng = np.random.default_rng(seed)
    mu = base_w[idx].astype(np.float64)
    sd = (hi[idx] - lo[idx]).astype(np.float64) * init_std_frac
    n_elite = max(1, int(round(batch * elite_frac)))

    t_start = time.time()
    history: list[dict[str, Any]] = []
    oracle_evals = 0
    best_score, best_w = float("inf") if minimize else -float("inf"), base_w
    W = scores = rows = None
    for step in range(steps):
        W = np.tile(base_w, (batch, 1))
        W[1:, idx] = np.clip(rng.normal(mu, sd, (batch - 1, idx.size)),
                             lo[idx], hi[idx])
        W = W.astype(np.float32)
        scores, rows = _mean_scores(ofn, sims, W, rps, scenarios, seeds,
                                    objective)
        oracle_evals += batch
        order = np.argsort(scores if minimize else -scores)
        elite = W[order[:n_elite]][:, idx].astype(np.float64)
        mu = elite.mean(axis=0)
        sd = np.maximum(elite.std(axis=0), 1e-3)
        k = int(order[0])
        if np.isfinite(scores[k]) and better(scores[k], best_score):
            best_score, best_w = float(scores[k]), W[k].copy()
        history.append({"step": step,
                        "oracle_best": (float(np.nanmin(scores)) if minimize
                                        else float(np.nanmax(scores))),
                        "mu": [round(float(v), 4) for v in mu],
                        "sd": [round(float(v), 4) for v in sd]})
    return GradTuneResult(
        weights=W, scores=scores, objective=objective, minimize=minimize,
        rows=rows, scenarios=scenarios, seeds=tuple(seeds),
        wall_s=round(time.time() - t_start, 2), steady_s=None,
        compile_cache_misses=ofn._cache_size(), n_devices=ofn.n_devices,
        method="cem", surrogate=None, surrogate_name=None,
        best_oracle=best_score, best_oracle_weights=best_w,
        history=history, surrogate_evals=0, oracle_evals=oracle_evals)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="random",
                    choices=["random", "grid", "grad", "cem"],
                    help="random/grid = one-shot population ranking; "
                         "grad = descend the soft-placement surrogate "
                         "with hard-oracle re-scoring; cem = "
                         "cross-entropy on the hard oracle")
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--objective", default="avg_runtime",
                    help="summary metric to optimize (lower = better; "
                         f"negated for {sorted(MAXIMIZE)})")
    ap.add_argument("--base", default="netaware",
                    help="registered policy the search perturbs")
    ap.add_argument("--grid", action="store_true",
                    help="(random/grid) coordinate-profile grid instead of "
                         "random draws")
    ap.add_argument("--seed", type=int, default=0, help="search RNG seed")
    g = ap.add_argument_group("grad / cem")
    g.add_argument("--steps", type=int, default=None,
                   help="optimizer steps (default: 24 grad, 6 cem)")
    g.add_argument("--batch", type=int, default=None,
                   help="candidates per step (default: 8 grad, 16 cem)")
    g.add_argument("--lr", type=float, default=0.1,
                   help="(grad) gradient-descent step size")
    g.add_argument("--tau0", type=float, default=1.0,
                   help="(grad) initial softmax temperature")
    g.add_argument("--tau-decay", type=float, default=0.85,
                   help="(grad) per-step temperature decay factor")
    g.add_argument("--tau-min", type=float, default=0.05,
                   help="(grad) temperature floor")
    g.add_argument("--eval-every", type=int, default=6,
                   help="(grad) hard-oracle re-scoring period in steps")
    g.add_argument("--surrogate", default="soft_blend",
                   choices=sorted(stats.SOFT_OBJECTIVES),
                   help="(grad) differentiable objective to descend")
    g.add_argument("--elite-frac", type=float, default=0.25,
                   help="(cem) elite fraction per refit")
    add_exec_args(ap, dist=True)
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--out", default=None,
                    help="write best weights + ranked samples as JSON")
    args = ap.parse_args()

    cfg = SimConfig(horizon=args.horizon)
    plan = ExecPlan.from_args(args)
    n_leaf = max(4, args.hosts // 5)
    common = dict(seeds=range(args.seeds), cfg=cfg, n_hosts=args.hosts,
                  n_spine=max(2, n_leaf // 4), n_leaf=n_leaf,
                  objective=args.objective, base=args.base, seed=args.seed,
                  plan=plan)
    if args.method == "grad":
        res = run_tune_grad(steps=args.steps or 24, batch=args.batch or 8,
                            lr=args.lr, tau0=args.tau0,
                            tau_decay=args.tau_decay, tau_min=args.tau_min,
                            eval_every=args.eval_every,
                            surrogate=args.surrogate, **common)
    elif args.method == "cem":
        res = run_tune_cem(steps=args.steps or 6, batch=args.batch or 16,
                           elite_frac=args.elite_frac, **common)
    else:
        res = run_tune(n_samples=args.samples,
                       grid=(args.method == "grid" or args.grid), **common)

    n_cand = res.weights.shape[0]
    cells = n_cand * len(res.scenarios) * len(res.seeds)
    print(f"# {args.method}: {cells} cells/eval ({n_cand} candidates x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s), "
          f"{res.n_devices} device(s)")
    if isinstance(res, GradTuneResult):
        arrow = "min" if res.minimize else "max"
        print(f"# best oracle {res.objective} ({arrow}): "
              f"{res.best_oracle:.4f} after {res.oracle_evals} oracle + "
              f"{res.surrogate_evals} surrogate evals")
        if res.method == "grad" and res.history:
            taus = [h["tau"] for h in res.history]
            print(f"# tau annealed {taus[0]:g} -> {taus[-1]:g} "
                  f"({res.surrogate_name} surrogate)")
    print(res.table(args.top))
    if args.out:
        from repro.core.report import json_clean
        out = {"method": args.method,
               "objective": res.objective,
               "best_sample": res.best,
               "best_weights": res.best_weights(),
               "scores": json_clean(list(map(float, res.scores))),
               "weights": [list(map(float, w)) for w in res.weights]}
        if isinstance(res, GradTuneResult):
            out["best_oracle"] = res.best_oracle
            if res.best_oracle_weights is not None:
                out["best_oracle_weights"] = dict(
                    zip(WEIGHT_NAMES,
                        map(float, res.best_oracle_weights)))
            out["history"] = res.history
        with open(args.out, "w") as f:
            json.dump(json_clean(out), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
