"""Weight search: learn scheduling-policy weights with the compiled sweep.

With branch-free scoring a policy IS a point in weight space
(``PolicyParams.weights``), so "learning a policy" degenerates to search:
sample W weight vectors, stack them on the sweep's policy axis, and run
the whole W x scenario x seed population as ONE jit — the same
``make_sweep_fn`` program the policy sweep uses, with weights instead of
named policies on the batch axis (and the same ``NamedSharding`` across
devices).  This is the ROADMAP "learned netaware weights" item in its
simplest honest form: random (or per-dimension grid) search, one
compilation, a ranked best-weights table via ``report.tune_table``.

    PYTHONPATH=src python -m repro.launch.tune --samples 16 --seeds 2 \\
        --objective avg_runtime --out tune.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimConfig, get_policy, sweep_summaries, tune_table
from repro.core.scenario import ScenarioSpec, build_scenarios
from repro.core.scheduling import validate_weights, weight_index
from repro.core.types import WEIGHT_NAMES, PolicyParams
from repro.launch.sweep import make_stream_fn, make_sweep_fn

# Default search space: the cost-model weights of the network-aware score
# plus the co-location / consolidation trade-off — the knobs the paper's
# comparison says matter.  Everything not named here keeps the base
# policy's value (FIFO selection, migration rule, ...).
DEFAULT_SPACE: dict[str, tuple[float, float]] = {
    "util": (0.0, 4.0),
    "cross_leaf": (0.0, 1.0),
    "row_comm": (0.0, 2.0),
    "row_coloc": (0.0, 2.0),
    "row_fallback_worst": (0.0, 2.0),
    "row_worst_fit": (0.0, 1.0),
    "row_cross_leaf": (0.0, 1.0),
}

# summary metrics where bigger is better — negated so "lower = better"
# holds for every objective
MAXIMIZE = {"completion_rate", "n_completed", "peak_running",
            "peak_deployed"}


def sample_weights(n: int, seed: int = 0, base: str = "netaware",
                   space: dict[str, tuple[float, float]] | None = None,
                   grid: bool = False) -> np.ndarray:
    """[n, NUM_POLICY_WEIGHTS] search population around a registered base.

    Random mode draws each searched dimension uniformly from its range;
    grid mode sweeps ONE dimension at a time over ``(n - 1) // len(space)``
    evenly spaced points per dimension (coordinate profile, not a full
    product — the honest grid at small budgets).  The grid points span
    ``(lo, hi]`` from the top: the lower bound is excluded (it is 0 =
    "feature off" for most ranges and often the base value itself), so a
    1-point-per-dimension budget tests ``hi``, not a duplicate of the
    incumbent.  Sample 0 is always the untouched base vector, so the
    incumbent appears in every ranking.
    """
    space = DEFAULT_SPACE if space is None else space
    idx = {name: weight_index(name) for name in space}   # loud on unknowns
    base_w = np.asarray(get_policy(base).weights, np.float32)
    W = np.tile(base_w, (n, 1))
    rng = np.random.default_rng(seed)
    if grid:
        names = list(space)
        per = max(1, (n - 1) // len(names))
        i = 1
        for name in names:
            lo, hi = space[name]
            for v in np.linspace(lo, hi, per + 1)[1:]:
                if i < n:
                    W[i, idx[name]] = v
                    i += 1
    else:
        for name, (lo, hi) in space.items():
            W[1:, idx[name]] = rng.uniform(lo, hi, n - 1)
    return W


@dataclasses.dataclass
class TuneResult:
    weights: np.ndarray       # [W, NUM_POLICY_WEIGHTS]
    scores: np.ndarray        # [W] TRUE objective values (NaN = failed)
    objective: str
    minimize: bool            # ranking direction (False for MAXIMIZE)
    rows: list[dict[str, Any]]
    scenarios: list[ScenarioSpec]
    seeds: tuple[int, ...]
    wall_s: float             # first (cold: compile + run) call
    steady_s: float | None    # min warm repeat of the same compiled call
    compile_cache_misses: int
    n_devices: int

    def ranking(self) -> np.ndarray:
        """Sample indices best-first (NaN scores last either way)."""
        return np.argsort(self.scores if self.minimize else -self.scores)

    @property
    def best(self) -> int:
        return int(self.ranking()[0])

    def best_weights(self) -> dict[str, float]:
        return {name: float(v)
                for name, v in zip(WEIGHT_NAMES, self.weights[self.best])}

    def table(self, top: int = 10) -> str:
        return tune_table(self.weights, self.scores, self.objective,
                          top=top, minimize=self.minimize)


def run_tune(n_samples: int = 16, seeds: Sequence[int] = (0,),
             scenarios: Sequence[ScenarioSpec] | None = None,
             cfg: SimConfig | None = None, n_hosts: int = 20,
             n_spine: int = 2, n_leaf: int = 4,
             objective: str = "avg_runtime", base: str = "netaware",
             space: dict[str, tuple[float, float]] | None = None,
             grid: bool = False, seed: int = 0,
             devices=None, reps: int = 1, chunk: int | None = None,
             slab: int | None = None, overlap: bool = True,
             procs: int = 1, devices_per_proc: int = 1) -> TuneResult:
    """One compiled call over the whole search population.

    The per-sample score is the objective's plain mean over every
    (scenario, seed) cell, reported in the metric's TRUE sign (the
    ranking direction comes from ``MAXIMIZE``) — a sample that fails the
    objective anywhere (e.g. completes nothing, NaN ``avg_runtime``)
    scores NaN and ranks last, deliberately NOT nan-skipped.

    ``reps > 1`` re-runs the SAME compiled call warm and records the
    minimum as ``steady_s`` — the runtime-dominated number the bench
    regression gate tracks (the first call's ``wall_s`` is mostly XLA
    compile on small grids).

    ``chunk`` streams the search through ``make_stream_fn`` — [W, S, N]
    summaries via online folds, never a [W, S, N, T] metrics stack, with
    the population optionally slabbed ``slab`` cells at a time (and, with
    ``overlap``, gathered one slab behind the async dispatch).  Scores
    match the stacked search to float precision (integer objectives
    exactly).

    ``procs > 1`` runs the streamed search MULTI-PROCESS through the
    distributed sweep fabric (``repro.launch.dist``): the weight
    population rides the same slab-per-process handout as a policy sweep
    (weights are just the policy batch axis), each process owning
    ``devices_per_proc`` forced CPU devices locally or one accelerator
    process slot on a real fleet, and the partial summaries reduced with
    ``stats.online_merge``.  Requires ``chunk``; scores are bit-identical
    to the single-process streamed search.
    """
    cfg = cfg or SimConfig()
    scenarios = list(scenarios if scenarios is not None else [
        ScenarioSpec("baseline"),
        ScenarioSpec("slow_net", bw=200.0),
        ScenarioSpec("bursty", arrival="bursty"),
    ])
    W = sample_weights(n_samples, seed=seed, base=base, space=space,
                       grid=grid)
    validate_weights(W, "tune samples: ")
    pol = PolicyParams(weights=jnp.asarray(W))
    net_spec, sims, rps = build_scenarios(scenarios, cfg, n_hosts=n_hosts,
                                          n_spine=n_spine, n_leaf=n_leaf,
                                          seeds=seeds)
    if procs > 1:
        if chunk is None:
            raise ValueError("procs > 1 requires chunk (the distributed "
                             "fabric streams slabs; there is no stacked "
                             "multi-process path)")
        from repro.launch.dist import make_dist_fn
        fn = make_dist_fn(cfg, scenarios, seeds, weights=W,
                          n_hosts=n_hosts, n_spine=n_spine, n_leaf=n_leaf,
                          num_procs=procs, devices_per_proc=devices_per_proc,
                          chunk=chunk, slab=slab, overlap=overlap)
    elif chunk is not None:
        fn = make_stream_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                            cfg.horizon, chunk=chunk, slab=slab,
                            devices=devices, overlap=overlap)
    else:
        fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                           cfg.horizon, devices=devices)
    def ready(x):   # streaming finals are already host-side numpy
        leaf = jax.tree.leaves(x)[0]
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()

    t0 = time.time()
    finals, metrics = fn(sims, pol, rps)   # streaming: OnlineSummary
    ready(finals)
    wall = time.time() - t0
    steady = None
    if reps > 1:
        reruns = []
        for _ in range(reps - 1):
            t0 = time.time()
            ready(fn(sims, pol, rps)[0])
            reruns.append(time.time() - t0)
        steady = round(min(reruns), 2)

    names = [f"w{i:03d}" for i in range(n_samples)]
    rows = sweep_summaries(finals, metrics, names,
                           [s.name for s in scenarios], seeds)
    per = {n: [] for n in names}
    for r in rows:
        per[r["policy"]].append(float(r[objective]))
    scores = np.asarray([np.mean(per[n]) for n in names])
    return TuneResult(weights=W, scores=scores, objective=objective,
                      minimize=objective not in MAXIMIZE,
                      rows=rows, scenarios=scenarios, seeds=tuple(seeds),
                      wall_s=round(wall, 2), steady_s=steady,
                      compile_cache_misses=fn._cache_size(),
                      n_devices=fn.n_devices)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--seeds", type=int, default=1,
                    help="number of seeds (0..n-1) per cell")
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--objective", default="avg_runtime",
                    help="summary metric to optimize (lower = better; "
                         f"negated for {sorted(MAXIMIZE)})")
    ap.add_argument("--base", default="netaware",
                    help="registered policy the search perturbs")
    ap.add_argument("--grid", action="store_true",
                    help="coordinate-profile grid instead of random draws")
    ap.add_argument("--seed", type=int, default=0, help="search RNG seed")
    ap.add_argument("--chunk", type=int, default=None,
                    help="stream the horizon in chunks with online "
                         "summaries (O(state) memory)")
    ap.add_argument("--slab", type=int, default=None,
                    help="with --chunk: population slab size in cells")
    ap.add_argument("--no-overlap", action="store_true",
                    help="with --chunk: synchronous slab gathers")
    ap.add_argument("--procs", type=int, default=1,
                    help="with --chunk: run the search across this many "
                         "jax.distributed processes (repro.launch.dist)")
    ap.add_argument("--devices-per-proc", type=int, default=1,
                    help="forced CPU devices per process (--procs)")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--out", default=None,
                    help="write best weights + ranked samples as JSON")
    args = ap.parse_args()

    cfg = SimConfig(horizon=args.horizon)
    n_leaf = max(4, args.hosts // 5)
    res = run_tune(n_samples=args.samples, seeds=range(args.seeds),
                   cfg=cfg, n_hosts=args.hosts,
                   n_spine=max(2, n_leaf // 4), n_leaf=n_leaf,
                   objective=args.objective, base=args.base,
                   grid=args.grid, seed=args.seed, chunk=args.chunk,
                   slab=args.slab, overlap=not args.no_overlap,
                   procs=args.procs, devices_per_proc=args.devices_per_proc)
    cells = args.samples * len(res.scenarios) * len(res.seeds)
    print(f"# {cells} cells ({args.samples} weight samples x "
          f"{len(res.scenarios)} scenarios x {len(res.seeds)} seeds) in "
          f"{res.wall_s}s, {res.compile_cache_misses} compilation(s), "
          f"{res.n_devices} device(s)")
    print(res.table(args.top))
    if args.out:
        from repro.core.report import json_clean
        out = {"objective": res.objective,
               "best_sample": res.best,
               "best_weights": res.best_weights(),
               "scores": json_clean(list(map(float, res.scores))),
               "weights": [list(map(float, w)) for w in res.weights]}
        with open(args.out, "w") as f:
            json.dump(json_clean(out), f, indent=1)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
