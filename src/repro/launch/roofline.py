"""Roofline-term extraction from a compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed out of the optimized HLO text (cost_analysis does not expose them)
with ring-algorithm wire-byte multipliers per op kind.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

# --- TPU v5e hardware constants -------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip injection budget)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# one tuple-typed or plain-typed result, e.g.
#   %ag = bf16[8,128]{1,0} all-gather(...)  or  (bf16[..], u32[]) all-reduce-start
_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|ragged-all-to-all)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[\d+,\d+\]<=)")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    # iota form: replica_groups=[G,S]<=[...] -> S members per group
    dims = re.match(r"\[(\d+),(\d+)\]<=", g)
    return int(dims.group(2)) if dims else 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring-algorithm model).

    all-reduce: 2(n-1)/n x buffer; all-gather: (n-1)/n x result;
    reduce-scatter: (n-1) x result (operand = n x result);
    all-to-all: (n-1)/n x buffer; collective-permute: 1 x buffer.
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("shapes"))
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * size
        elif op == "all-gather":
            wire = (n - 1) / n * size
        elif op == "reduce-scatter":
            wire = float(n - 1) * size
        elif op in ("all-to-all", "ragged-all-to-all"):
            wire = (n - 1) / n * size
        else:  # collective-permute
            wire = float(size)
        out[op] = out.get(op, 0.0) + wire
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class RooflineTerms:
    """All quantities are PER DEVICE: ``compiled.cost_analysis()`` describes
    the SPMD-partitioned per-partition module (verified: num_partitions=256
    in the entry layout, flops scale with 1/partitions)."""

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device wire bytes
    n_devices: int
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0     # whole-step model flops (all devices)
    useful_ratio: float = 0.0    # model_flops / (flops * n_devices)
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def finalize(self) -> "RooflineTerms":
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.hbm_bytes / HBM_BW
        self.t_collective = self.coll_bytes / ICI_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops:
            self.useful_ratio = self.model_flops / max(
                self.flops * self.n_devices, 1.0)
        return self


def raw_costs(compiled, hlo_text: str) -> Dict[str, float]:
    """Per-device (flops, bytes, collective bytes + breakdown) of one
    compiled executable — no loop-body correction (see dryrun probes)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    coll = collective_bytes(hlo_text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total"],
        "coll_breakdown": coll,
    }


def analyze(compiled, hlo_text: str, n_devices: int,
            model_flops: float = 0.0) -> RooflineTerms:
    c = raw_costs(compiled, hlo_text)
    return RooflineTerms(
        flops=c["flops"], hbm_bytes=c["hbm_bytes"],
        coll_bytes=c["coll_bytes"], n_devices=n_devices,
        model_flops=model_flops, coll_breakdown=c["coll_breakdown"],
    ).finalize()


def from_probes(c1: Dict, c2: Dict, k1: int, k2: int, L: int,
                n_devices: int, model_flops: float = 0.0) -> RooflineTerms:
    """Linear depth-extrapolation of two shallow UNROLLED probe lowerings.

    Scanned (deploy) programs hide per-layer cost inside a while body that
    HloCostAnalysis counts once; fully unrolled programs are cost-exact but
    compile in O(L) (minutes at 256 devices).  For a homogeneous stack,
    cost(L) is affine in L, so two shallow unrolled probes k1 < k2 recover
    slope + intercept exactly:  cost(L) = c1 + (c2-c1)/(k2-k1) * (L-k1).
    """
    def extrap(a, b):
        return a + (b - a) / (k2 - k1) * (L - k1)

    coll = {k: extrap(c1["coll_breakdown"].get(k, 0.0),
                      c2["coll_breakdown"].get(k, 0.0))
            for k in set(c1["coll_breakdown"]) | set(c2["coll_breakdown"])}
    return RooflineTerms(
        flops=extrap(c1["flops"], c2["flops"]),
        hbm_bytes=extrap(c1["hbm_bytes"], c2["hbm_bytes"]),
        coll_bytes=extrap(c1["coll_bytes"], c2["coll_bytes"]),
        n_devices=n_devices, model_flops=model_flops,
        coll_breakdown=coll,
    ).finalize()


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) per step; decode
    steps process one token per sequence."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 tok/seq
