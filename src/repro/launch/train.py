"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (CPU: 1-device mesh with the production axis
names, so the same sharding code paths execute).  Integrates: deterministic
data pipeline, AdamW train step, checkpoint cadence + restore-on-start, and
the fault supervisor (heartbeat + straggler bookkeeping for the launcher).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, make_dataset
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import (FaultConfig, HeartbeatMonitor,
                                     StragglerDetector, TrainingSupervisor)
from repro.launch.mesh import make_mesh_for
from repro.models import sharding as shd
from repro.train.optimizer import OptimizerConfig
from repro.train.step import StepConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--sp", default=None, choices=["off", "attn", "full"],
                    help="sequence parallelism (EXPERIMENTS.md §Perf)")
    ap.add_argument("--moe", default=None, choices=["psum", "a2a"])
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    import dataclasses
    if args.sp:
        cfg = dataclasses.replace(cfg, seq_parallel=args.sp)
    if args.moe:
        cfg = dataclasses.replace(cfg, moe_impl=args.moe)
    mesh = make_mesh_for(jax.device_count(), args.model_parallel)
    dp = shd.data_axes(mesh)

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps)
    step_cfg = StepConfig(n_microbatches=args.microbatches)
    train_step = make_train_step(cfg, opt_cfg, step_cfg, mesh=mesh, dp=dp)

    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    if mesh.size > 1:
        p_spec = shd.param_specs(cfg, state.params, mesh)
        shardings = type(state)(
            params=shd.to_shardings(p_spec, mesh),
            opt=type(state.opt)(
                m=shd.to_shardings(p_spec, mesh),
                v=shd.to_shardings(p_spec, mesh),
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())))
        state = jax.device_put(state, shardings)

    data = make_dataset(DataConfig(
        seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
        seed=args.seed, frontend=cfg.frontend, n_prefix=cfg.n_prefix,
        d_model=cfg.d_model))

    start_step = 0
    if args.ckpt_dir:
        latest = ckpt.latest_step_dir(args.ckpt_dir)
        if latest:
            state, start_step = ckpt.restore_checkpoint(latest, state)
            print(f"[restore] resumed from {latest} @ step {start_step}")

    def save_fn(step: int) -> None:
        d = os.path.join(args.ckpt_dir, f"step_{step}")
        ckpt.save_checkpoint(d, state, step)
        print(f"[ckpt] saved {d}")

    sup = TrainingSupervisor(FaultConfig(), args.ckpt_every,
                             save_fn=save_fn, restore_fn=lambda: start_step)
    monitor = HeartbeatMonitor(["pod0:0"], FaultConfig())
    straggler = StragglerDetector(FaultConfig())

    step_jit = jax.jit(train_step, donate_argnums=(0,))
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jax.device_put(v)
                     for k, v in data.batch_at(step).items()}
            state, metrics = step_jit(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.beat("pod0:0")
            straggler.record("pod0:0", dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:8.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{dt*1e3:7.1f} ms")
            assert np.isfinite(loss), f"loss diverged at step {step}"
            if args.ckpt_dir:
                sup.maybe_checkpoint(step)
    print("[done] final loss", loss)


if __name__ == "__main__":
    main()
