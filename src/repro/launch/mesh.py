"""Production meshes (DESIGN.md §5).

Defined as FUNCTIONS so importing this module never touches jax device
state — only ``dryrun.py`` (which sets XLA_FLAGS first) builds the 256/512
device meshes; smoke tests build 1-device meshes from the same code path.
"""
from __future__ import annotations

import jax


def compat_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` with Auto axis types where the jax version has them
    (``jax.sharding.AxisType`` only exists in newer releases).

    ``devices`` optionally pins an explicit device sequence (e.g. a subset,
    or ``jax.local_devices()`` under ``jax.distributed`` where the global
    ``jax.devices()`` list contains non-addressable devices) — the sweep
    fabric's ``grid_mesh`` builds through here so there is exactly ONE
    AxisType-compat mesh constructor in the repo.
    """
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes), **kwargs)
    return jax.make_mesh(shape, axes, **kwargs)  # older jax: Auto only


_mesh = compat_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips).

    Axes: ``pod`` — pure data parallelism across pods (params replicated,
    only gradient all-reduce crosses the DCN); ``data`` — FSDP + batch;
    ``model`` — TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return _mesh((1, 1), ("data", "model"))


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Generic mesh over however many devices are actually present."""
    assert n_devices % model_parallel == 0
    return _mesh((n_devices // model_parallel, model_parallel),
                 ("data", "model"))
