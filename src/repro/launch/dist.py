"""Multi-host sweep fabric: ``jax.distributed`` slab scheduling with
overlapped cross-host reduction (PR 8, ROADMAP item 5).

The single-process sweep already runs policy x scenario x seed as ONE
sharded program (``repro.launch.sweep``); this module scales the SAME
compiled slab-chunk step across processes.  The design is deliberately
*slab-per-process with a host-side reduction*, never a global-SPMD
program:

* every process builds the full grid spec from a JSON ``GridSpec`` (the
  grid is cheap to construct and deterministic), makes a LOCAL mesh over
  ``jax.local_devices()``, and integrates only the wrap-padded slabs it
  owns via ``make_stream_fn(...).iter_slabs`` — there is no cross-process
  collective inside the compiled step, so a straggler host never stalls
  another host's compute;
* slab ownership is DYNAMIC: process 0 runs a tiny TCP ``SlabServer``
  (the coordinator of the issue text) handing out start offsets on
  request, so fast processes take more slabs and a straggler — flagged by
  the rolling-median ``StragglerDetector`` from ``repro.distributed.fault``
  — simply receives fewer (``--handout`` omitted falls back to a static
  round-robin partition for fleets that cannot open the side channel);
* each finished slab is written ATOMICALLY (tmp dir + rename) as a tiny
  checkpoint through ``repro.distributed.checkpoint`` — finals leaves plus
  the slab's f64/i64 ``OnlineSummary`` partial — so a crashed or killed
  run RESUMES by rerunning with the same ``out_dir`` (the coordinator
  skips slabs already on disk; the merge picks them up as resumed);
* the cross-host reduction is ``stats.online_merge`` (Chan's parallel
  combine) over per-process partial ``OnlineSummary``s with disjoint cell
  support.  Merging a cell with an ``n == 0`` partial is an exact identity
  (``nb/nb == 1.0`` in f64; sums add ``+0.0``; peaks max with ``0``), so
  the distributed result is BIT-IDENTICAL to the single-process sweep —
  asserted by ``tests/test_sweep_dist.py`` at 2 processes x 2 forced CPU
  devices.

``jax.distributed.initialize`` is still called by default (workers form a
real distributed system: shared coordination service, global device list)
— the compute simply never depends on it, which is what makes the fabric
testable on a CPU box with ``--xla_force_host_platform_device_count``.

    PYTHONPATH=src python -m repro.launch.dist --policies all --seeds 2 \\
        --horizon 120 --procs 2 --devices-per-proc 2 --chunk 40

Worker mode (what the launcher spawns; on a real fleet, run one per
host — the entry point is ``repro.launch.dist_worker`` because
``jax.distributed.initialize`` must run before this module's imports):

    python -m repro.launch.dist_worker --spec grid_spec.json --out RUN \\
        --process-id 1 --num-processes 4 --coordinator host0:1234 \\
        --handout host0:1235
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import json
import os
import pathlib
import re
import shutil
import socket
import subprocess
import sys
import tempfile
import threading
import time
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimConfig, list_policies, stats
from repro.core.scenario import (ScenarioSpec, build_scenarios,
                                 default_scenarios)
from repro.core.scheduling import validate_weights
from repro.core.types import ExecPlan, OnlineSummary, PolicyParams
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import FaultConfig, StragglerDetector
from repro.launch.sweep import (SweepResult, _is_static_leaf, make_stream_fn,
                                stack_policies)

_SRC = pathlib.Path(__file__).resolve().parents[2]   # .../src
_SLAB_RE = re.compile(r"slab_(\d{8})$")
_META_RE = re.compile(r"worker_(\d+)\.json$")


def _resolve_dist_plan(plan: ExecPlan | None, cfg: SimConfig,
                       **legacy) -> tuple[ExecPlan, SimConfig]:
    """Dist twin of ``engine.resolve_plan``: same deprecation cycle for
    the bare kwargs, but the no-plan default keeps the fabric's historical
    2-worker spawn (``ExecPlan.procs`` defaults to 1 = in-process, which
    is right for ``run_sim``/``run_sweep`` but would silently turn the
    dist entry points into single-worker runs)."""
    used = {k: v for k, v in legacy.items() if v is not None}
    if used:
        if plan is not None:
            raise TypeError(
                f"pass execution options via plan= OR the deprecated "
                f"kwargs {sorted(used)}, not both")
        warnings.warn(
            f"the {sorted(used)} kwargs are deprecated; pass "
            f"plan=ExecPlan(...) instead", DeprecationWarning, stacklevel=3)
    if plan is None:
        plan = ExecPlan(
            chunk=used.get("chunk"), slab=used.get("slab"),
            overlap=used.get("overlap", True),
            procs=used.get("num_procs", 2),
            devices_per_proc=used.get("devices_per_proc", 1))
    if plan.telescope:
        # the GridSpec worker contract has no telescope field — passing
        # it through would silently run workers per-tick while the caller
        # believes they telescope
        raise ValueError(
            "telescope is not threaded through the multi-process fabric "
            "yet — drop procs (the in-process sweep telescopes) or drop "
            "telescope")
    return plan, plan.apply_to_config(cfg)


def _slab_cells(B: int, slab: int | None, n_dev: int) -> int:
    """The slab plan: ``min(slab, B)`` padded to a device multiple.  Every
    process MUST compute the same value or slab ownership diverges — the
    worker cross-checks its local device count against the spec."""
    Bs = B if slab is None else min(slab, B)
    return Bs + (-Bs) % n_dev


# ---------------------------------------------------------------------------
# GridSpec: the JSON contract between launcher and workers
# ---------------------------------------------------------------------------

_TUPLE_FIELDS = {f.name for f in dataclasses.fields(SimConfig)
                 if isinstance(f.default, tuple)}


@dataclasses.dataclass
class GridSpec:
    """Everything a worker needs to rebuild the grid bit-for-bit: the
    static config, the scenario ladder, seeds, the policy batch (names OR
    a raw weight matrix — tune ships sampled weights), topology sizes and
    the streaming plan.  JSON-serializable; ``SimConfig`` tuple fields are
    restored from JSON lists on load."""

    config: dict
    scenarios: list
    seeds: list
    n_hosts: int
    n_spine: int
    n_leaf: int
    chunk: int
    slab: int | None
    overlap: bool
    devices_per_proc: int
    policies: list | None = None
    weights: list | None = None

    @classmethod
    def build(cls, *, cfg: SimConfig, scenarios: Sequence[ScenarioSpec],
              seeds: Sequence[int], policies: Sequence[str] | None = None,
              weights=None, n_hosts: int, n_spine: int, n_leaf: int,
              chunk: int, slab: int | None, overlap: bool,
              devices_per_proc: int) -> "GridSpec":
        if (policies is None) == (weights is None):
            raise ValueError("exactly one of policies/weights")
        return cls(
            config=dataclasses.asdict(cfg),
            scenarios=[dataclasses.asdict(s) for s in scenarios],
            seeds=[int(s) for s in seeds],
            n_hosts=int(n_hosts), n_spine=int(n_spine), n_leaf=int(n_leaf),
            chunk=int(chunk), slab=None if slab is None else int(slab),
            overlap=bool(overlap), devices_per_proc=int(devices_per_proc),
            policies=None if policies is None else [str(p) for p in policies],
            weights=None if weights is None
            else np.asarray(weights, np.float32).tolist())

    def sim_config(self) -> SimConfig:
        return SimConfig(**{
            k: tuple(v) if k in _TUPLE_FIELDS else v
            for k, v in self.config.items()})

    def scenario_specs(self) -> list[ScenarioSpec]:
        return [ScenarioSpec(**d) for d in self.scenarios]

    def policy_params(self) -> PolicyParams:
        if self.policies is not None:
            return stack_policies(self.policies)
        W = jnp.asarray(np.asarray(self.weights, np.float32))
        validate_weights(W, "dist grid spec weights: ")
        return PolicyParams(weights=W)

    def policy_names(self) -> list[str]:
        if self.policies is not None:
            return list(self.policies)
        return [f"w{i:03d}" for i in range(len(self.weights))]

    @property
    def n_cells(self) -> int:   # P * S * N, no jax needed (coordinator)
        P = len(self.policies if self.policies is not None else self.weights)
        return P * len(self.scenarios) * len(self.seeds)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(dataclasses.asdict(self), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "GridSpec":
        with open(path) as f:
            return cls(**json.load(f))


GridBundle = collections.namedtuple(
    "GridBundle", "cfg net_spec sims rps pol scenarios")


def build_grid(spec: GridSpec) -> GridBundle:
    """Spec -> batched simulator inputs.  Deterministic: every process
    (and the merging launcher) reconstructs the identical grid."""
    cfg = spec.sim_config()
    scen = spec.scenario_specs()
    net_spec, sims, rps = build_scenarios(
        scen, cfg, n_hosts=spec.n_hosts, n_spine=spec.n_spine,
        n_leaf=spec.n_leaf, seeds=spec.seeds)
    return GridBundle(cfg, net_spec, sims, rps, spec.policy_params(), scen)


# ---------------------------------------------------------------------------
# Dynamic slab handout: process 0's coordinator + the worker-side queue
# ---------------------------------------------------------------------------

class SlabServer(threading.Thread):
    """Process 0's slab coordinator: a one-line-per-connection TCP queue.

    Protocol: a worker connects and sends ``NEXT <wid>\\n``; the reply is
    a start offset or ``DONE``.  The server measures each worker's
    request cadence (~ one slab period under the overlapped driver) and
    feeds it to the rolling-median ``StragglerDetector`` — a straggler is
    not stalled on, it just wins fewer slabs.  The thread exits once every
    worker has been told DONE (daemon: a crashed worker cannot wedge
    process 0 past ``--server-timeout``)."""

    def __init__(self, addr: tuple[str, int], starts: Sequence[int],
                 n_workers: int, fault_cfg: FaultConfig | None = None):
        super().__init__(daemon=True, name="slab-server")
        self.sock = socket.create_server(addr)
        self.sock.settimeout(0.5)
        self.queue = collections.deque(int(s) for s in starts)
        self.n_workers = n_workers
        self.assigned: dict[int, list[int]] = {}
        self.done: set[int] = set()
        self.detector = StragglerDetector(fault_cfg or FaultConfig())
        self._last_req: dict[int, float] = {}
        self._lock = threading.Lock()

    def _serve_one(self) -> None:
        try:
            conn, _ = self.sock.accept()
        except socket.timeout:
            return
        with conn:
            try:
                parts = conn.recv(4096).decode().split()
                wid = int(parts[1]) if len(parts) >= 2 else -1
            except (ValueError, UnicodeDecodeError, OSError):
                return
            now = time.monotonic()
            with self._lock:
                if wid in self._last_req:
                    self.detector.record(f"proc{wid}",
                                         now - self._last_req[wid])
                self._last_req[wid] = now
                if self.queue:
                    s0 = self.queue.popleft()
                    self.assigned.setdefault(wid, []).append(s0)
                    reply = str(s0)
                else:
                    self.done.add(wid)
                    reply = "DONE"
            try:
                conn.sendall((reply + "\n").encode())
            except OSError:
                pass

    def run(self) -> None:
        while len(self.done) < self.n_workers:
            self._serve_one()
        self.sock.close()

    def report(self) -> dict:
        with self._lock:
            return {
                "handout": "dynamic",
                "assignments": {str(w): list(s)
                                for w, s in sorted(self.assigned.items())},
                "stragglers": self.detector.stragglers(),
                "median_slab_s": round(self.detector.median_step(), 4),
            }


def _request_next(addr: str, wid: int, retry_s: float = 60.0) -> int | None:
    """One handout round-trip; retries while the coordinator comes up."""
    host, port = addr.rsplit(":", 1)
    deadline = time.monotonic() + retry_s
    while True:
        try:
            with socket.create_connection((host, int(port)),
                                          timeout=10.0) as s:
                s.sendall(f"NEXT {wid}\n".encode())
                buf = b""
                while not buf.endswith(b"\n"):
                    got = s.recv(64)
                    if not got:
                        break
                    buf += got
            reply = buf.decode().strip()
            return None if reply == "DONE" else int(reply)
        except (OSError, ValueError):
            if time.monotonic() > deadline:
                raise
            time.sleep(0.2)


def _handout_queue(addr: str, wid: int):
    """Lazy slab-start iterable driven by the coordinator.  Fed straight
    to ``fn.iter_slabs``: under the overlapped driver the next start is
    requested while the previous slab is still integrating on device."""
    while True:
        s0 = _request_next(addr, wid)
        if s0 is None:
            return
        yield s0


# ---------------------------------------------------------------------------
# Worker: integrate owned slabs, checkpoint each one atomically
# ---------------------------------------------------------------------------

def completed_slab_starts(out_dir: str) -> set[int]:
    """Start offsets with a complete slab checkpoint on disk (manifest +
    shard both present — the atomic rename means a dir either exists fully
    or not at all; stray ``.tmp*`` dirs from a crash are ignored)."""
    done = set()
    if not os.path.isdir(out_dir):
        return done
    for name in os.listdir(out_dir):
        m = _SLAB_RE.fullmatch(name)
        if not m:
            continue
        p = os.path.join(out_dir, name)
        if (os.path.exists(os.path.join(p, "manifest.json"))
                and os.path.exists(os.path.join(p, "shard_0.npz"))):
            done.add(int(m.group(1)))
    return done


def _write_slab(out_dir: str, s0: int, real: int, leaves, statics,
                slab_sum: OnlineSummary) -> None:
    final = os.path.join(out_dir, f"slab_{s0:08d}")
    tmp = final + f".tmp{os.getpid()}"
    state = {
        "finals": {f"leaf_{i:03d}": x[:real]
                   for i, x in enumerate(leaves) if i not in statics},
        "summary": {k: v[:real]
                    for k, v in zip(OnlineSummary._fields, slab_sum)},
    }
    ckpt.save_checkpoint(tmp, state, step=s0, process_index=0)
    shutil.rmtree(final, ignore_errors=True)   # stale dir from a dead run
    os.rename(tmp, final)


def _worker_loop(spec: GridSpec, out_dir: str, process_id: int, *,
                 slab_starts=None, handout: str | None = None) -> dict:
    """The per-process slab loop: build the grid, drive the overlapped
    ``iter_slabs`` runner over this process's starts (a coordinator queue
    or an explicit list), checkpoint each slab, write the worker meta."""
    t_start = time.monotonic()
    g = build_grid(spec)
    P = g.pol.weights.shape[0]
    S, N = g.sims.t.shape
    B = P * S * N
    fn = make_stream_fn(g.cfg, g.net_spec.n_hosts, g.net_spec.n_nodes,
                        g.cfg.horizon, chunk=spec.chunk, slab=spec.slab,
                        overlap=spec.overlap)
    Bs = fn.slab_cells(B)
    planned = _slab_cells(B, spec.slab, spec.devices_per_proc)
    if Bs != planned:
        raise RuntimeError(
            f"process {process_id}: {len(jax.local_devices())} local "
            f"device(s) pad the slab to {Bs} cells but the spec planned "
            f"{planned} (devices_per_proc={spec.devices_per_proc}); every "
            "process must pad identically or slab ownership diverges")
    flat_sims = jax.tree_util.tree_flatten_with_path(g.sims)[0]
    statics = {i for i, (p, _) in enumerate(flat_sims)
               if _is_static_leaf(p)}
    starts = (iter(slab_starts) if slab_starts is not None
              else _handout_queue(handout, process_id))
    owned, walls = [], []
    t_prev = time.monotonic()
    for s0, leaves, slab_sum in fn.iter_slabs(g.sims, g.pol, g.rps, starts):
        _write_slab(out_dir, s0, min(Bs, B - s0), leaves, statics, slab_sum)
        owned.append(int(s0))
        now = time.monotonic()
        walls.append(round(now - t_prev, 4))
        t_prev = now
    meta = {
        "process_index": int(process_id),
        "slabs": owned,
        "slab_walls_s": walls,
        "compile_cache_misses": int(fn._cache_size()),
        "n_local_devices": len(jax.local_devices()),
        "backend": jax.default_backend(),
        "wall_s": round(time.monotonic() - t_start, 3),
    }
    path = os.path.join(out_dir, f"worker_{process_id:02d}.json")
    with open(path + ".tmp", "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(path + ".tmp", path)
    return meta


def run_worker_inline(spec: GridSpec, out_dir: str, process_id: int,
                      slab_starts: Sequence[int]) -> dict:
    """One virtual worker in-process — the test hook for uneven-partition
    and resume properties without spawning (same loop the subprocess
    worker runs, minus ``jax.distributed`` and the TCP handout)."""
    os.makedirs(out_dir, exist_ok=True)
    return _worker_loop(spec, out_dir, process_id,
                        slab_starts=list(slab_starts))


# ---------------------------------------------------------------------------
# Merge: cross-host reduction of per-process partials
# ---------------------------------------------------------------------------

def merge_out_dir(spec: GridSpec, out_dir: str, grid: GridBundle | None = None):
    """Reassemble ``(finals, summary, worker_metas)`` from the slab
    checkpoints in ``out_dir``.

    Finals rows are disjoint slices — pure assembly.  Summaries reduce as
    a tree: one [B]-support partial per owner (each worker's slabs, plus a
    synthetic ``resumed`` owner for slabs left by a previous run), folded
    with ``stats.online_merge`` — associative, and exact over disjoint
    support, so the reduction order can never change the result.  Raises
    with the missing-slab list when coverage is incomplete (the resume
    path: rerun with the same ``out_dir``)."""
    g = grid or build_grid(spec)
    jtu = jax.tree_util
    P = g.pol.weights.shape[0]
    S, N = g.sims.t.shape
    B = P * S * N
    Bs = _slab_cells(B, spec.slab, spec.devices_per_proc)
    expected = set(range(0, B, Bs))

    flat_sims, sims_def = jtu.tree_flatten_with_path(g.sims)
    statics = {i for i, (p, _) in enumerate(flat_sims)
               if _is_static_leaf(p)}
    host = [np.asarray(x) for _, x in flat_sims]

    metas = []
    for name in sorted(os.listdir(out_dir)):
        if _META_RE.fullmatch(name):
            with open(os.path.join(out_dir, name)) as f:
                metas.append(json.load(f))
    claimed: dict[int, int] = {}
    for m in metas:
        for s0 in m["slabs"]:
            if s0 in claimed:
                raise RuntimeError(
                    f"slab {s0} claimed by workers {claimed[s0]} and "
                    f"{m['process_index']} — handout protocol violation")
            claimed[s0] = m["process_index"]

    on_disk = completed_slab_starts(out_dir)
    extra = sorted(on_disk - expected)   # diagnose plan mismatch FIRST: a
    if extra:                            # foreign plan also looks 'missing'
        raise RuntimeError(
            f"out_dir holds slabs from a different grid/slab plan "
            f"(e.g. start {extra[:4]}; this grid: B={B}, slab={Bs}); "
            "use a fresh out_dir")
    missing = sorted(expected - on_disk)
    if missing:
        raise RuntimeError(
            f"distributed sweep incomplete: {len(missing)}/{len(expected)} "
            f"slabs missing (first: {missing[:4]}); rerun with the same "
            "out_dir to resume")

    groups: dict = {m["process_index"]: [s for s in m["slabs"]]
                    for m in metas}
    orphans = sorted(on_disk - set(claimed))
    if orphans:
        groups["resumed"] = orphans

    finals_flat = [host[i][0, 0] if i in statics
                   else np.empty((B,) + host[i].shape[2:], host[i].dtype)
                   for i in range(len(host))]
    partials = []
    for _, slabs in sorted(groups.items(), key=lambda kv: str(kv[0])):
        if not slabs:
            continue
        part = stats.online_init((B,))
        for s0 in slabs:
            real = min(Bs, B - s0)
            like = {
                "finals": {f"leaf_{i:03d}":
                           np.empty((real,) + host[i].shape[2:],
                                    host[i].dtype)
                           for i in range(len(host)) if i not in statics},
                "summary": dict(zip(OnlineSummary._fields,
                                    stats.online_init((real,)))),
            }
            state, step = ckpt.restore_checkpoint(
                os.path.join(out_dir, f"slab_{s0:08d}"), like)
            if step != s0:
                raise RuntimeError(
                    f"slab_{s0:08d} manifest says step {step}")
            for i in range(len(host)):
                if i not in statics:
                    finals_flat[i][s0:s0 + real] = \
                        state["finals"][f"leaf_{i:03d}"]
            for j, fname in enumerate(OnlineSummary._fields):
                part[j][s0:s0 + real] = state["summary"][fname]
        partials.append(part)

    summary = (functools.reduce(stats.online_merge, partials)
               if partials else stats.online_init((B,)))
    leaves = [np.broadcast_to(x, (P, S, N) + x.shape).copy()
              if i in statics
              else x.reshape((P, S, N) + x.shape[1:])
              for i, x in enumerate(finals_flat)]
    finals = jtu.tree_unflatten(sims_def, leaves)
    summary = OnlineSummary(*(x.reshape((P, S, N)) for x in summary))
    return finals, summary, metas


# ---------------------------------------------------------------------------
# Launcher: spawn N workers, join, merge
# ---------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _log_tail(out_dir: str, i: int, lines: int = 30) -> str:
    path = os.path.join(out_dir, f"worker_{i:02d}.log")
    try:
        with open(path, errors="replace") as f:
            tail = f.readlines()[-lines:]
        return f"--- {path} ---\n" + "".join(tail)
    except OSError:
        return f"--- {path}: unreadable ---"


def _spawn_and_wait(spec_path: str, out_dir: str, num_procs: int,
                    devices_per_proc: int, dist_init: bool, force_cpu: bool,
                    timeout_s: float) -> None:
    coord = f"127.0.0.1:{_free_port()}" if dist_init else None
    handout = f"127.0.0.1:{_free_port()}"
    procs = []
    logs = []
    try:
        for i in range(num_procs):
            env = dict(os.environ)
            env["PYTHONPATH"] = (str(_SRC) + os.pathsep
                                 + env.get("PYTHONPATH", ""))
            if force_cpu:
                env["JAX_PLATFORMS"] = "cpu"
                flags = re.sub(
                    r"--xla_force_host_platform_device_count=\d+", "",
                    env.get("XLA_FLAGS", ""))
                env["XLA_FLAGS"] = (
                    flags + " --xla_force_host_platform_device_count="
                    + str(devices_per_proc)).strip()
            cmd = [sys.executable, "-m", "repro.launch.dist_worker",
                   "--spec", spec_path, "--out", out_dir,
                   "--process-id", str(i),
                   "--num-processes", str(num_procs),
                   "--handout", handout]
            cmd += ["--coordinator", coord] if dist_init \
                else ["--no-dist-init"]
            log = open(os.path.join(out_dir, f"worker_{i:02d}.log"), "w")
            logs.append(log)
            procs.append(subprocess.Popen(cmd, env=env, stdout=log,
                                          stderr=subprocess.STDOUT))
        deadline = time.monotonic() + timeout_s
        while True:
            rcs = [p.poll() for p in procs]
            for i, rc in enumerate(rcs):
                if rc not in (None, 0):
                    for q in procs:
                        q.kill()
                    raise RuntimeError(
                        f"worker {i} exited with rc={rc}\n"
                        + _log_tail(out_dir, i))
            if all(rc == 0 for rc in rcs):
                return
            if time.monotonic() > deadline:
                for q in procs:
                    q.kill()
                raise TimeoutError(
                    f"distributed sweep timed out after {timeout_s}s\n"
                    + "\n".join(_log_tail(out_dir, i)
                                for i in range(num_procs)))
            time.sleep(0.1)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        for log in logs:
            log.close()


DistRun = collections.namedtuple("DistRun", "finals summary metas wall_s")


def run_spec(spec: GridSpec, *, num_procs: int, out_dir: str | None = None,
             dist_init: bool = True, force_cpu: bool = True,
             timeout_s: float = 900.0) -> DistRun:
    """Spawn ``num_procs`` workers over ``spec``, join, merge.  With a
    persistent ``out_dir`` a rerun resumes (completed slabs are skipped by
    the coordinator and merged from disk); the default is a temp dir
    cleaned up after the merge."""
    tmp = None
    if out_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="dist_sweep_")
        out_dir = tmp.name
    try:
        os.makedirs(out_dir, exist_ok=True)
        spec_path = os.path.join(out_dir, "grid_spec.json")
        spec.save(spec_path)
        t0 = time.time()
        _spawn_and_wait(spec_path, out_dir, num_procs,
                        spec.devices_per_proc, dist_init, force_cpu,
                        timeout_s)
        finals, summary, metas = merge_out_dir(spec, out_dir)
        return DistRun(finals, summary, metas, round(time.time() - t0, 2))
    finally:
        if tmp is not None:
            tmp.cleanup()


def make_dist_fn(cfg: SimConfig, scenarios: Sequence[ScenarioSpec],
                 seeds: Sequence[int], *,
                 policies: Sequence[str] | None = None, weights=None,
                 n_hosts: int = 20, n_spine: int = 2, n_leaf: int = 4,
                 num_procs: int | None = None,
                 devices_per_proc: int | None = None,
                 chunk: int | None = None, slab: int | None = None,
                 overlap: bool | None = None,
                 plan: ExecPlan | None = None,
                 out_dir: str | None = None, dist_init: bool = True,
                 force_cpu: bool = True, timeout_s: float = 900.0):
    """Drop-in sweep callable (``fn(sims, pols, rps) -> (finals,
    summary)`` with ``fn._cache_size``/``fn.n_devices``, like
    ``make_stream_fn``) that runs the grid MULTI-PROCESS.  Execution
    options ride in ``plan`` (``procs`` = worker processes; the bare
    ``num_procs``/``devices_per_proc``/``chunk``/``slab``/``overlap``
    kwargs are deprecated, one cycle).  The spec — not the passed trees —
    is the source of truth: workers rebuild the grid from it, so the call
    only sanity-checks that the caller's batch matches (``launch.tune``
    rides this for ``--procs``)."""
    plan, cfg = _resolve_dist_plan(plan, cfg, num_procs=num_procs,
                                   devices_per_proc=devices_per_proc,
                                   chunk=chunk, slab=slab, overlap=overlap)
    if plan.chunk is None:
        raise ValueError("the dist fabric streams slabs: the plan needs a "
                         "chunk (there is no stacked multi-process path)")
    num_procs = plan.procs
    devices_per_proc = plan.devices_per_proc
    spec = GridSpec.build(cfg=cfg, scenarios=scenarios, seeds=seeds,
                          policies=policies, weights=weights,
                          n_hosts=n_hosts, n_spine=n_spine, n_leaf=n_leaf,
                          chunk=plan.chunk, slab=plan.slab,
                          overlap=plan.overlap,
                          devices_per_proc=devices_per_proc)
    state: dict = {"metas": []}

    def fn(sims, pols, rps):
        P = len(spec.policy_names())
        S, N = len(spec.scenarios), len(spec.seeds)
        if pols.weights.shape[0] != P or sims.t.shape != (S, N):
            raise ValueError(
                f"grid mismatch: spec is [{P},{S},{N}] but got "
                f"P={pols.weights.shape[0]}, (S,N)={tuple(sims.t.shape)}")
        if not np.array_equal(np.asarray(pols.weights, np.float32),
                              np.asarray(spec.policy_params().weights)):
            raise ValueError("policy weights differ from the dist spec — "
                             "workers rebuild the grid from the spec")
        run = run_spec(spec, num_procs=num_procs, out_dir=out_dir,
                       dist_init=dist_init, force_cpu=force_cpu,
                       timeout_s=timeout_s)
        state["metas"] = run.metas
        fn.last_run = run
        return run.finals, run.summary

    fn._cache_size = lambda: max(
        (m["compile_cache_misses"] for m in state["metas"]), default=0)
    fn.n_devices = num_procs * devices_per_proc
    fn.spec = spec
    return fn


def run_dist_sweep(policies: Sequence[str] | None = None,
                   scenarios: Sequence[ScenarioSpec] | None = None,
                   seeds: Sequence[int] = (0,),
                   cfg: SimConfig | None = None, n_hosts: int = 20,
                   n_spine: int = 2, n_leaf: int = 4,
                   num_procs: int | None = None,
                   devices_per_proc: int | None = None,
                   chunk: int | None = None, slab: int | None = None,
                   overlap: bool | None = None,
                   plan: ExecPlan | None = None,
                   out_dir: str | None = None, dist_init: bool = True,
                   force_cpu: bool = True,
                   timeout_s: float = 900.0) -> SweepResult:
    """The multi-process twin of ``sweep.run_sweep`` — always streaming
    (a missing ``plan.chunk`` defaults to the largest bound-safe chunk).
    Execution options ride in ``plan`` (bare kwargs: one deprecation
    cycle; no plan at all spawns the historical 2 workers).  Returns the
    same ``SweepResult``; ``compile_cache_misses`` is the MAX across
    processes (the per-process compile bill), ``worker_meta`` carries each
    process's slab assignment and walls."""
    policies = list(policies if policies is not None else list_policies())
    scenarios = list(scenarios if scenarios is not None
                     else default_scenarios())
    cfg = cfg or SimConfig()
    plan, cfg = _resolve_dist_plan(plan, cfg, num_procs=num_procs,
                                   devices_per_proc=devices_per_proc,
                                   chunk=chunk, slab=slab, overlap=overlap)
    chunk = plan.chunk
    if chunk is None:
        chunk = min(cfg.horizon, stats.max_chunk_ticks(cfg.n_containers))
    spec = GridSpec.build(cfg=cfg, scenarios=scenarios, seeds=seeds,
                          policies=policies, n_hosts=n_hosts,
                          n_spine=n_spine, n_leaf=n_leaf, chunk=chunk,
                          slab=plan.slab, overlap=plan.overlap,
                          devices_per_proc=plan.devices_per_proc)
    run = run_spec(spec, num_procs=plan.procs, out_dir=out_dir,
                   dist_init=dist_init, force_cpu=force_cpu,
                   timeout_s=timeout_s)
    return SweepResult(
        policies=policies, scenarios=scenarios, seeds=tuple(seeds),
        finals=run.finals, metrics=None, summary=run.summary,
        wall_s=run.wall_s,
        compile_cache_misses=max(
            (m["compile_cache_misses"] for m in run.metas), default=0),
        n_devices=plan.procs * plan.devices_per_proc,
        worker_meta=run.metas)


# ---------------------------------------------------------------------------
# CLI: launcher mode + worker mode
# ---------------------------------------------------------------------------

def worker_run(a) -> None:
    """The worker body, AFTER ``jax.distributed.initialize`` — entered via
    ``repro.launch.dist_worker`` (this module's imports already execute
    jax computations, so the init must happen before they run)."""
    spec = GridSpec.load(a.spec)
    os.makedirs(a.out, exist_ok=True)
    B = spec.n_cells
    Bs = _slab_cells(B, spec.slab, spec.devices_per_proc)
    all_starts = list(range(0, B, Bs))

    server = None
    if a.process_id == 0 and a.handout:
        # coordinator comes up BEFORE the grid build/compile so other
        # workers' first requests never wait on process 0's compile
        # (clients also retry for 60s while it boots)
        done = completed_slab_starts(a.out)
        host, port = a.handout.rsplit(":", 1)
        server = SlabServer((host, int(port)),
                            [s for s in all_starts if s not in done],
                            a.num_processes)
        server.start()

    if a.handout:
        meta = _worker_loop(spec, a.out, a.process_id, handout=a.handout)
    else:
        done = completed_slab_starts(a.out)
        starts = [s for k, s in enumerate(all_starts)
                  if k % a.num_processes == a.process_id and s not in done]
        meta = _worker_loop(spec, a.out, a.process_id, slab_starts=starts)

    if server is not None:
        server.join(timeout=a.server_timeout)
        path = os.path.join(a.out, "coordinator.json")
        with open(path + ".tmp", "w") as f:
            json.dump(server.report(), f, indent=1)
        os.replace(path + ".tmp", path)
    print(f"worker {a.process_id}: {len(meta['slabs'])} slab(s), "
          f"{meta['compile_cache_misses']} compile(s), "
          f"{meta['n_local_devices']} device(s), {meta['wall_s']}s")


def _launcher_main(argv) -> None:
    ap = argparse.ArgumentParser(
        description="multi-process sweep: spawn N slab workers and merge")
    ap.add_argument("--policies", default="all")
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--horizon", type=int, default=120)
    ap.add_argument("--hosts", type=int, default=20)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--devices-per-proc", type=int, default=1,
                    help="forced CPU devices per worker process")
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--slab", type=int, default=None)
    ap.add_argument("--no-overlap", action="store_true")
    ap.add_argument("--no-dist-init", action="store_true")
    ap.add_argument("--out-dir", default=None,
                    help="persistent run dir (enables resume)")
    ap.add_argument("--timeout", type=float, default=900.0)
    ap.add_argument("--table", default="avg_runtime")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    policies = (list_policies() if args.policies == "all"
                else args.policies.split(","))
    cfg = SimConfig(horizon=args.horizon)
    n_leaf = max(4, args.hosts // 5)
    plan = ExecPlan(chunk=args.chunk, slab=args.slab,
                    overlap=not args.no_overlap, procs=args.procs,
                    devices_per_proc=args.devices_per_proc)
    res = run_dist_sweep(
        policies=policies, seeds=range(args.seeds), cfg=cfg,
        n_hosts=args.hosts, n_spine=max(2, n_leaf // 4), n_leaf=n_leaf,
        plan=plan, out_dir=args.out_dir, dist_init=not args.no_dist_init,
        timeout_s=args.timeout)
    cells = len(res.policies) * len(res.scenarios) * len(res.seeds)
    print(f"# {cells} cells over {args.procs} process(es) x "
          f"{args.devices_per_proc} device(s) in {res.wall_s}s, "
          f"<= {res.compile_cache_misses} compile(s)/process")
    print(res.table(args.table))
    if args.out:
        from repro.core.report import json_clean
        with open(args.out, "w") as f:
            json.dump(json_clean(res.summaries()), f, indent=1)
        print(f"# wrote {args.out}")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--worker" in argv:
        raise SystemExit(
            "worker mode lives in `python -m repro.launch.dist_worker` — "
            "jax.distributed must initialize before this module imports")
    _launcher_main(argv)


if __name__ == "__main__":
    main()
