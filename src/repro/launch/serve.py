"""Batched serving driver: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.launch.mesh import make_mesh_for
from repro.models import sharding as shd
from repro.models import transformer
from repro.serve.step import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_mesh_for(jax.device_count(), args.model_parallel)
    dp = shd.data_axes(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)

    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    if cfg.frontend == "patch_embeds":
        batch = {"patch_embeds": jnp.asarray(
                     rng.standard_normal((B, cfg.n_prefix, cfg.d_model)),
                     jnp.bfloat16),
                 "tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab, (B, S - cfg.n_prefix)),
                     jnp.int32)}
    elif cfg.frontend == "frame_embeds":
        batch = {"frame_embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16)}
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                       jnp.int32)}

    with mesh:
        t0 = time.time()
        toks = generate(cfg, params, batch, args.gen, mesh=mesh, dp=dp)
        toks = np.asarray(toks)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: batch={B} prompt={S} gen={args.gen} "
          f"in {dt:.2f}s ({B * args.gen / dt:.1f} tok/s)")
    print("first sequence:", toks[0][:16], "...")
    assert toks.shape == (B, args.gen)
    assert (toks >= 0).all() and (toks < cfg.vocab_padded).all()


if __name__ == "__main__":
    main()
