"""Shared execution-option CLI surface.

Every launcher (``repro.launch.sim`` / ``sweep`` / ``tune``) spells the
:class:`~repro.core.types.ExecPlan` flags identically through this one
builder, and ``ExecPlan.from_args`` turns the parsed namespace back into
a plan — so ``--chunk 16 --slab 64 --delay-kernel off`` means the same
thing on every entry point and a new execution knob is added in exactly
one place.

The kernel-selector flags default to ``None`` (= keep the ``SimConfig``
defaults) rather than ``'auto'``: an unset flag must not *override* a
config the caller built with explicit selectors.
"""
from __future__ import annotations

import argparse


def add_exec_args(ap: argparse.ArgumentParser, *, chunk: bool = True,
                  slab: bool = True, devices: bool = True,
                  overlap: bool = True, kernels: bool = True,
                  dist: bool = False):
    """Attach the ExecPlan flags to ``ap`` (one argument group).

    The keyword switches drop flags that make no sense for a launcher
    (``repro.launch.sim`` has no grid, so no ``--slab``); dropped flags
    simply stay absent from the namespace and ``ExecPlan.from_args``
    falls back to the field defaults.  Returns the argument group.
    """
    g = ap.add_argument_group("execution (ExecPlan)")
    if chunk:
        g.add_argument("--chunk", type=int, default=None,
                       help="stream the horizon in chunks of this many "
                            "ticks with online summaries (O(state) memory; "
                            "default: stacked per-tick metrics)")
        g.add_argument("--telescope", action="store_true",
                       help="macro-tick engine: advance dt >= 1 ticks per "
                            "step over quiescent intervals, folding skipped "
                            "ticks' metrics in closed form (docs/events.md; "
                            "bit-identical final state, no per-tick series)")
    if slab:
        g.add_argument("--slab", type=int, default=None,
                       help="with --chunk: iterate the grid in slabs of "
                            "this many cells through one compiled step "
                            "(default: the whole grid at once)")
    if devices:
        g.add_argument("--devices", type=int, default=None,
                       help="shard the flattened grid over this many "
                            "devices (default: all local devices)")
    if overlap:
        g.add_argument("--no-overlap", action="store_true",
                       help="with --chunk: gather each slab synchronously "
                            "instead of one slab behind the async dispatch")
    if kernels:
        g.add_argument("--delay-kernel", default=None,
                       choices=["auto", "on", "off"],
                       help="fw APSP Pallas kernel (auto: compiled on "
                            "TPU/GPU, jnp ref on CPU; default: keep the "
                            "SimConfig selector)")
        g.add_argument("--waterfill-kernel", default=None,
                       choices=["auto", "on", "off"],
                       help="fused waterfilling Pallas kernel (same "
                            "semantics)")
    if dist:
        g.add_argument("--procs", type=int, default=None,
                       help="spawn this many worker processes over the "
                            "slab queue (repro.launch.dist; default: "
                            "in-process)")
        g.add_argument("--devices-per-proc", type=int, default=None,
                       help="devices each dist worker claims")
    return g
