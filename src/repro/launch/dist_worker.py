"""Worker entry point for the multi-process sweep fabric.

``jax.distributed.initialize`` must run before ANY jax computation, and
importing ``repro.launch.dist`` already executes some (the policy
registry builds device arrays at import) — so this module stays LIGHT:
it parses the worker args and initializes the distributed runtime first,
then imports the fabric and hands over.

    python -m repro.launch.dist_worker --spec grid_spec.json --out RUN \\
        --process-id 1 --num-processes 4 --coordinator host0:1234 \\
        --handout host0:1235
"""
from __future__ import annotations

import argparse
import sys


def parse_args(argv):
    ap = argparse.ArgumentParser("repro.launch.dist_worker")
    ap.add_argument("--spec", required=True,
                    help="GridSpec JSON (see repro.launch.dist)")
    ap.add_argument("--out", required=True, help="shared run directory")
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--num-processes", type=int, required=True)
    ap.add_argument("--handout", default=None,
                    help="host:port of the slab coordinator (process 0 "
                         "serves it); omitted = static round-robin slabs")
    ap.add_argument("--coordinator", default=None,
                    help="host:port for jax.distributed.initialize")
    ap.add_argument("--no-dist-init", action="store_true",
                    help="skip jax.distributed (pure slab-worker mode)")
    ap.add_argument("--server-timeout", type=float, default=120.0)
    return ap.parse_args(argv)


def main(argv=None) -> None:
    a = parse_args(sys.argv[1:] if argv is None else list(argv))
    if not a.no_dist_init:
        if not a.coordinator:
            raise SystemExit("--coordinator required unless --no-dist-init")
        import jax                       # importing jax computes nothing
        jax.distributed.initialize(a.coordinator, a.num_processes,
                                   a.process_id)
    from repro.launch import dist        # heavy: touches the backend
    dist.worker_run(a)


if __name__ == "__main__":
    main()
