"""Bridge: compiled model steps -> DCSim jobs (DESIGN.md §3).

The paper's motivating workload is container-based distributed training /
inference.  This module closes the loop: a dry-run cell's roofline terms
(per-device FLOPs, collective wire bytes) become a DCSim job whose

* container compute demand  = per-device step FLOPs (scaled to the paper's
  work-unit clock so heterogeneous host speeds matter), and
* pairwise communication    = per-device collective bytes per step

— so scheduling experiments ask the paper's actual question ("where should
communication-heavy ML containers land?") with communication matrices
measured from real compiled programs instead of uniform random draws.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from repro.core.datacenter import SimConfig
from repro.core.types import ContainerState, empty_containers


@dataclasses.dataclass(frozen=True)
class MLJobSpec:
    """One training/serving job derived from a dry-run cell."""
    arch: str
    shape: str
    n_workers: int             # containers (data-parallel workers)
    steps: int                 # training steps to simulate
    flops_per_step: float      # per worker
    coll_bytes_per_step: float  # per worker, to its ring neighbours
    mem_gb: float              # per-worker memory request


def job_from_dryrun(result: dict, n_workers: int = 8,
                    steps: int = 20) -> MLJobSpec:
    """Container compute = per-device step FLOPs from the dry-run.

    Container *network* traffic = only the bytes that actually cross the
    data-center fabric between workers: the cross-pod gradient exchange
    (2 x active params in bf16 for a ring all-reduce).  The rest of the
    dry-run's collective bytes are intra-pod ICI traffic and never leave
    the host in the deployment this simulates (DESIGN.md §5: the pod axis
    is pure DP; only the gradient all-reduce crosses the DCN).
    """
    mem_gb = max(1.0, min(32.0, result.get(
        "approx_bytes_per_device_gb", 4.0)))
    from repro.configs import get_config
    try:
        n_active = get_config(result["arch"]).active_param_count()
    except KeyError:
        n_active = 1e9
    grad_exchange_bytes = 2.0 * 2.0 * n_active      # bf16, ring ~2x
    return MLJobSpec(
        arch=result["arch"], shape=result["shape"], n_workers=n_workers,
        steps=steps,
        flops_per_step=result["flops"],
        coll_bytes_per_step=grad_exchange_bytes,
        mem_gb=mem_gb)


def jobs_from_results(path: str, shape: str = "train_4k",
                      archs: Sequence[str] | None = None,
                      n_workers: int = 8, steps: int = 20):
    with open(path) as f:
        rows = json.load(f)
    out = []
    for r in rows:
        if r.get("status") != "ok" or r["shape"] != shape:
            continue
        if r["mesh"] != "single":
            continue
        if archs and r["arch"] not in archs:
            continue
        out.append(job_from_dryrun(r, n_workers, steps))
    return out


def workload_from_jobs(jobs: Sequence[MLJobSpec], cfg: SimConfig,
                       capacity: int | None = None,
                       gpu_speed_flops: float = 197e12,
                       seed: int = 0) -> ContainerState:
    """Materialize MLJobSpecs as a DCSim ContainerState.

    * duration (work units) = steps * flops / gpu_speed_flops — a speed-s
      host finishes in duration/s seconds, exactly the paper's model;
    * per-step collective traffic becomes ``n_comms = steps`` comm events
      of ``coll_bytes/steps`` KB each between same-job containers;
    * GPU-heavy resource profile (this is the GPU-trace regime the paper
      targets with its Alibaba dataset).
    """
    rng = np.random.default_rng(seed)
    n_total = sum(j.n_workers for j in jobs)
    C = capacity or n_total
    state = empty_containers(C)

    req = np.zeros((C, 3), np.float32)
    ctype = np.full(C, 2, np.int32)               # GPU-intensive
    duration = np.zeros(C, np.float32)
    n_comms = np.zeros(C, np.int32)
    comm_kb = np.zeros(C, np.float32)
    gap = np.full(C, np.inf, np.float32)
    first_at = np.full(C, np.inf, np.float32)
    submit = np.full(C, np.inf, np.float32)
    job_ids = np.full(C, -1, np.int32)
    task_ids = np.full(C, -1, np.int32)

    i = 0
    for jid, job in enumerate(jobs):
        arrive = rng.uniform(0.0, 10.0)
        dur = job.steps * job.flops_per_step / gpu_speed_flops
        dur = float(np.clip(dur, 5.0, 300.0))
        for w in range(job.n_workers):
            req[i] = [400.0, job.mem_gb, 100.0]
            duration[i] = dur
            n_comms[i] = min(job.steps, 10)
            comm_kb[i] = job.coll_bytes_per_step / 1024.0 \
                * job.steps / n_comms[i]
            gap[i] = dur / (n_comms[i] + 1)
            first_at[i] = gap[i]
            submit[i] = arrive
            job_ids[i] = jid
            task_ids[i] = jid
            i += 1

    import jax.numpy as jnp
    return state._replace(
        req=jnp.asarray(req), ctype=jnp.asarray(ctype),
        duration=jnp.asarray(duration),
        n_comms_left=jnp.asarray(n_comms),
        comm_bytes=jnp.asarray(comm_kb),
        comm_work_gap=jnp.asarray(gap),
        next_comm_at=jnp.asarray(first_at),
        submit_t=jnp.asarray(submit),
        job=jnp.asarray(job_ids), task=jnp.asarray(task_ids),
    )
