"""Data center module (paper §3.3): hosts + config (paper Tables 5/6)."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import network
from repro.core.types import HostState, make_hosts


@dataclasses.dataclass(frozen=True)
class HostCategory:
    """One row of paper Table 5."""

    count: int
    cpu_cores: int      # cores; capacity = cores * 100 (percent units)
    cpu_speed: float
    mem_gb: int
    mem_speed: float
    gpu_count: int      # GPUs; capacity = gpus * 100 (percent units)
    gpu_speed: float
    price: float


# Paper Table 5 — four heterogeneous host classes, five hosts each.
PAPER_HOST_CATEGORIES: tuple[HostCategory, ...] = (
    HostCategory(5, 80, 1.0, 128, 1.0, 8, 1.0, 1.0),
    HostCategory(5, 80, 2.0, 128, 2.0, 8, 2.0, 1.5),
    HostCategory(5, 80, 3.0, 128, 3.0, 8, 3.0, 3.0),
    HostCategory(5, 80, 4.0, 128, 4.0, 8, 4.0, 5.0),
)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Simulator parameters (paper Table 6, INI-config equivalent)."""

    # workload
    n_jobs: int = 100
    n_tasks: int = 300
    n_containers: int = 300
    duration_range: tuple[float, float] = (20.0, 30.0)
    cpu_req_range: tuple[float, float] = (100.0, 1700.0)   # percent
    mem_req_range: tuple[float, float] = (1.0, 32.0)       # GB
    gpu_req_range: tuple[float, float] = (50.0, 200.0)     # percent
    n_comms_range: tuple[int, int] = (1, 5)
    comm_kb_range: tuple[float, float] = (100.0, 102400.0)  # KB per comm
    arrival_window: float = 36.0   # jobs arrive uniformly in [0, window)
    # simulator
    delay_update_interval: int = 10   # ticks between delay-matrix refreshes
    max_retries: int = 3              # iperf retransmission cap
    congestion_threshold: float = 0.2
    max_containers_per_host: int = 10  # network nodes allocated per host
    overload_threshold: float = 0.7
    idle_threshold: float = 0.3
    # engine
    horizon: int = 120                # simulated seconds
    placements_per_tick: int = 64     # inner scheduling scan length
    migrations_per_tick: int = 8
    waterfill_rounds: int = 8
    delay_mode: str = "path"          # 'path' | 'fw'
    fw_use_kernel: bool = False
    sparse_flows: bool = True         # segment-based flow engine (docs/perf.md)
    batched_placement: bool = True    # conflict-resolved top-K placement round
    stall_rate_floor: float = 50.0    # KB/s under which a flow is 'stalled'
    mig_kb_per_gb: float = 1024.0     # migration bytes per GB of memory req
    queue_coef: float = 0.5
    # network-aware scoring (NetState.comm_cost refresh weights).  Defaults
    # mirror network.DEFAULT_* — build_network seeds the initial table with
    # those, and the engine re-weights from this config at every delay
    # refresh (the first one fires at the end of tick 0).
    netaware_util_weight: float = network.DEFAULT_UTIL_WEIGHT
    netaware_cross_leaf_ms: float = network.DEFAULT_CROSS_LEAF_MS


def build_paper_hosts(categories: Sequence[HostCategory] = PAPER_HOST_CATEGORIES,
                      n_leaf: int = 4) -> HostState:
    rows_cap, rows_speed, price = [], [], []
    for cat in categories:
        for _ in range(cat.count):
            rows_cap.append([cat.cpu_cores * 100.0, float(cat.mem_gb),
                             cat.gpu_count * 100.0])
            rows_speed.append([cat.cpu_speed, cat.mem_speed, cat.gpu_speed])
            price.append(cat.price)
    cap = np.asarray(rows_cap, np.float32)
    speed = np.asarray(rows_speed, np.float32)
    price_a = np.asarray(price, np.float32)
    H = cap.shape[0]
    leaf = (np.arange(H) % n_leaf).astype(np.int32)
    return make_hosts(cap, speed, price_a, leaf)


def scaled_hosts(n_hosts: int, n_leaf: int,
                 categories: Sequence[HostCategory] = PAPER_HOST_CATEGORIES
                 ) -> HostState:
    """Round-robin the paper's categories up to ``n_hosts`` (Table 7 sweeps)."""
    per = max(1, n_hosts // len(categories))
    cats = []
    for cat in categories:
        cats.append(dataclasses.replace(cat, count=per))
    # remainder goes to the first category
    rem = n_hosts - per * len(categories)
    if rem > 0:
        cats[0] = dataclasses.replace(cats[0], count=per + rem)
    return build_paper_hosts(tuple(cats), n_leaf=n_leaf)


def build_paper_network(cfg: SimConfig, n_hosts: int = 20, n_spine: int = 2,
                        n_leaf: int = 4, bw: float = 1000.0,
                        loss: float = 0.0):
    spec = network.SpineLeafSpec(
        n_spine=n_spine, n_leaf=n_leaf, n_hosts=n_hosts,
        host_leaf_bw=bw, leaf_spine_bw=bw, loss=loss)
    return spec, network.build_network(spec)
