"""Data center module (paper §3.3): hosts + config (paper Tables 5/6)."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import network
from repro.core.types import HostState, RunParams, make_hosts


@dataclasses.dataclass(frozen=True)
class HostCategory:
    """One row of paper Table 5."""

    count: int
    cpu_cores: int      # cores; capacity = cores * 100 (percent units)
    cpu_speed: float
    mem_gb: int
    mem_speed: float
    gpu_count: int      # GPUs; capacity = gpus * 100 (percent units)
    gpu_speed: float
    price: float


# Paper Table 5 — four heterogeneous host classes, five hosts each.
PAPER_HOST_CATEGORIES: tuple[HostCategory, ...] = (
    HostCategory(5, 80, 1.0, 128, 1.0, 8, 1.0, 1.0),
    HostCategory(5, 80, 2.0, 128, 2.0, 8, 2.0, 1.5),
    HostCategory(5, 80, 3.0, 128, 3.0, 8, 3.0, 3.0),
    HostCategory(5, 80, 4.0, 128, 4.0, 8, 4.0, 5.0),
)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """STATIC simulator parameters (paper Table 6, INI-config equivalent).

    Everything here is compile-time: tensor shapes (container capacity, scan
    lengths), engine control flow (flow engine, placement path, delay mode)
    and the workload-generation distributions (host-side numpy).  Knobs that
    a sweep varies at runtime — link bandwidth/loss, the queueing
    coefficient, the overload/idle thresholds — live in the
    :class:`~repro.core.types.RunParams` pytree instead, threaded through
    the tick as traced scalars; the copies kept on this config are only the
    *defaults* :meth:`run_params` reads.  Changing a RunParams value never
    recompiles; changing a SimConfig field does.
    """

    # workload
    n_jobs: int = 100
    n_tasks: int = 300
    n_containers: int = 300
    duration_range: tuple[float, float] = (20.0, 30.0)
    cpu_req_range: tuple[float, float] = (100.0, 1700.0)   # percent
    mem_req_range: tuple[float, float] = (1.0, 32.0)       # GB
    gpu_req_range: tuple[float, float] = (50.0, 200.0)     # percent
    n_comms_range: tuple[int, int] = (1, 5)
    comm_kb_range: tuple[float, float] = (100.0, 102400.0)  # KB per comm
    arrival_window: float = 36.0   # jobs arrive uniformly in [0, window)
    # simulator
    delay_update_interval: int = 10   # ticks between delay-matrix refreshes
    max_retries: int = 3              # iperf retransmission cap
    congestion_threshold: float = 0.2
    max_containers_per_host: int = 10  # network nodes allocated per host
    overload_threshold: float = 0.7
    idle_threshold: float = 0.3
    # engine
    horizon: int = 120                # simulated seconds
    placements_per_tick: int = 64     # inner scheduling scan length
    migrations_per_tick: int = 8
    waterfill_rounds: int = 8
    delay_mode: str = "path"          # 'path' | 'fw'
    # Pallas kernel dispatch flags ('auto' | 'on' | 'off', resolved per
    # backend by repro.kernels.resolve_kernel: compiled kernel on TPU/GPU,
    # jnp reference on CPU under 'auto'; 'on' forces the kernel — the
    # interpreter-lowered oracle-test mode on CPU — and 'off' forces the
    # reference everywhere):
    delay_kernel: str = "auto"        # fw_minplus APSP ('fw' delay mode)
    waterfill_kernel: str = "auto"    # fused seg_waterfill flow allocation
    sparse_flows: bool = True         # segment-based flow engine (docs/perf.md)
    batched_placement: bool = True    # conflict-resolved top-K placement round
    # Differentiable-scheduling surrogate (docs/autodiff.md): when on, every
    # placement/migration argmin ALSO accumulates softmax expected-feature
    # costs (temperature RunParams.tau) into TickMetrics/SummaryAcc — the
    # dynamics stay the exact hard argmin, so results are bit-for-bit
    # identical to soft_placement=False; the extra terms are what
    # jax.grad(objective)(weights) differentiates.  Requires
    # batched_placement.
    soft_placement: bool = False
    tau: float = 1.0                  # RunParams.tau default (runtime knob)
    stall_rate_floor: float = 50.0    # KB/s under which a flow is 'stalled'
    mig_kb_per_gb: float = 1024.0     # migration bytes per GB of memory req
    queue_coef: float = 0.5           # RunParams default (runtime knob)

    def run_params(self) -> RunParams:
        """Default runtime-parameter pytree for this config.

        ``bw_mbps``/``loss`` default to their keep-the-topology sentinels
        (<=0 / <0): the network built for the scenario keeps its per-link
        values unless a sweep point overrides them uniformly.
        """
        f32 = lambda x: jnp.asarray(x, jnp.float32)
        return RunParams(
            bw_mbps=f32(-1.0), loss=f32(-1.0),
            queue_coef=f32(self.queue_coef),
            overload_threshold=f32(self.overload_threshold),
            idle_threshold=f32(self.idle_threshold),
            tau=f32(self.tau),
        )


def build_paper_hosts(categories: Sequence[HostCategory] = PAPER_HOST_CATEGORIES,
                      n_leaf: int = 4) -> HostState:
    rows_cap, rows_speed, price = [], [], []
    for cat in categories:
        for _ in range(cat.count):
            rows_cap.append([cat.cpu_cores * 100.0, float(cat.mem_gb),
                             cat.gpu_count * 100.0])
            rows_speed.append([cat.cpu_speed, cat.mem_speed, cat.gpu_speed])
            price.append(cat.price)
    cap = np.asarray(rows_cap, np.float32)
    speed = np.asarray(rows_speed, np.float32)
    price_a = np.asarray(price, np.float32)
    H = cap.shape[0]
    leaf = (np.arange(H) % n_leaf).astype(np.int32)
    return make_hosts(cap, speed, price_a, leaf)


def scaled_hosts(n_hosts: int, n_leaf: int,
                 categories: Sequence[HostCategory] = PAPER_HOST_CATEGORIES
                 ) -> HostState:
    """Round-robin the paper's categories up to ``n_hosts`` (Table 7 sweeps)."""
    per = max(1, n_hosts // len(categories))
    cats = []
    for cat in categories:
        cats.append(dataclasses.replace(cat, count=per))
    # remainder goes to the first category
    rem = n_hosts - per * len(categories)
    if rem > 0:
        cats[0] = dataclasses.replace(cats[0], count=per + rem)
    return build_paper_hosts(tuple(cats), n_leaf=n_leaf)


# Heterogeneous host price/capacity mixes for the scenario layer
# (paper Table 5 is "paper"; the others stress price- and speed-sensitive
# policies with the same [H, ...] shapes, so scenarios stack cleanly).
HOST_MIXES: dict[str, tuple[HostCategory, ...]] = {
    "paper": PAPER_HOST_CATEGORIES,
    # uniform cheap & slow fleet: no speed/price gradient to exploit
    "budget": (HostCategory(20, 80, 1.0, 128, 1.0, 8, 1.0, 1.0),),
    # top-heavy: a few premium hosts among many baseline ones
    "premium": (
        HostCategory(15, 80, 1.0, 128, 1.0, 8, 1.0, 1.0),
        HostCategory(5, 80, 4.0, 256, 4.0, 8, 4.0, 8.0),
    ),
    # wide spread: small/cheap against big/fast, strong consolidation signal
    "contrast": (
        HostCategory(10, 40, 1.0, 64, 1.0, 4, 1.0, 0.5),
        HostCategory(10, 160, 3.0, 256, 3.0, 16, 3.0, 6.0),
    ),
}


def mixed_hosts(mix: str, n_hosts: int, n_leaf: int) -> HostState:
    """Build ``n_hosts`` hosts from a named :data:`HOST_MIXES` entry."""
    try:
        cats = HOST_MIXES[mix]
    except KeyError:
        raise KeyError(
            f"unknown host mix {mix!r}; known: {sorted(HOST_MIXES)}") from None
    return scaled_hosts(n_hosts, n_leaf, cats)


def build_paper_network(cfg: SimConfig, n_hosts: int = 20, n_spine: int = 2,
                        n_leaf: int = 4, bw: float = 1000.0,
                        loss: float = 0.0):
    spec = network.SpineLeafSpec(
        n_spine=n_spine, n_leaf=n_leaf, n_hosts=n_hosts,
        host_leaf_bw=bw, leaf_spine_bw=bw, loss=loss)
    return spec, network.build_network(spec)
