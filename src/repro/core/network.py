"""Network simulation module (paper §3.4), tensor-native.

Mininet's emulated fabric is replaced by an analytic flow-level model that
reproduces the quantities the paper *measures*:

* ``ping``-refreshed delay matrix  -> min-plus Floyd-Warshall over the
  congestion-adjusted link-delay graph (Pallas kernel on TPU; jnp ref here).
* ``iperf`` transfers under (bw, delay, loss) -> per-flow rate =
  min(max-min-fair share via progressive filling, Mathis TCP bound
  MSS / (RTT * sqrt(p))).
* bounded retransmissions -> flows stalled below a rate floor accrue retries
  and fail after ``max_retries`` ticks (paper: failed traffic is handed back
  to the scheduling module).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import NetState

INF = jnp.float32(1e9)
MBPS_TO_KBPS = 125.0  # 1 Mbps = 125 KB/s
LOCAL_RATE_KBPS = 4.0e6  # same-host "loopback" transfer rate
# comm-cost weights: single source of truth — every policy's weight vector
# defaults to these (scheduling.weight_vector seeds its util/cross_leaf
# slots from them), and build_network/set_link_params (which have no policy
# in scope) use them for the initial table; the engine re-weights from the
# policy's weight vector at every delay refresh.
DEFAULT_UTIL_WEIGHT = 1.0     # ms-equivalent at 100% path utilization
DEFAULT_CROSS_LEAF_MS = 0.05  # penalty for transiting the spine


# ---------------------------------------------------------------------------
# Topology construction (spine-leaf, paper Fig 3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpineLeafSpec:
    n_spine: int = 2
    n_leaf: int = 4
    n_hosts: int = 20
    host_leaf_bw: float = 1000.0   # Mbps
    leaf_spine_bw: float = 1000.0  # Mbps
    link_delay_ms: float = 0.05    # per-link base delay
    loss: float = 0.0              # per-link packet loss fraction

    @property
    def n_nodes(self) -> int:
        return self.n_hosts + self.n_leaf + self.n_spine

    @property
    def n_links(self) -> int:
        return self.n_hosts + self.n_leaf * self.n_spine


def build_network(spec: SpineLeafSpec) -> NetState:
    """Build link tables + deterministic ECMP paths for a spine-leaf fabric.

    Node numbering: hosts [0, H), leaves [H, H+L), spines [H+L, H+L+S).
    Link numbering: host-leaf links [0, H) (link i connects host i to its
    leaf), then leaf-spine links H + l * S + s.
    """
    H, L, S = spec.n_hosts, spec.n_leaf, spec.n_spine
    E = spec.n_links

    host_leaf = np.arange(H) % L                      # host -> leaf id
    link_u = np.zeros(E, np.int32)
    link_v = np.zeros(E, np.int32)
    link_bw = np.zeros(E, np.float32)
    # host-leaf links
    link_u[:H] = np.arange(H)
    link_v[:H] = H + host_leaf
    link_bw[:H] = spec.host_leaf_bw
    # leaf-spine links
    for leaf in range(L):
        for s in range(S):
            e = H + leaf * S + s
            link_u[e] = H + leaf
            link_v[e] = H + L + s
            link_bw[e] = spec.leaf_spine_bw

    # Deterministic ECMP: pair (i, j) hashes onto spine (i + j) % S.
    # Vectorized over the H^2 pairs so multi-thousand-host fabrics build in
    # milliseconds (the Python double loop was itself a scalability ceiling).
    I, J = np.meshgrid(np.arange(H), np.arange(H), indexing="ij")
    li, lj = host_leaf[I], host_leaf[J]
    same = (li == lj) & (I != J)
    cross = li != lj
    spine = (I + J) % S
    path_links = np.full((H, H, 4), -1, np.int32)
    path_links[same, 0] = I[same]
    path_links[same, 1] = J[same]
    path_links[cross, 0] = I[cross]
    path_links[cross, 1] = (H + li * S + spine)[cross]
    path_links[cross, 2] = (H + lj * S + spine)[cross]
    path_links[cross, 3] = J[cross]
    path_nlinks = np.where(same, 2, np.where(cross, 4, 0)).astype(np.int32)

    base_delay = np.full(E, spec.link_delay_ms, np.float32)
    loss = np.full(E, spec.loss, np.float32)
    delay0 = path_delay_matrix(
        jnp.asarray(base_delay), jnp.asarray(path_links))
    pl = jnp.asarray(path_links)
    net = NetState(
        link_bw=jnp.asarray(link_bw),
        link_delay=jnp.asarray(base_delay),
        link_loss=jnp.asarray(loss),
        link_u=jnp.asarray(link_u),
        link_v=jnp.asarray(link_v),
        path_links=pl,
        path_nlinks=jnp.asarray(path_nlinks),
        link_bw_kbps=jnp.asarray(link_bw) * MBPS_TO_KBPS,
        path_loss=path_loss_matrix(jnp.asarray(loss), pl),
        link_util=jnp.zeros((E,), jnp.float32),
        delay_matrix=delay0,
        comm_cost=jnp.zeros((H, H), jnp.float32),
    )
    return net._replace(comm_cost=pairwise_comm_cost(net))


def apply_link_params(net: NetState, bw_mbps: jnp.ndarray,
                      loss: jnp.ndarray) -> NetState:
    """Trace-friendly uniform bandwidth/loss override (RunParams semantics).

    ``bw_mbps <= 0`` / ``loss < 0`` keep the topology's per-link values, so
    the no-override default is expressible as data and a (bw, loss) ladder
    is a batch axis on two scalars — the engine applies this at t=0, which
    is how ``launch/sweep.py`` runs a whole Fig 5/8-style sweep in one
    compiled program.  The derived tables (``link_bw_kbps``, ``path_loss``,
    ``comm_cost``) are rebuilt in the same pass.
    """
    bw_mbps = jnp.asarray(bw_mbps, jnp.float32)
    loss = jnp.asarray(loss, jnp.float32)
    new_bw = jnp.where(bw_mbps > 0, bw_mbps, net.link_bw)
    new_loss = jnp.where(loss >= 0, loss, net.link_loss)
    net = net._replace(
        link_bw=new_bw,
        link_bw_kbps=new_bw * MBPS_TO_KBPS,
        link_loss=new_loss,
        path_loss=path_loss_matrix(new_loss, net.path_links))
    return net._replace(comm_cost=pairwise_comm_cost(net))


def set_link_params(net: NetState, bw: float | None = None,
                    loss: float | None = None) -> NetState:
    """Override bandwidth / loss on every link (paper Fig 5/8 sweeps).

    Host-side convenience over :func:`apply_link_params`; ``None`` maps to
    the keep-the-topology sentinel.  Values inside the sentinel domain
    (``bw <= 0``, ``loss < 0``) are rejected loudly — they would otherwise
    silently keep the topology instead of overriding it.
    """
    if bw is not None and bw <= 0:
        raise ValueError(f"bw override must be > 0 Mbps, got {bw}")
    if loss is not None and loss < 0:
        raise ValueError(f"loss override must be >= 0, got {loss}")
    return apply_link_params(net,
                             -1.0 if bw is None else bw,
                             -1.0 if loss is None else loss)


# ---------------------------------------------------------------------------
# Delay model
# ---------------------------------------------------------------------------
def congested_link_delay(net: NetState, q_coef: float = 0.5,
                         max_q: float = 20.0) -> jnp.ndarray:
    """Per-link delay = base + M/M/1-style queueing term from utilization."""
    u = jnp.clip(net.link_util, 0.0, 0.97)
    return net.link_delay + jnp.minimum(q_coef * u / (1.0 - u), max_q)


def path_delay_matrix(link_delay: jnp.ndarray,
                      path_links: jnp.ndarray) -> jnp.ndarray:
    """Host-to-host delay along the fixed ECMP path (fast path, 'path' mode)."""
    padded = jnp.concatenate([link_delay, jnp.zeros((1,), link_delay.dtype)])
    d = padded[path_links].sum(axis=-1)          # [-1] pad indexes the 0
    return d


def path_loss_matrix(link_loss: jnp.ndarray,
                     path_links: jnp.ndarray) -> jnp.ndarray:
    """Host-to-host end-to-end loss 1 - prod(1 - loss_e) along the ECMP path.

    Static per topology, so it is precomputed onto ``NetState.path_loss`` and
    the per-tick Mathis bound becomes a single [F] gather.
    """
    keep = jnp.concatenate([jnp.log1p(-jnp.clip(link_loss, 0.0, 0.99)),
                            jnp.zeros((1,), link_loss.dtype)])
    return 1.0 - jnp.exp(keep[path_links].sum(axis=-1))  # [-1] pad hits the 0


def path_util_matrix(net: NetState) -> jnp.ndarray:
    """Max link utilization along the ECMP path between every host pair.

    The bottleneck view of current congestion: a flow between (i, j) is
    limited by the hottest link on its fixed path.  Pad slots (-1) index the
    appended zero, so same-host pairs report 0 utilization.
    """
    padded = jnp.concatenate([net.link_util,
                              jnp.zeros((1,), net.link_util.dtype)])
    return padded[net.path_links].max(axis=-1)


def path_util_row(net: NetState, src: jnp.ndarray) -> jnp.ndarray:
    """One source row of :func:`path_util_matrix` — f32[H].

    The congestion-aware migration picker needs the bottleneck utilization
    from ONE source host to every destination; gathering ``path_links[src]``
    first keeps that O(H·4) instead of materializing the O(H²·4) matrix
    inside the per-tick migration scan.
    """
    padded = jnp.concatenate([net.link_util,
                              jnp.zeros((1,), net.link_util.dtype)])
    return padded[net.path_links[src]].max(axis=-1)


def pairwise_comm_cost(net: NetState,
                       util_weight: float = DEFAULT_UTIL_WEIGHT,
                       cross_leaf_ms: float = DEFAULT_CROSS_LEAF_MS
                       ) -> jnp.ndarray:
    """Expected cost [ms-equivalent] of communicating between host pairs.

    ``delay_matrix`` (the paper's ping-refreshed D, already congestion-
    adjusted at refresh time) + ``util_weight`` * bottleneck utilization of
    the ECMP path + a ``cross_leaf_ms`` penalty for pairs whose traffic must
    transit the spine (path_nlinks == 4; same-leaf pairs use 2 links and
    same-host pairs 0).  Refreshed onto ``NetState.comm_cost`` together with
    the delay matrix; the network-aware policies score hosts against it.
    """
    cross_spine = (net.path_nlinks >= 4).astype(jnp.float32)
    return (net.delay_matrix + util_weight * path_util_matrix(net)
            + cross_leaf_ms * cross_spine)


def adjacency_from_links(net: NetState, link_delay: jnp.ndarray,
                         n_nodes: int) -> jnp.ndarray:
    """Symmetric node-graph adjacency with link delays; INF where no edge.

    Built with a ``segment_min`` over flattened (u, v) pair ids instead of
    the former ``.at[u, v].min`` scatters — min is order-independent, so
    the result is bit-identical, and the delay-refresh arm of the tick
    ('fw' mode) stays scatter-free under a vmapped sweep.  Parallel links
    (none on the spine-leaf fabric, but allowed) still take the min.
    """
    seg = jnp.concatenate([net.link_u * n_nodes + net.link_v,
                           net.link_v * n_nodes + net.link_u])
    vals = jnp.concatenate([link_delay, link_delay])
    A = jax.ops.segment_min(vals, seg, num_segments=n_nodes * n_nodes)
    A = jnp.minimum(A, INF).reshape(n_nodes, n_nodes)  # empty segments: +inf
    eye = jnp.arange(n_nodes)[:, None] == jnp.arange(n_nodes)[None, :]
    return jnp.where(eye, 0.0, A)


def floyd_warshall_ref(A: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp min-plus APSP (oracle for the Pallas kernel)."""
    n = A.shape[0]

    def body(D, k):
        D = jnp.minimum(D, D[:, k, None] + D[None, k, :])
        return D, None

    D, _ = jax.lax.scan(body, A, jnp.arange(n))
    return D


def update_delay_matrix(net: NetState, n_hosts: int, n_nodes: int,
                        mode: str = "path", use_kernel: bool = False,
                        q_coef: float = 0.5,
                        util_weight: float = DEFAULT_UTIL_WEIGHT,
                        cross_leaf_ms: float = DEFAULT_CROSS_LEAF_MS
                        ) -> NetState:
    """Refresh the paper's delay_matrix (and comm_cost) from congestion.

    mode='path'  — sum link delays along the fixed ECMP path (O(H^2)).
    mode='fw'    — full APSP over the node graph (the SDN-controller view);
                   uses the Pallas blocked kernel when ``use_kernel``.
    The pairwise communication-cost table consumed by the network-aware
    policies is rebuilt from the fresh delay matrix in the same pass.
    """
    d_link = congested_link_delay(net, q_coef=q_coef)
    if mode == "path":
        D = path_delay_matrix(d_link, net.path_links)
    else:
        A = adjacency_from_links(net, d_link, n_nodes)
        if use_kernel:
            from repro.kernels.fw_minplus import ops as fw_ops
            D_full = fw_ops.floyd_warshall(A)
        else:
            D_full = floyd_warshall_ref(A)
        D = D_full[:n_hosts, :n_hosts]
    net = net._replace(delay_matrix=D)
    return net._replace(comm_cost=pairwise_comm_cost(
        net, util_weight=util_weight, cross_leaf_ms=cross_leaf_ms))


# ---------------------------------------------------------------------------
# Flow-level rate allocation
#
# Two interchangeable engines (docs/perf.md):
#   sparse (default) — every ECMP path has <= 4 links, so each per-link
#     reduction is a [F, 4] gather + segment_sum scatter-add: O(F*4 + E)
#     per waterfilling round.
#   dense (reference oracle, ``sparse=False``) — materializes the [F, E]
#     membership matrix the seed engine used: O(F*E) per round.  Kept so
#     property tests can assert the sparse path is numerically equivalent.
# ---------------------------------------------------------------------------
def path_membership(path_links: jnp.ndarray, src: jnp.ndarray,
                    dst: jnp.ndarray, n_links: int) -> jnp.ndarray:
    """[F, E] bool: does flow f traverse link e. Same-host flows hit no link."""
    links = path_links[src, dst]                      # [F, 4]
    return (links[:, :, None] == jnp.arange(n_links)[None, None, :]).any(1)


def max_min_fair_rates(member: jnp.ndarray, active: jnp.ndarray,
                       link_bw_kbps: jnp.ndarray,
                       n_rounds: int = 8) -> jnp.ndarray:
    """Progressive-filling max-min fair allocation, fixed rounds, jit-safe.

    Each round saturates (at least) the globally most contended link and
    freezes the flows crossing it at their fair share.  Dense [F, E]
    reference implementation.
    """
    F = member.shape[0]
    member_f = member.astype(jnp.float32) * active[:, None]

    def fair_bound(unfrozen, cap_rem):
        live = member_f * unfrozen[:, None].astype(jnp.float32)
        cnt = live.sum(0)                                      # [E]
        share = jnp.where(cnt > 0, cap_rem / jnp.maximum(cnt, 1.0), INF)
        # per-flow bound = min share along its path (INF for no-link flows)
        return jnp.where(member, share[None, :], INF).min(1)   # [F]

    def round_body(carry, _):
        alloc, frozen, cap_rem = carry
        unfrozen = active & ~frozen
        bound = jnp.where(unfrozen, fair_bound(unfrozen, cap_rem), INF)
        m = bound.min()
        newly = unfrozen & (bound <= m * 1.000001 + 1e-6)
        new_alloc = jnp.where(newly, jnp.minimum(bound, LOCAL_RATE_KBPS), alloc)
        used = (member_f * (newly * new_alloc)[:, None]).sum(0)
        return (new_alloc, frozen | newly, jnp.maximum(cap_rem - used, 0.0)), None

    alloc0 = jnp.where(active, LOCAL_RATE_KBPS, 0.0)  # no-link flows: local bw
    init = (alloc0, active & ~member.any(1), link_bw_kbps)
    (alloc, frozen, cap_rem), _ = jax.lax.scan(round_body, init, None,
                                               length=n_rounds)
    # Flows still unfrozen after n_rounds (more distinct bottleneck levels
    # than rounds) get their current fair-share bound, NOT the LOCAL_RATE
    # alloc0 they were initialized with — the latter oversubscribed links.
    leftover = active & ~frozen
    tail = jnp.minimum(fair_bound(leftover, cap_rem), LOCAL_RATE_KBPS)
    alloc = jnp.where(leftover, tail, alloc)
    return jnp.where(active, alloc, 0.0)


def max_min_fair_rates_sparse(flow_links: jnp.ndarray, active: jnp.ndarray,
                              link_bw_kbps: jnp.ndarray,
                              n_rounds: int = 8) -> jnp.ndarray:
    """Sparse progressive filling over the [F, 4] per-flow link lists.

    Numerically equivalent to :func:`max_min_fair_rates` (same round
    structure, same freeze rule) but every per-link reduction is a
    ``segment_sum`` over at most 4 link ids per flow — no [F, E] tensor.
    """
    F = flow_links.shape[0]
    E = link_bw_kbps.shape[0]
    valid = (flow_links >= 0) & active[:, None]          # [F, 4]
    seg = jnp.where(valid, flow_links, E).reshape(-1)    # pad slots -> seg E
    w_valid = valid.astype(jnp.float32)

    def per_link_sum(per_flow):                          # [F] -> [E]
        w = (per_flow[:, None] * w_valid).reshape(-1)
        return jax.ops.segment_sum(w, seg, num_segments=E + 1)[:E]

    def fair_bound(unfrozen, cap_rem):
        cnt = per_link_sum(unfrozen.astype(jnp.float32))
        share = jnp.where(cnt > 0, cap_rem / jnp.maximum(cnt, 1.0), INF)
        padded = jnp.concatenate([share, jnp.full((1,), INF)])
        return jnp.where(valid, padded[seg.reshape(F, 4)], INF).min(1)

    def round_body(carry, _):
        alloc, frozen, cap_rem = carry
        unfrozen = active & ~frozen
        bound = jnp.where(unfrozen, fair_bound(unfrozen, cap_rem), INF)
        m = bound.min()
        newly = unfrozen & (bound <= m * 1.000001 + 1e-6)
        new_alloc = jnp.where(newly, jnp.minimum(bound, LOCAL_RATE_KBPS), alloc)
        used = per_link_sum(jnp.where(newly, new_alloc, 0.0))
        return (new_alloc, frozen | newly, jnp.maximum(cap_rem - used, 0.0)), None

    alloc0 = jnp.where(active, LOCAL_RATE_KBPS, 0.0)
    init = (alloc0, active & ~valid.any(1), link_bw_kbps)
    (alloc, frozen, cap_rem), _ = jax.lax.scan(round_body, init, None,
                                               length=n_rounds)
    leftover = active & ~frozen
    tail = jnp.minimum(fair_bound(leftover, cap_rem), LOCAL_RATE_KBPS)
    alloc = jnp.where(leftover, tail, alloc)
    return jnp.where(active, alloc, 0.0)


def mathis_cap(delay_matrix: jnp.ndarray, link_loss: jnp.ndarray,
               member: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
               mss_kb: float = 1.46, c_mathis: float = 1.22) -> jnp.ndarray:
    """TCP throughput ceiling under loss: C * MSS / (RTT * sqrt(p)) [KB/s]."""
    # path loss: 1 - prod(1 - loss_e)
    log_keep = jnp.where(member, jnp.log1p(-jnp.clip(link_loss, 0, 0.99))[None, :], 0.0)
    p = 1.0 - jnp.exp(log_keep.sum(1))
    return _mathis_from_loss(delay_matrix, p, src, dst, mss_kb, c_mathis)


def mathis_cap_sparse(delay_matrix: jnp.ndarray, path_loss: jnp.ndarray,
                      src: jnp.ndarray, dst: jnp.ndarray,
                      mss_kb: float = 1.46,
                      c_mathis: float = 1.22) -> jnp.ndarray:
    """Mathis bound from the precomputed [H, H] path-loss table: one gather."""
    return _mathis_from_loss(delay_matrix, path_loss[src, dst], src, dst,
                             mss_kb, c_mathis)


def _mathis_from_loss(delay_matrix, p, src, dst, mss_kb, c_mathis):
    rtt_ms = 2.0 * delay_matrix[src, dst]
    rtt_s = jnp.maximum(rtt_ms, 1e-2) * 1e-3
    cap = c_mathis * mss_kb / (rtt_s * jnp.sqrt(jnp.maximum(p, 1e-12)))
    return jnp.where(p > 1e-9, cap, INF)


def flow_rates(net: NetState, src: jnp.ndarray, dst: jnp.ndarray,
               active: jnp.ndarray, n_rounds: int = 8, sparse: bool = True,
               use_kernel: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Allocate KB/s to each (src_host -> dst_host) flow; also new link util.

    ``sparse`` selects the segment-based engine (default); ``sparse=False``
    runs the dense [F, E] membership oracle.  ``use_kernel`` routes the
    sparse allocation through the fused Pallas ``seg_waterfill`` kernel
    (all waterfilling rounds + Mathis min + link load in one kernel; the
    unfused jnp chain below is its oracle — docs/kernels.md).  Returns
    (rates [F], util [E]).
    """
    E = net.link_bw.shape[0]
    src_c = jnp.clip(src, 0, None)
    dst_c = jnp.clip(dst, 0, None)
    bw_kbps = net.link_bw_kbps

    if sparse and use_kernel:
        from repro.kernels.seg_waterfill import ops as wf_ops
        links = jnp.where(active[:, None], net.path_links[src_c, dst_c], -1)
        tcp = mathis_cap_sparse(net.delay_matrix, net.path_loss, src_c, dst_c)
        rates, load = wf_ops.seg_waterfill(
            links, active, bw_kbps, tcp, n_rounds=n_rounds,
            local_rate=float(LOCAL_RATE_KBPS), inf=float(INF))
    elif sparse:
        links = jnp.where(active[:, None], net.path_links[src_c, dst_c], -1)
        fair = max_min_fair_rates_sparse(links, active, bw_kbps, n_rounds)
        tcp = mathis_cap_sparse(net.delay_matrix, net.path_loss, src_c, dst_c)
        rates = jnp.minimum(fair, tcp) * active
        valid = links >= 0                                    # [F, 4]
        seg = jnp.where(valid, links, E).reshape(-1)
        w = (rates[:, None] * valid.astype(jnp.float32)).reshape(-1)
        load = jax.ops.segment_sum(w, seg, num_segments=E + 1)[:E]
    else:
        member = path_membership(net.path_links, src_c, dst_c, E)
        member = member & active[:, None]
        fair = max_min_fair_rates(member, active, bw_kbps, n_rounds)
        tcp = mathis_cap(net.delay_matrix, net.link_loss, member, src_c, dst_c)
        rates = jnp.minimum(fair, tcp) * active
        load = (member.astype(jnp.float32) * rates[:, None]).sum(0)
    util = jnp.where(bw_kbps > 0, load / jnp.maximum(bw_kbps, 1e-6), 0.0)
    return rates, jnp.clip(util, 0.0, 1.0)
