# DCSim-JAX: the paper's computing+networking-integrated container-scheduling
# simulator as one compiled JAX program (see DESIGN.md §2 for the mapping).
from repro.core.datacenter import (  # noqa: F401
    HOST_MIXES, PAPER_HOST_CATEGORIES, HostCategory, SimConfig,
    build_paper_hosts, build_paper_network, mixed_hosts, scaled_hosts,
)
from repro.core.engine import (  # noqa: F401
    init_sim, run_sim, run_sim_chunked, simulate, simulate_chunk,
)
from repro.core.report import (  # noqa: F401
    summarize, sweep_summaries, sweep_table, timeseries, to_csv, tune_table,
)
from repro.core.scenario import (  # noqa: F401
    ScenarioSpec, build_scenario, build_scenarios, default_scenarios,
)
from repro.core.scheduling import (  # noqa: F401
    get_policy, list_policies, register, validate_weights, weight_vector,
)
from repro.core.stats import (  # noqa: F401
    SOFT_OBJECTIVES, acc_init, acc_update, check_chunk, max_chunk_ticks,
    online_fold, online_from_metrics, online_init, soft_num_den,
    soft_objective,
)
from repro.core.types import (  # noqa: F401
    NUM_POLICY_WEIGHTS, WEIGHT_NAMES, ExecPlan, OnlineSummary, PolicyParams,
    RunParams, SummaryAcc,
)
from repro.core.workload import (  # noqa: F401
    bursty_workload, paper_workload, trace_workload,
)
