# DCSim-JAX: the paper's computing+networking-integrated container-scheduling
# simulator as one compiled JAX program (see DESIGN.md §2 for the mapping).
from repro.core.datacenter import (  # noqa: F401
    HOST_MIXES, PAPER_HOST_CATEGORIES, HostCategory, SimConfig,
    build_paper_hosts, build_paper_network, mixed_hosts, scaled_hosts,
)
from repro.core.engine import init_sim, run_sim, simulate  # noqa: F401
from repro.core.report import (  # noqa: F401
    summarize, sweep_summaries, sweep_table, timeseries, to_csv, tune_table,
)
from repro.core.scenario import (  # noqa: F401
    ScenarioSpec, build_scenario, build_scenarios, default_scenarios,
)
from repro.core.scheduling import (  # noqa: F401
    get_policy, list_policies, register, validate_weights, weight_vector,
)
from repro.core.types import (  # noqa: F401
    NUM_POLICY_WEIGHTS, WEIGHT_NAMES, PolicyParams, RunParams,
)
from repro.core.workload import (  # noqa: F401
    bursty_workload, paper_workload, trace_workload,
)
