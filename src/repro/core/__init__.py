# DCSim-JAX: the paper's computing+networking-integrated container-scheduling
# simulator as one compiled JAX program (see DESIGN.md §2 for the mapping).
from repro.core.datacenter import (  # noqa: F401
    PAPER_HOST_CATEGORIES, HostCategory, SimConfig, build_paper_hosts,
    build_paper_network, scaled_hosts,
)
from repro.core.engine import init_sim, run_sim, run_sim_vmapped  # noqa: F401
from repro.core.report import summarize, timeseries, to_csv  # noqa: F401
from repro.core.scheduling import Policy, get_policy, list_policies, register  # noqa: F401
from repro.core.workload import paper_workload, trace_workload  # noqa: F401
