"""Container scheduling module (paper §3.5) — policy-as-data.

A scheduling algorithm is split into a *code* half and a *data* half:

* the code half is a :class:`PolicyDef` — a named set of scoring branch
  functions (selection key, per-candidate host-preference row, placement
  carry hooks, optional migration rule) registered into a branch table;
* the data half is a :class:`PolicyParams` pytree (``types.py``) — the
  branch index plus a weight vector.

The engine never sees a ``PolicyDef`` directly: every hook is evaluated
through a ``lax.switch`` over the registered branches, indexed by
``PolicyParams.policy_id``.  What varies between policies is therefore pure
data, so a batch of policies is a ``PolicyParams`` with a leading axis and a
policy sweep is ONE compiled program (see ``repro/launch/sweep.py``) —
instead of one XLA compilation per algorithm.

The scoring interface itself is unchanged from the unified score-based API:

* ``select_key(sim, pol) -> i32[C]`` — selection order over containers
  (lower = scheduled earlier, ``INT_BIG`` = not schedulable this tick);
* ``host_row(sim, cfg, params, pol, carry, k, cand, used) -> f32[H]`` —
  candidate ``k``'s host preference (lower = better);
* a scan-carried :class:`PlaceCarry` (Round's rotating pointer + the
  same-job co-location counts) updated after every admit, so intra-round
  decisions see each other and batched == sequential placements exactly.

Migration: ``migrate(sim, cfg, params, pol) -> (container | -1, dst | -1)``,
dispatched through the same branch table (policies without a migration rule
hit a no-op branch).  Users extend by registering a ``PolicyDef`` — the
paper's "flexible and scalable interface for scheduling algorithms".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import network
from repro.core.datacenter import SimConfig
from repro.core.types import (
    NUM_POLICY_WEIGHTS, STATUS_COMMUNICATING, STATUS_INACTIVE,
    STATUS_MIGRATING, STATUS_RUNNING, STATUS_WAITING, PolicyParams, RunParams,
    SimState,
)

BIG = jnp.float32(1e18)          # host-score sentinel (infeasible)
INT_BIG = jnp.int32(2**31 - 1)   # selection-key sentinel (unschedulable)

DEFAULT_WEIGHTS = (network.DEFAULT_UTIL_WEIGHT, network.DEFAULT_CROSS_LEAF_MS)


# ---------------------------------------------------------------------------
# Shared predicates
# ---------------------------------------------------------------------------
def feasible_hosts(cap: jnp.ndarray, used: jnp.ndarray, ncont: jnp.ndarray,
                   req: jnp.ndarray, cfg: SimConfig) -> jnp.ndarray:
    """Hosts that can take a container requesting ``req``: resource headroom
    + a free container slot (``max_containers_per_host``, the per-host
    net-node cap).

    Takes the raw counters rather than the SimState so the engine can feed
    it either the live state (sequential path, migration sources) or the
    in-round counters carried by the batched admit scan — one predicate,
    every feasibility decision.
    """
    fits = ((used + req[None, :]) <= cap).all(axis=1)
    return fits & (ncont < cfg.max_containers_per_host)


def schedulable_mask(sim: SimState) -> jnp.ndarray:
    """Containers eligible for (re)placement: submitted+unscheduled or waiting."""
    st = sim.containers.status
    arrived = sim.containers.submit_t <= sim.t
    return arrived & ((st == STATUS_INACTIVE) | (st == STATUS_WAITING))


def rank_key(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Sortable i32 selection key: rank under lexicographic (values, index).

    A stable argsort gives every slot its rank (< C, so no overflow at any
    capacity — unlike ``values * C + index`` float encodings, which lose the
    index tie-break once the combined key exceeds f32's 2^24 integer range).
    Slots outside ``mask`` get ``INT_BIG``.

    The rank is the inverse of the sort permutation, so a second argsort
    computes it scatter-free — identical integers to the former
    ``zeros.at[order].set(arange)`` scatter, without XLA:CPU's slow
    batched-scatter lowering when the tick is vmapped over sweep cells.
    """
    order = jnp.argsort(values, stable=True)
    rank = jnp.argsort(order).astype(jnp.int32)
    return jnp.where(mask, rank, INT_BIG)


def select_key_fifo(sim: SimState) -> jnp.ndarray:
    """Paper default selection: earliest-submitted first, index tie-break."""
    return rank_key(sim.containers.submit_t, schedulable_mask(sim))


def _first_true(order_key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index minimizing order_key among mask; -1 if mask empty."""
    key = jnp.where(mask, order_key, BIG)
    return jnp.where(mask.any(), jnp.argmin(key), -1)


# ---------------------------------------------------------------------------
# The unified placement carry
#
# One pytree shape shared by every branch, so ``lax.switch`` can dispatch
# over policies whose scores carry different things: Round rotates ``rr``,
# the co-location policies (JobGroup, NetAware) update ``counts``, the
# static scores touch neither.
# ---------------------------------------------------------------------------
class PlaceCarry(NamedTuple):
    rr: jnp.ndarray      # i32[]    Round's rotating last-used-host pointer
    counts: jnp.ndarray  # f32[K,H] deployed same-job containers per host


def same_job_host_counts(sim: SimState, cand: jnp.ndarray) -> jnp.ndarray:
    """[K, H] deployed same-job container count per host, per candidate.

    One ``segment_sum`` of the C deployed containers onto a small [K, H]
    table keyed by (first candidate sharing the container's job, host) —
    the pad-slot trick in two dimensions (slot K*H swallows containers
    matching no candidate).  Candidates sharing a job then gather the first
    sharer's row.  Replaces the K vmapped per-candidate scatter-adds of the
    PR 2 form (kept as :func:`same_job_host_counts_scatter`); counts are
    integer-valued, so the regrouped sum is exact and both forms agree
    bit-for-bit.
    """
    H = sim.hosts.cap.shape[0]
    K = cand.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    jobs_k = ct.job[cand]                                    # [K]
    eq = ct.job[:, None] == jobs_k[None, :]                  # [C, K]
    hit = eq.any(axis=1) & deployed
    k_first = jnp.argmax(eq, axis=1)                         # [C]
    hostc = jnp.clip(ct.host, 0, H - 1)
    seg = jnp.where(hit, k_first * H + hostc, K * H)
    table = jax.ops.segment_sum(
        hit.astype(jnp.float32), seg, num_segments=K * H + 1)[:K * H]
    kk_first = jnp.argmax(jobs_k[None, :] == jobs_k[:, None], axis=1)
    return table.reshape(K, H)[kk_first]


def same_job_host_counts_scatter(sim: SimState,
                                 cand: jnp.ndarray) -> jnp.ndarray:
    """PR 2 per-candidate scatter-add form — oracle for the segment-sum
    rewrite (tests/test_scatter_free.py)."""
    H = sim.hosts.cap.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    same = deployed[None, :] & (ct.job[None, :] == ct.job[cand][:, None])
    hostc = jnp.clip(ct.host, 0, H - 1)
    return jax.vmap(
        lambda s: jnp.zeros((H,), jnp.float32).at[hostc].add(s)
    )(same.astype(jnp.float32))


def _zero_counts(sim: SimState, cand: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros((cand.shape[0], sim.hosts.cap.shape[0]), jnp.float32)


# --- carry init branches: (sim, cand) -> PlaceCarry ------------------------
def _init_static(sim: SimState, cand: jnp.ndarray) -> PlaceCarry:
    return PlaceCarry(rr=sim.sched.rr_pointer, counts=_zero_counts(sim, cand))


def _init_coloc(sim: SimState, cand: jnp.ndarray) -> PlaceCarry:
    return PlaceCarry(rr=sim.sched.rr_pointer,
                      counts=same_job_host_counts(sim, cand))


# --- carry update branches: (sim, carry, k, cand, hh, ok) -> PlaceCarry ----
def _update_noop(sim, carry, k, cand, hh, ok) -> PlaceCarry:
    return carry


def _update_round(sim, carry, k, cand, hh, ok) -> PlaceCarry:
    return carry._replace(rr=jnp.where(ok, hh, carry.rr))


def _update_coloc(sim, carry, k, cand, hh, ok) -> PlaceCarry:
    """Admitting candidate k onto host hh raises the co-location count of
    every later same-job candidate — the intra-round carry that makes the
    batched round match the sequential reference exactly.  The single-column
    bump is a where-mask (one float add, bit-identical to the former
    ``.at[:, hh].add`` scatter) so the admit scan stays scatter-free under
    a vmapped sweep."""
    same = sim.containers.job[cand] == sim.containers.job[cand[k]]
    hot = (jnp.arange(carry.counts.shape[1]) == hh) & ok
    return carry._replace(counts=jnp.where(
        hot[None, :] & same[:, None], carry.counts + 1.0, carry.counts))


# ---------------------------------------------------------------------------
# Host-preference rows (paper §3.5 algorithms 2-3)
#
# ``row(sim, cfg, params, w, carry, k, cand, used) -> f32[H]``: candidate
# ``k``'s host preference (lower = better; argmin breaks ties toward the
# lowest host index).  Feasibility is NOT baked in — the engine masks
# infeasible hosts against its live resource counters so intra-round
# decisions see each other.  ``w`` is the policy's weight vector.
# ---------------------------------------------------------------------------
def _row_firstfit(sim, cfg, params, w, carry, k, cand, used):
    """FirstFit [36]: lowest-numbered host satisfying the constraints."""
    return jnp.arange(sim.hosts.cap.shape[0], dtype=jnp.float32)


def _row_performance_first(sim, cfg, params, w, carry, k, cand, used):
    """PerformanceFirst (DRAPS-derived): fastest host for the candidate's
    primary resource."""
    return -sim.hosts.speed[:, sim.containers.ctype[cand[k]]]


def _row_round(sim, cfg, params, w, carry, k, cand, used):
    """Round (paper §3.5): first feasible host after the last used one."""
    H = sim.hosts.cap.shape[0]
    return jnp.mod(jnp.arange(H) - carry.rr - 1, H).astype(jnp.float32)


def _worst_fit_row(sim: SimState, used: jnp.ndarray) -> jnp.ndarray:
    """Most total normalized free resources first (lower key = better)."""
    free = (sim.hosts.cap - used) / jnp.maximum(sim.hosts.cap, 1e-6)
    return -free.sum(axis=1)


def _row_jobgroup(sim, cfg, params, w, carry, k, cand, used):
    """JobGroup (CA-WFD-derived): host holding the most same-job containers;
    worst-fit on free resources while the job has none deployed."""
    cnt = carry.counts[k]
    return jnp.where(cnt.sum() > 0, -cnt, _worst_fit_row(sim, used))


def _row_netaware(sim, cfg, params, w, carry, k, cand, used):
    """NetAware: mean expected communication cost from each host to the
    candidate's deployed same-job peers, under the current fabric state.

    ``NetState.comm_cost`` (delay matrix + bottleneck link utilization along
    the ECMP path + cross-leaf penalty, re-weighted from the policy's weight
    vector at every delay refresh) prices every host pair; peers placed
    earlier in the same round are in ``carry.counts`` via the co-location
    carry.  Jobs with no deployed peers fall back to worst-fit, like
    JobGroup.
    """
    cnt = carry.counts[k]                                    # [H] peers/host
    cost = cnt @ sim.net.comm_cost                           # [H] total cost
    return jnp.where(cnt.sum() > 0, cost / jnp.maximum(cnt.sum(), 1.0),
                     _worst_fit_row(sim, used))


# ---------------------------------------------------------------------------
# Migration (paper §3.5 algorithm 1, DRAPS-derived)
# ---------------------------------------------------------------------------
def _overload_source(sim: SimState, cfg: SimConfig, params: RunParams):
    """Shared source/container selection for the migration policies.

    Returns (src, cont, src_c, dst_mask):
    * src: host with max over-threshold utilization on any resource (-1 none);
    * cont: RUNNING container on it consuming the most of the host's
      bottleneck resource;
    * dst_mask: feasible hosts with all utilizations < idle threshold.
    """
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)   # [H, 3]
    worst = util.max(axis=1)
    overloaded = worst > params.overload_threshold
    H = worst.shape[0]
    src = _first_true(-worst, overloaded)
    src_c = jnp.clip(src, 0, H - 1)
    bottleneck = jnp.argmax(util[src_c])                       # resource index
    st = sim.containers.status
    movable = (st == STATUS_RUNNING) & (sim.containers.host == src_c)
    usage = sim.containers.req[:, bottleneck]
    cont = _first_true(-usage, movable)
    C = movable.shape[0]
    cont_c = jnp.clip(cont, 0, C - 1)

    req = sim.containers.req[cont_c]
    feas = feasible_hosts(sim.hosts.cap, sim.hosts.used,
                          sim.hosts.n_containers, req, cfg)
    idle = (util < params.idle_threshold).all(axis=1)
    dst_mask = feas & idle & (jnp.arange(H) != src_c)
    return src, cont, src_c, dst_mask


def _migration_pair(src, cont, dst):
    ok = (src >= 0) & (cont >= 0) & (dst >= 0)
    return jnp.where(ok, cont, -1), jnp.where(ok, dst, -1)


def _migrate_none(sim: SimState, cfg: SimConfig, params: RunParams):
    """No-migration branch: uniform (container, dst) = (-1, -1)."""
    minus1 = jnp.full((), -1, jnp.int32)
    return minus1, minus1


def overload_migrate(sim: SimState, cfg: SimConfig,
                     params: RunParams | None = None):
    """Relieve the most overloaded host; first-fit destination.

    Returns (-1, -1) when no (source, container, destination) triple exists.
    """
    params = cfg.run_params() if params is None else params
    src, cont, src_c, dst_mask = _overload_source(sim, cfg, params)
    H = dst_mask.shape[0]
    dst = _first_true(jnp.arange(H, dtype=jnp.float32), dst_mask)
    return _migration_pair(src, cont, dst)


def congestion_migrate(sim: SimState, cfg: SimConfig,
                       params: RunParams | None = None):
    """Congestion-aware variant: same source/container selection, but the
    destination minimizes the bottleneck link utilization of the ECMP path
    the migration flow will traverse (index tie-break) — instead of blindly
    taking the first feasible idle host across a hot spine."""
    params = cfg.run_params() if params is None else params
    src, cont, src_c, dst_mask = _overload_source(sim, cfg, params)
    path_util = network.path_util_row(sim.net, src_c)          # f32[H]
    dst = _first_true(path_util, dst_mask)
    return _migration_pair(src, cont, dst)


# ---------------------------------------------------------------------------
# Registry (paper: "easy extensibility of container scheduling algorithms")
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PolicyDef:
    """The *code* half of a scheduling algorithm: one registered branch of
    the ``lax.switch`` dispatch tables.

    ``row`` is mandatory; the carry hooks default to no-ops (static scores)
    and ``migrate`` to the no-op branch.  ``weights`` seeds
    ``PolicyParams.weights`` — the cost-model-driven knobs a sweep (or a
    future learned-weight search) varies without recompiling.
    """

    name: str
    row: Callable                    # (sim, cfg, params, w, carry, k, cand,
    #                                   used) -> f32[H]
    init: Callable = _init_static    # (sim, cand) -> PlaceCarry
    update: Callable = _update_noop  # (sim, carry, k, cand, hh, ok) -> carry
    select: Callable = select_key_fifo  # (sim) -> i32[C], INT_BIG = skip
    migrate: Callable = _migrate_none   # (sim, cfg, params) -> (cont, dst)
    weights: tuple[float, ...] = DEFAULT_WEIGHTS

    def __post_init__(self):
        if len(self.weights) != NUM_POLICY_WEIGHTS:
            raise ValueError(
                f"policy {self.name!r}: weights must have "
                f"{NUM_POLICY_WEIGHTS} entries, got {len(self.weights)}")


_REGISTRY: dict[str, int] = {}   # name -> branch index (registration order)
_DEFS: list[PolicyDef] = []
_REGISTRY_VERSION = 0


def registry_version() -> int:
    """Monotone counter bumped by every (re-)registration.  The engine keys
    its jit caches on it: the branch tables are baked into compiled switch
    dispatch, so a registration AFTER a compiled run must invalidate that
    cache — otherwise ``lax.switch`` would clamp the new branch index into
    the stale table and silently run the wrong policy."""
    return _REGISTRY_VERSION


def register(pdef: PolicyDef) -> PolicyDef:
    """Add (or replace, by name) a scoring branch.  The branch tables are
    read at trace time; :func:`registry_version` makes sure previously
    compiled runs are re-traced after a new registration."""
    global _REGISTRY_VERSION
    if pdef.name in _REGISTRY:
        _DEFS[_REGISTRY[pdef.name]] = pdef
    else:
        _REGISTRY[pdef.name] = len(_DEFS)
        _DEFS.append(pdef)
    _REGISTRY_VERSION += 1
    return pdef


def get_policy(name: str, weights=None) -> PolicyParams:
    """The data handle for a registered policy: branch id + weight vector.

    ``weights`` overrides the branch's default weight vector — policy
    variants (e.g. a heavier cross-leaf penalty) are new *data*, not new
    code, so they share the compiled program.
    """
    try:
        idx = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; known: {sorted(_REGISTRY)}") from None
    w = _DEFS[idx].weights if weights is None else tuple(weights)
    if len(w) != NUM_POLICY_WEIGHTS:
        # a short vector would be silently clamped by jit-mode gathers
        # (weights[W_CROSS_LEAF] -> index 0), a long one breaks stacking
        raise ValueError(
            f"policy {name!r}: weights must have {NUM_POLICY_WEIGHTS} "
            f"entries, got {len(w)}")
    return PolicyParams(policy_id=jnp.asarray(idx, jnp.int32),
                        weights=jnp.asarray(w, jnp.float32))


def policy_name(pol: PolicyParams) -> str:
    """Registered name for a (concrete, unbatched) PolicyParams."""
    return _DEFS[int(pol.policy_id)].name


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Switch-dispatched hooks — the ONLY policy surface the engine consumes.
# Branch index is data (PolicyParams.policy_id), so under a policy-batched
# vmap every branch is evaluated and selected per cell; on an unbatched run
# only the selected branch executes.
# ---------------------------------------------------------------------------
def _dedup_switch(idx: jnp.ndarray, hooks, call, *args):
    """``lax.switch`` over the UNIQUE hook functions, with the branch index
    remapped through a constant table.

    Registered policies share hook implementations heavily (every built-in
    uses the FIFO ``select``; four share the static carry init).  Under a
    policy-batched ``vmap`` the switch evaluates EVERY branch and selects
    per cell, so dispatching over the raw per-policy tables would run the
    duplicated hooks once per registration instead of once per distinct
    implementation.  Dedup also collapses the common all-policies-share-it
    case to a direct call — no switch at all.  ``call`` adapts a hook to
    the dispatch arguments (closure over trace-time statics like cfg).
    """
    pos: dict = {}                      # hook -> index into uniq
    remap = [pos.setdefault(h, len(pos)) for h in hooks]
    uniq = list(pos)                    # insertion-ordered distinct hooks
    if len(uniq) == 1:
        return call(uniq[0])(*args)
    branches = tuple(call(h) for h in uniq)
    if remap == list(range(len(remap))):
        return jax.lax.switch(idx, branches, *args)
    return jax.lax.switch(jnp.asarray(remap, jnp.int32)[idx], branches,
                          *args)


def select_key(sim: SimState, pol: PolicyParams) -> jnp.ndarray:
    return _dedup_switch(pol.policy_id, [d.select for d in _DEFS],
                         lambda h: h, sim)


def init_place_carry(sim: SimState, cand: jnp.ndarray,
                     pol: PolicyParams) -> PlaceCarry:
    return _dedup_switch(pol.policy_id, [d.init for d in _DEFS],
                         lambda h: h, sim, cand)


def host_row(sim: SimState, cfg: SimConfig, params: RunParams,
             pol: PolicyParams, carry: PlaceCarry, k, cand,
             used) -> jnp.ndarray:
    """The one scoring rule both engine paths evaluate: the f32[H]
    preference row for candidate ``k`` given the round's live state."""
    return _dedup_switch(
        pol.policy_id, [d.row for d in _DEFS],
        lambda h: (lambda s, p, w, cr, kk, cd, us:
                   h(s, cfg, p, w, cr, kk, cd, us)),
        sim, params, pol.weights, carry, k, cand, used)


def update_place_carry(sim: SimState, pol: PolicyParams, carry: PlaceCarry,
                       k, cand, hh, ok) -> PlaceCarry:
    return _dedup_switch(pol.policy_id, [d.update for d in _DEFS],
                         lambda h: h, sim, carry, k, cand, hh, ok)


def commit_place_carry(sched, carry: PlaceCarry):
    """Persist the round's carry across ticks.  Only the rotating pointer
    outlives the round; non-Round branches never move it, so the write is
    an identity for them."""
    return sched._replace(rr_pointer=carry.rr)


def migrate(sim: SimState, cfg: SimConfig, params: RunParams,
            pol: PolicyParams):
    return _dedup_switch(pol.policy_id, [d.migrate for d in _DEFS],
                         lambda h: (lambda s, p: h(s, cfg, p)), sim, params)


# ---------------------------------------------------------------------------
# The six registered branches (paper §3.5 + the PR 2 network-aware pair)
# ---------------------------------------------------------------------------
register(PolicyDef("firstfit", _row_firstfit))
register(PolicyDef("round", _row_round, update=_update_round))
register(PolicyDef("performance_first", _row_performance_first))
register(PolicyDef("jobgroup", _row_jobgroup, init=_init_coloc,
                   update=_update_coloc))
register(PolicyDef("netaware", _row_netaware, init=_init_coloc,
                   update=_update_coloc, migrate=congestion_migrate))
register(PolicyDef("overload_migrate", _row_firstfit,
                   migrate=overload_migrate))
