"""Container scheduling module (paper §3.5) — branch-free scoring.

A scheduling algorithm IS a weight vector.  The engine computes one shared
**feature bank** and every decision is a weighted sum:

* selection: ``priority[c] = sel_features(c) @ w`` ranked by
  :func:`rank_key` (lower = scheduled earlier);
* placement: ``score[h] = placement_features(h) @ w`` for each candidate,
  argmin over the feasible hosts (free CPU/mem, host utilization,
  round-robin recency, same-job co-location count, mean ``comm_cost`` to
  deployed peers, access-link utilization, cross-leaf peer fraction — the
  ``F_*`` enum in ``types.py``);
* migration: the trigger is a mask weight (``W_MIG_ENABLE``; 0 reproduces
  the old no-op branch exactly) and the destination is
  ``migration_features(h) @ w`` (host index, bottleneck path utilization
  from the source, cross-leaf indicator, worst fit).

There is no ``lax.switch``, no branch table and no per-policy code: the
six paper/DRAPS policies ship as named weight vectors in the registry
(one-hot or disjoint-support vectors, so each reproduces its former
branch's scores **bit-for-bit** — every feature is finite by construction
and a zero weight contributes an exact ``0.0``).  Consequences the old
branch dispatch could not offer:

* a policy-batched sweep pays ONE feature-bank evaluation per cell instead
  of evaluating every registered branch under ``vmap``
  (``docs/sweeps.md``);
* registering a policy never invalidates compiled programs — new policies
  are new *data* through the same executable;
* weight search (``repro.launch.tune``) is just a batch axis on
  ``PolicyParams.weights``.

Users extend by registering a weight vector — ``register("mine",
dict(row_worst_fit=1.0, sel_duration=0.1))`` — the paper's "flexible and
scalable interface for scheduling algorithms" with no code at all.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network
from repro.core.datacenter import SimConfig
from repro.core.types import (
    M_PATH_UTIL, NUM_MIG_FEATURES, NUM_POLICY_WEIGHTS, NUM_ROW_FEATURES,
    STATUS_COMMUNICATING, STATUS_INACTIVE, STATUS_MIGRATING, STATUS_RUNNING,
    STATUS_WAITING, W_MIG0, W_MIG_ENABLE, W_ROW0, W_RR_TRACK, W_SEL_DURATION,
    W_SEL_SUBMIT, WEIGHT_NAMES, PolicyParams, RunParams, SimState,
)

BIG = jnp.float32(1e18)          # host-score sentinel (infeasible)
INT_BIG = jnp.int32(2**31 - 1)   # selection-key sentinel (unschedulable)


# ---------------------------------------------------------------------------
# Shared predicates
# ---------------------------------------------------------------------------
def feasible_hosts(cap: jnp.ndarray, used: jnp.ndarray, ncont: jnp.ndarray,
                   req: jnp.ndarray, cfg: SimConfig) -> jnp.ndarray:
    """Hosts that can take a container requesting ``req``: resource headroom
    + a free container slot (``max_containers_per_host``, the per-host
    net-node cap).

    Takes the raw counters rather than the SimState so the engine can feed
    it either the live state (sequential path, migration sources) or the
    in-round counters carried by the batched admit scan — one predicate,
    every feasibility decision.
    """
    fits = ((used + req[None, :]) <= cap).all(axis=1)
    return fits & (ncont < cfg.max_containers_per_host)


def schedulable_mask(sim: SimState) -> jnp.ndarray:
    """Containers eligible for (re)placement: submitted+unscheduled or waiting."""
    st = sim.containers.status
    arrived = sim.containers.submit_t <= sim.t
    return arrived & ((st == STATUS_INACTIVE) | (st == STATUS_WAITING))


def rank_key(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Sortable i32 selection key: rank under lexicographic (values, index).

    A stable argsort gives every slot its rank (< C, so no overflow at any
    capacity — unlike ``values * C + index`` float encodings, which lose the
    index tie-break once the combined key exceeds f32's 2^24 integer range).
    Slots outside ``mask`` get ``INT_BIG``.

    The rank is the inverse of the sort permutation, so a second argsort
    computes it scatter-free — identical integers to the former
    ``zeros.at[order].set(arange)`` scatter, without XLA:CPU's slow
    batched-scatter lowering when the tick is vmapped over sweep cells.
    """
    order = jnp.argsort(values, stable=True)
    rank = jnp.argsort(order).astype(jnp.int32)
    return jnp.where(mask, rank, INT_BIG)


def select_key_fifo(sim: SimState) -> jnp.ndarray:
    """Paper default selection: earliest-submitted first, index tie-break.
    (== the generic :func:`select_key` with ``sel_submit=1`` and every other
    selection weight 0 — kept as the named reference.)"""
    return rank_key(sim.containers.submit_t, schedulable_mask(sim))


def _first_true(order_key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index minimizing order_key among mask; -1 if mask empty."""
    key = jnp.where(mask, order_key, BIG)
    return jnp.where(mask.any(), jnp.argmin(key), -1)


def soft_assign(row: jnp.ndarray, feas: jnp.ndarray,
                tau: jnp.ndarray) -> jnp.ndarray:
    """Softmax relaxation of ``argmin over the feasible hosts``.

    ``q[h] = softmax(-row/tau)[h]`` over ``feas``; infeasible hosts get an
    exact 0.0 and an all-infeasible row returns all-zero (NOT uniform — a
    no-decision contributes nothing to the surrogate sums).  NaN-safety
    under ``jax.grad`` is load-bearing: the row is shifted by its feasible
    minimum BEFORE the masked exp, so every exponent is finite and
    non-positive (``exp <= 1``) and no ``0 * inf`` appears in either the
    primal or the cotangent.  As ``tau -> 0`` the weights underflow to the
    exact one-hot of the hard argmin — the annealing limit the oracle
    tests rely on.
    """
    feas_f = feas.astype(row.dtype)
    lo = jnp.min(jnp.where(feas, row, BIG))
    shifted = jnp.where(feas, row - lo, 0.0)
    e = jnp.exp(-shifted / tau) * feas_f
    return e / jnp.maximum(e.sum(), jnp.float32(1e-30))


# ---------------------------------------------------------------------------
# The placement carry
#
# The one pytree every policy's round shares: Round's rotating pointer
# (tracked only when ``W_RR_TRACK`` is set) and the same-job co-location
# counts the F_COLOC / F_COMM / F_CROSS_LEAF features read.
# ---------------------------------------------------------------------------
class PlaceCarry(NamedTuple):
    rr: jnp.ndarray      # i32[]    Round's rotating last-used-host pointer
    counts: jnp.ndarray  # f32[K,H] deployed same-job containers per host
    # same-job peers on the HOST'S OWN leaf, per (candidate, host) — the
    # F_CROSS_LEAF numerator.  Maintained incrementally (exact integer
    # adds): the alternative, a segment_sum over leaf ids per admit step,
    # is a batched scatter inside the hot scan — the PR 4 anti-pattern.
    leafpeers: jnp.ndarray  # f32[K,H]


def same_job_host_counts(sim: SimState, cand: jnp.ndarray) -> jnp.ndarray:
    """[K, H] deployed same-job container count per host, per candidate.

    One ``segment_sum`` of the C deployed containers onto a small [K, H]
    table keyed by (first candidate sharing the container's job, host) —
    the pad-slot trick in two dimensions (slot K*H swallows containers
    matching no candidate).  Candidates sharing a job then gather the first
    sharer's row.  Replaces the K vmapped per-candidate scatter-adds of the
    PR 2 form (kept as :func:`same_job_host_counts_scatter`); counts are
    integer-valued, so the regrouped sum is exact and both forms agree
    bit-for-bit.
    """
    H = sim.hosts.cap.shape[0]
    K = cand.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    jobs_k = ct.job[cand]                                    # [K]
    eq = ct.job[:, None] == jobs_k[None, :]                  # [C, K]
    hit = eq.any(axis=1) & deployed
    k_first = jnp.argmax(eq, axis=1)                         # [C]
    hostc = jnp.clip(ct.host, 0, H - 1)
    seg = jnp.where(hit, k_first * H + hostc, K * H)
    table = jax.ops.segment_sum(
        hit.astype(jnp.float32), seg, num_segments=K * H + 1)[:K * H]
    kk_first = jnp.argmax(jobs_k[None, :] == jobs_k[:, None], axis=1)
    return table.reshape(K, H)[kk_first]


def same_job_host_counts_scatter(sim: SimState,
                                 cand: jnp.ndarray) -> jnp.ndarray:
    """PR 2 per-candidate scatter-add form — unit oracle for the segment-sum
    rewrite (tests/test_scatter_free.py)."""
    H = sim.hosts.cap.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    same = deployed[None, :] & (ct.job[None, :] == ct.job[cand][:, None])
    hostc = jnp.clip(ct.host, 0, H - 1)
    return jax.vmap(
        lambda s: jnp.zeros((H,), jnp.float32).at[hostc].add(s)
    )(same.astype(jnp.float32))


def _worst_fit_row(sim: SimState, used: jnp.ndarray) -> jnp.ndarray:
    """Most total normalized free resources first (lower key = better)."""
    free = (sim.hosts.cap - used) / jnp.maximum(sim.hosts.cap, 1e-6)
    return -free.sum(axis=1)


# ---------------------------------------------------------------------------
# The generic scoring hooks — the ONLY policy surface the engine consumes.
# Everything is a weighted sum over a feature bank, so a batch of policies
# is a batch axis on ``PolicyParams.weights`` and nothing else.
#
# EXACTNESS CONTRACT: every feature must be FINITE for every reachable
# state.  A zero weight then contributes an exact 0.0 to the dot product,
# which is what lets one-hot legacy vectors reproduce the former per-policy
# branches bit-for-bit (0.0 * inf would poison the score with NaN).
# ---------------------------------------------------------------------------
def select_key(sim: SimState, pol: PolicyParams) -> jnp.ndarray:
    """i32[C] selection ranks from the weighted container-priority score.

    ``priority = w[sel_submit] * submit_t + w[sel_duration] * duration``;
    lower = scheduled earlier, ``INT_BIG`` = not schedulable this tick.
    (``submit_t`` is +inf on unborn slots; they are masked out, and NaNs a
    zero submit-weight would produce there sort last without disturbing
    the ranks of schedulable containers.)
    """
    ct = sim.containers
    w = pol.weights
    priority = w[W_SEL_SUBMIT] * ct.submit_t + w[W_SEL_DURATION] * ct.duration
    return rank_key(priority, schedulable_mask(sim))


def init_place_carry(sim: SimState, cand: jnp.ndarray,
                     pol: PolicyParams) -> PlaceCarry:
    """One generic carry for every policy: the co-location counts feed the
    F_COLOC/F_COMM/F_CROSS_LEAF features (an exact 0.0 in the score when
    their weights are zero), the pointer starts from the persisted
    ``rr_pointer`` and only moves when ``W_RR_TRACK`` is set.

    The per-leaf peer totals are reduced ONCE per round here (and then
    maintained by elementwise adds in :func:`update_place_carry`), so the
    admit scan itself stays free of segment reductions."""
    H = sim.hosts.cap.shape[0]
    counts = same_job_host_counts(sim, cand)
    per_leaf = jax.vmap(lambda c: jax.ops.segment_sum(
        c, sim.hosts.leaf, num_segments=H))(counts)          # [K, leafslot]
    return PlaceCarry(rr=sim.sched.rr_pointer, counts=counts,
                      leafpeers=per_leaf[:, sim.hosts.leaf])


def _row_feature_columns(sim: SimState, cfg: SimConfig, params: RunParams,
                         carry: PlaceCarry, k, cand,
                         used: jnp.ndarray) -> tuple:
    """The shared feature columns (``F_*`` order) for candidate ``k`` —
    computed ONCE per admit step, whatever the weights select.  All
    columns are finite (the exactness contract)."""
    hosts = sim.hosts
    H = hosts.cap.shape[0]
    ct = sim.containers

    # recency: mod-distance past the rotating pointer.  With rr pinned at
    # -1 (untracked) this is exactly the host index — FirstFit's score.
    recency = jnp.mod(jnp.arange(H) - carry.rr - 1, H).astype(jnp.float32)
    neg_speed = -hosts.speed[:, ct.ctype[cand[k]]]
    free = (hosts.cap - used) / jnp.maximum(hosts.cap, 1e-6)     # [H, 3]
    worst = -free.sum(axis=1)

    cnt = carry.counts[k]                                        # [H]
    total = cnt.sum()
    has = total > 0
    coloc = jnp.where(has, -cnt, 0.0)
    comm = jnp.where(has, (cnt @ sim.net.comm_cost)
                     / jnp.maximum(total, 1.0), 0.0)
    fallback = jnp.where(has, 0.0, worst)

    host_util = (used / jnp.maximum(hosts.cap, 1e-6)).max(axis=1)
    # host i's access link is link i (network.build_network numbering)
    uplink = sim.net.link_util[:H]
    cross_leaf = jnp.where(has, (total - carry.leafpeers[k])
                           / jnp.maximum(total, 1.0), 0.0)
    return (recency, neg_speed, worst, coloc, comm, fallback,
            host_util, free[:, 0], free[:, 1], uplink, cross_leaf)


def placement_features(sim: SimState, cfg: SimConfig, params: RunParams,
                       carry: PlaceCarry, k, cand,
                       used: jnp.ndarray) -> jnp.ndarray:
    """The [H, NUM_ROW_FEATURES] bank view of the feature columns —
    the introspection/debugging surface (the hot path sums the columns
    directly, see :func:`host_row`)."""
    return jnp.stack(_row_feature_columns(sim, cfg, params, carry, k, cand,
                                          used), axis=1)


def host_row_cols(sim: SimState, cfg: SimConfig, params: RunParams,
                  pol: PolicyParams, carry: PlaceCarry, k, cand,
                  used) -> tuple:
    """:func:`host_row` plus the raw feature columns it was summed from —
    the soft-placement path needs both (the score for the softmax, the
    columns for the expected-cost surrogate) without paying the bank
    twice."""
    cols = _row_feature_columns(sim, cfg, params, carry, k, cand, used)
    w = pol.weights
    score = cols[0] * w[W_ROW0]
    for i in range(1, NUM_ROW_FEATURES):
        score = score + cols[i] * w[W_ROW0 + i]
    return score, cols


def host_row(sim: SimState, cfg: SimConfig, params: RunParams,
             pol: PolicyParams, carry: PlaceCarry, k, cand,
             used) -> jnp.ndarray:
    """The one scoring rule both engine paths evaluate: candidate ``k``'s
    f32[H] preference row = weighted sum of the feature columns (lower =
    better; argmin breaks ties toward the lowest host index).  Summed as
    an elementwise chain rather than a [H, F] matmul — no bank
    materialization inside the admit scan, and exactness is unaffected:
    legacy vectors have one-hot / disjoint-support weights, so every term
    but the live one is an exact 0.0 in any order.  Feasibility is NOT
    baked in — the engine masks infeasible hosts against its live
    resource counters so intra-round decisions see each other."""
    return host_row_cols(sim, cfg, params, pol, carry, k, cand, used)[0]


def update_place_carry(sim: SimState, pol: PolicyParams, carry: PlaceCarry,
                       k, cand, hh, ok) -> PlaceCarry:
    """Admit bookkeeping after candidate ``k`` lands on ``hh``: the pointer
    follows the admit when ``W_RR_TRACK`` is set, and every later same-job
    candidate's co-location column is raised (a masked column add — one
    float add, scatter-free) so intra-round decisions see each other and
    batched == sequential placements exactly."""
    track = pol.weights[W_RR_TRACK] > 0
    rr = jnp.where(ok & track, hh, carry.rr)
    same = sim.containers.job[cand] == sim.containers.job[cand[k]]
    hot = (jnp.arange(carry.counts.shape[1]) == hh) & ok
    counts = jnp.where(hot[None, :] & same[:, None],
                       carry.counts + 1.0, carry.counts)
    # the admitted peer lands on leaf[hh]: same-job candidates gain one
    # same-leaf peer at every host on that leaf (elementwise, exact)
    leaf = sim.hosts.leaf
    on_leaf = (leaf == leaf[hh]) & ok
    leafpeers = jnp.where(on_leaf[None, :] & same[:, None],
                          carry.leafpeers + 1.0, carry.leafpeers)
    return PlaceCarry(rr=rr, counts=counts, leafpeers=leafpeers)


def commit_place_carry(sched, carry: PlaceCarry):
    """Persist the round's carry across ticks.  Only the rotating pointer
    outlives the round; policies without ``W_RR_TRACK`` never move it, so
    the write is an identity for them."""
    return sched._replace(rr_pointer=carry.rr)


# ---------------------------------------------------------------------------
# Migration (paper §3.5 algorithm 1, DRAPS-derived) — weighted like
# placement: shared overload-source rule, scored destination, mask-weight
# trigger.
# ---------------------------------------------------------------------------
def _overload_source(sim: SimState, cfg: SimConfig, params: RunParams):
    """Shared source/container selection for every migrating policy.

    Returns (src, cont, src_c, dst_mask):
    * src: host with max over-threshold utilization on any resource (-1 none);
    * cont: RUNNING container on it consuming the most of the host's
      bottleneck resource;
    * dst_mask: feasible hosts with all utilizations < idle threshold.
    """
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)   # [H, 3]
    worst = util.max(axis=1)
    overloaded = worst > params.overload_threshold
    H = worst.shape[0]
    src = _first_true(-worst, overloaded)
    src_c = jnp.clip(src, 0, H - 1)
    bottleneck = jnp.argmax(util[src_c])                       # resource index
    st = sim.containers.status
    movable = (st == STATUS_RUNNING) & (sim.containers.host == src_c)
    usage = sim.containers.req[:, bottleneck]
    cont = _first_true(-usage, movable)
    C = movable.shape[0]
    cont_c = jnp.clip(cont, 0, C - 1)

    req = sim.containers.req[cont_c]
    feas = feasible_hosts(sim.hosts.cap, sim.hosts.used,
                          sim.hosts.n_containers, req, cfg)
    idle = (util < params.idle_threshold).all(axis=1)
    dst_mask = feas & idle & (jnp.arange(H) != src_c)
    return src, cont, src_c, dst_mask


def migration_features(sim: SimState, src_c: jnp.ndarray) -> jnp.ndarray:
    """[H, NUM_MIG_FEATURES] destination bank (``M_*`` enum, all finite):
    host index, bottleneck ECMP-path utilization from the source
    (``network.path_util_row``, O(H·4)), cross-leaf indicator, worst fit."""
    H = sim.hosts.cap.shape[0]
    idx = jnp.arange(H, dtype=jnp.float32)
    putil = network.path_util_row(sim.net, src_c)              # f32[H]
    cross = (sim.hosts.leaf != sim.hosts.leaf[src_c]).astype(jnp.float32)
    return jnp.stack([idx, putil, cross,
                      _worst_fit_row(sim, sim.hosts.used)], axis=1)


def _migration_pair(src, cont, dst):
    ok = (src >= 0) & (cont >= 0) & (dst >= 0)
    return jnp.where(ok, cont, -1), jnp.where(ok, dst, -1)


def _migrate_core(sim: SimState, cfg: SimConfig, params: RunParams,
                  pol: PolicyParams):
    """The shared decision: hard (container | -1, dst | -1) outputs plus the
    destination score row / feature bank / mask the soft surrogate reads."""
    w = pol.weights
    src, cont, src_c, dst_mask = _overload_source(sim, cfg, params)
    feats = migration_features(sim, src_c)
    score = feats @ w[W_MIG0:W_MIG0 + NUM_MIG_FEATURES]
    dst = _first_true(score, dst_mask)
    cont_out, dst_out = _migration_pair(src, cont, dst)
    enabled = w[W_MIG_ENABLE] > 0
    minus1 = jnp.full((), -1, jnp.int32)
    return (jnp.where(enabled, cont_out, minus1),
            jnp.where(enabled, dst_out, minus1), feats, score, dst_mask)


def migrate(sim: SimState, cfg: SimConfig, params: RunParams,
            pol: PolicyParams):
    """(container | -1, dst | -1) for this decision step.

    ``W_MIG_ENABLE`` is the trigger mask weight: 0 returns the uniform
    (-1, -1) no-op the engine's where-masks turn into an identity — the
    exact behavior of the old no-op branch, without a branch.
    """
    cont_out, dst_out, _, _, _ = _migrate_core(sim, cfg, params, pol)
    return cont_out, dst_out


def migrate_soft(sim: SimState, cfg: SimConfig, params: RunParams,
                 pol: PolicyParams):
    """:func:`migrate` plus the softmax surrogate terms.

    Returns ``(cont, dst, soft_val, soft_cnt)`` where the hard pair is
    bit-identical to :func:`migrate` and ``soft_val`` is the expected
    bottleneck-path utilization of the destination under
    ``q = soft_assign(score, dst_mask, tau)`` — differentiable in the
    migration weights (the score is ``features @ w[W_MIG0:]``).  Both soft
    terms are exact 0.0 when no migration actually fires this step, so
    disabled policies contribute nothing to the surrogate sums.
    """
    cont_out, dst_out, feats, score, dst_mask = _migrate_core(
        sim, cfg, params, pol)
    q = soft_assign(score, dst_mask, params.tau)
    fired = (dst_out >= 0).astype(jnp.float32)
    soft_val = fired * (q * feats[:, M_PATH_UTIL]).sum()
    return cont_out, dst_out, soft_val, fired


def overload_migrate(sim: SimState, cfg: SimConfig,
                     params: RunParams | None = None):
    """Relieve the most overloaded host; first-fit destination.
    (= the generic :func:`migrate` under ``overload_migrate``'s weights.)"""
    params = cfg.run_params() if params is None else params
    return migrate(sim, cfg, params, get_policy("overload_migrate"))


def congestion_migrate(sim: SimState, cfg: SimConfig,
                       params: RunParams | None = None):
    """Congestion-aware variant: same source/container selection, but the
    destination minimizes the bottleneck link utilization of the ECMP path
    the migration flow will traverse (index tie-break).
    (= the generic :func:`migrate` under ``netaware``'s weights.)"""
    params = cfg.run_params() if params is None else params
    return migrate(sim, cfg, params, get_policy("netaware"))


# ---------------------------------------------------------------------------
# Registry (paper: "easy extensibility of container scheduling algorithms")
# — a name -> canonical weight vector table.  Nothing here is baked into
# compiled programs: registration after a compiled run is fine, the new
# policy rides the existing executable as data.
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, np.ndarray] = {}


def weight_index(name: str) -> int:
    """Index of a named weight slot, failing loudly on unknown names — the
    ONE lookup every by-name surface (:func:`weight_vector`,
    :func:`get_policy` dict overrides, ``tune.sample_weights``) routes
    through."""
    try:
        return WEIGHT_NAMES.index(name)
    except ValueError:
        raise KeyError(f"unknown weight {name!r}; known: "
                       f"{list(WEIGHT_NAMES)}") from None


def weight_vector(**overrides) -> np.ndarray:
    """Build a canonical-length weight vector by name.

    Starts from the neutral defaults every built-in shares — FIFO selection
    (``sel_submit=1``) and the comm-cost model weights
    (``util``/``cross_leaf``, consumed by the ``NetState.comm_cost``
    refresh) — with every scoring weight at zero; keyword overrides use the
    ``types.WEIGHT_NAMES`` names.
    """
    w = np.zeros(NUM_POLICY_WEIGHTS, np.float32)
    w[weight_index("util")] = network.DEFAULT_UTIL_WEIGHT
    w[weight_index("cross_leaf")] = network.DEFAULT_CROSS_LEAF_MS
    w[weight_index("sel_submit")] = 1.0
    for name, val in overrides.items():
        w[weight_index(name)] = val
    return w


def validate_weights(w, context: str = "") -> None:
    """Loud canonical-length check.  A short vector would silently clamp
    jit-mode gathers (``weights[W_MIG_ENABLE]`` -> index 0) and a ragged
    batch would break stacking — reject both up front."""
    shape = jnp.shape(w)
    if len(shape) == 0 or shape[-1] != NUM_POLICY_WEIGHTS:
        raise ValueError(
            f"{context}weights must have the canonical length "
            f"{NUM_POLICY_WEIGHTS} (types.WEIGHT_NAMES), got shape {shape}")


def register(name: str, weights) -> np.ndarray:
    """Add (or replace, by name) a policy: a weight vector, or a dict of
    by-name overrides passed to :func:`weight_vector`.  Pure data — no
    compiled program is invalidated by a registration."""
    if isinstance(weights, dict):
        weights = weight_vector(**weights)
    # np.array (not asarray): the registry must own its vector — storing
    # the caller's array by reference would let later in-place mutation
    # silently rewrite a registered policy
    w = np.array(weights, np.float32)
    validate_weights(w, f"policy {name!r}: ")
    _REGISTRY[name] = w
    return w


def get_policy(name: str, weights=None) -> PolicyParams:
    """The data handle for a registered policy.

    ``weights`` overrides the registered vector — a full canonical-length
    vector, or a dict of by-name deltas (e.g. ``{"cross_leaf": 0.5}`` for
    a heavier spine penalty).  Variants are new *data*, not new code, so
    they share every compiled program.
    """
    try:
        base = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; known: {sorted(_REGISTRY)}") from None
    if weights is None:
        w = base
    elif isinstance(weights, dict):
        w = base.copy()
        for k, v in weights.items():
            w[weight_index(k)] = v
    else:
        w = np.asarray(weights, np.float32)
        validate_weights(w, f"policy {name!r}: ")
    return PolicyParams(weights=jnp.asarray(w, jnp.float32))


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# The six built-ins (paper §3.5 + the PR 2 network-aware pair) as weight
# vectors.  Each is one-hot (or disjoint-support) over features computed
# exactly as the former branches computed them, so every vector reproduces
# its PR 4 switch-dispatched run bit-for-bit
# (tests/test_policy_equivalence.py).
# ---------------------------------------------------------------------------
# FirstFit [36]: lowest-numbered feasible host (recency with rr pinned -1).
register("firstfit", dict(row_recency=1.0))
# Round (paper §3.5): first feasible host after the last used one.
register("round", dict(row_recency=1.0, rr_track=1.0))
# PerformanceFirst (DRAPS-derived): fastest host for the primary resource.
register("performance_first", dict(row_neg_speed=1.0))
# JobGroup (CA-WFD-derived): most same-job containers; worst fit while the
# job has none deployed.
register("jobgroup", dict(row_coloc=1.0, row_fallback_worst=1.0))
# NetAware: mean expected comm cost to deployed same-job peers under the
# current fabric state (NetState.comm_cost), worst-fit fallback;
# congestion-aware migration destination.
register("netaware", dict(row_comm=1.0, row_fallback_worst=1.0,
                          mig_enable=1.0, mig_path_util=1.0))
# FirstFit placement + DRAPS overload migration, first-fit destination.
register("overload_migrate", dict(row_recency=1.0, mig_enable=1.0,
                                  mig_idx=1.0))
