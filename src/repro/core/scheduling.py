"""Container scheduling module (paper §3.5) — unified score-based Policy API.

Every algorithm is expressed through ONE batched scoring interface:

* ``select_key(sim) -> i32[C]`` — selection order over containers (lower =
  scheduled earlier, ``INT_BIG`` = not schedulable this tick);
* ``place_score(sim, cand, cfg) -> f32[K, H]`` — per-candidate host
  preference (lower = better), computed once per placement round;
* optional ``DynamicTerm`` — a scan-carried score component for policies
  whose host preference depends on decisions made earlier in the same round
  (Round's rotating pointer, JobGroup/NetAware same-job co-location counts).

Both engine paths consume the SAME hooks: the batched conflict-resolved
round (``engine._place_batched``) and the sequential reference path
(``engine._place_sequential``, a K=1 degenerate round applied
``placements_per_tick`` times) — so batched == sequential placements by
construction for every registered policy, including the co-location ones.

Migration signature: ``migrate(sim, cfg) -> (container | -1, dst | -1)``.
Users extend by registering a Policy — the paper's "flexible and scalable
interface for scheduling algorithms".
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import network
from repro.core.datacenter import SimConfig
from repro.core.types import (
    STATUS_COMMUNICATING, STATUS_INACTIVE, STATUS_MIGRATING, STATUS_RUNNING,
    STATUS_WAITING, SimState,
)

BIG = jnp.float32(1e18)          # host-score sentinel (infeasible)
INT_BIG = jnp.int32(2**31 - 1)   # selection-key sentinel (unschedulable)


# ---------------------------------------------------------------------------
# Shared predicates
# ---------------------------------------------------------------------------
def feasible_hosts(cap: jnp.ndarray, used: jnp.ndarray, ncont: jnp.ndarray,
                   req: jnp.ndarray, cfg: SimConfig) -> jnp.ndarray:
    """Hosts that can take a container requesting ``req``: resource headroom
    + a free container slot (``max_containers_per_host``, the per-host
    net-node cap).

    Takes the raw counters rather than the SimState so the engine can feed
    it either the live state (sequential path, migration sources) or the
    in-round counters carried by the batched admit scan — one predicate,
    every feasibility decision.
    """
    fits = ((used + req[None, :]) <= cap).all(axis=1)
    return fits & (ncont < cfg.max_containers_per_host)


def schedulable_mask(sim: SimState) -> jnp.ndarray:
    """Containers eligible for (re)placement: submitted+unscheduled or waiting."""
    st = sim.containers.status
    arrived = sim.containers.submit_t <= sim.t
    return arrived & ((st == STATUS_INACTIVE) | (st == STATUS_WAITING))


def rank_key(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Sortable i32 selection key: rank under lexicographic (values, index).

    A stable argsort gives every slot its rank (< C, so no overflow at any
    capacity — unlike ``values * C + index`` float encodings, which lose the
    index tie-break once the combined key exceeds f32's 2^24 integer range).
    Slots outside ``mask`` get ``INT_BIG``.
    """
    C = values.shape[0]
    order = jnp.argsort(values, stable=True)
    rank = jnp.zeros((C,), jnp.int32).at[order].set(
        jnp.arange(C, dtype=jnp.int32))
    return jnp.where(mask, rank, INT_BIG)


def select_key_fifo(sim: SimState) -> jnp.ndarray:
    """Paper default selection: earliest-submitted first, index tie-break."""
    return rank_key(sim.containers.submit_t, schedulable_mask(sim))


def _first_true(order_key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index minimizing order_key among mask; -1 if mask empty."""
    key = jnp.where(mask, order_key, BIG)
    return jnp.where(mask.any(), jnp.argmin(key), -1)


# ---------------------------------------------------------------------------
# Static placement scores (paper §3.5 algorithms 2-3)
#
# ``place_score(sim, cand, cfg) -> f32[K, H]``: per-candidate host preference
# (lower = better; argmin breaks ties toward the lowest host index).
# Feasibility is NOT baked in — the engine masks infeasible hosts against its
# live resource counters so intra-round decisions see each other.
# ---------------------------------------------------------------------------
def score_firstfit(sim: SimState, cand: jnp.ndarray,
                   cfg: SimConfig) -> jnp.ndarray:
    """FirstFit [36]: lowest-numbered host satisfying the constraints."""
    H = sim.hosts.cap.shape[0]
    return jnp.broadcast_to(jnp.arange(H, dtype=jnp.float32),
                            (cand.shape[0], H))


def score_performance_first(sim: SimState, cand: jnp.ndarray,
                            cfg: SimConfig) -> jnp.ndarray:
    """PerformanceFirst (DRAPS-derived): fastest host for the candidate's
    primary resource."""
    ctype = sim.containers.ctype[cand]                       # [K]
    return -sim.hosts.speed.T[ctype]                         # [K, H]


# ---------------------------------------------------------------------------
# Scan-carried dynamic terms
#
# A DynamicTerm replaces the static score row for policies whose preference
# depends on the round's earlier decisions.  The carry is a pytree threaded
# through the engine's admit scan:
#   init(sim, cand, cfg) -> carry            once per round
#   row(sim, cfg, carry, k, cand, used) -> f32[H]   per candidate
#   update(sim, cfg, carry, k, cand, hh, ok) -> carry   after each admit
#   commit(sched, carry) -> sched            persist across ticks (Round)
# ---------------------------------------------------------------------------
def _commit_noop(sched, carry):
    return sched


@dataclasses.dataclass(frozen=True)
class DynamicTerm:
    init: Callable
    row: Callable
    update: Callable
    commit: Callable = _commit_noop


# --- Round (paper §3.5 algorithm: first feasible host after the last used) --
def _round_init(sim: SimState, cand: jnp.ndarray, cfg: SimConfig):
    return sim.sched.rr_pointer


def _round_row(sim: SimState, cfg: SimConfig, rr, k, cand, used):
    H = sim.hosts.cap.shape[0]
    return jnp.mod(jnp.arange(H) - rr - 1, H).astype(jnp.float32)


def _round_update(sim: SimState, cfg: SimConfig, rr, k, cand, hh, ok):
    return jnp.where(ok, hh, rr)


def _round_commit(sched, rr):
    return sched._replace(rr_pointer=rr)


ROUND_DYNAMIC = DynamicTerm(_round_init, _round_row, _round_update,
                            _round_commit)


# --- Same-job co-location carry (JobGroup, NetAware) -----------------------
def same_job_host_counts(sim: SimState, cand: jnp.ndarray) -> jnp.ndarray:
    """[K, H] deployed same-job container count per host, per candidate."""
    H = sim.hosts.cap.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    same = deployed[None, :] & (ct.job[None, :] == ct.job[cand][:, None])
    hostc = jnp.clip(ct.host, 0, H - 1)
    return jax.vmap(
        lambda s: jnp.zeros((H,), jnp.float32).at[hostc].add(s)
    )(same.astype(jnp.float32))


def _coloc_init(sim: SimState, cand: jnp.ndarray, cfg: SimConfig):
    return same_job_host_counts(sim, cand)


def _coloc_update(sim: SimState, cfg: SimConfig, counts, k, cand, hh, ok):
    """Admitting candidate k onto host hh raises the co-location count of
    every later same-job candidate — the intra-round carry that makes the
    batched round match the sequential reference exactly."""
    same = sim.containers.job[cand] == sim.containers.job[cand[k]]
    inc = same.astype(jnp.float32) * ok.astype(jnp.float32)
    return counts.at[:, hh].add(inc)


def _worst_fit_row(sim: SimState, used: jnp.ndarray) -> jnp.ndarray:
    """Most total normalized free resources first (lower key = better)."""
    free = (sim.hosts.cap - used) / jnp.maximum(sim.hosts.cap, 1e-6)
    return -free.sum(axis=1)


def _jobgroup_row(sim: SimState, cfg: SimConfig, counts, k, cand, used):
    """JobGroup (CA-WFD-derived): host holding the most same-job containers;
    worst-fit on free resources while the job has none deployed."""
    cnt = counts[k]
    return jnp.where(cnt.sum() > 0, -cnt, _worst_fit_row(sim, used))


JOBGROUP_DYNAMIC = DynamicTerm(_coloc_init, _jobgroup_row, _coloc_update)


def _netaware_row(sim: SimState, cfg: SimConfig, counts, k, cand, used):
    """NetAware: mean expected communication cost from each host to the
    candidate's deployed same-job peers, under the current fabric state.

    ``NetState.comm_cost`` (delay matrix + bottleneck link utilization along
    the ECMP path + cross-leaf penalty, refreshed with the delay matrix)
    prices every host pair; peers placed earlier in the same round are in
    ``counts`` via the co-location carry.  Jobs with no deployed peers fall
    back to worst-fit, like JobGroup.
    """
    cnt = counts[k]                                          # [H] peers/host
    cost = cnt @ sim.net.comm_cost                           # [H] total cost
    return jnp.where(cnt.sum() > 0, cost / jnp.maximum(cnt.sum(), 1.0),
                     _worst_fit_row(sim, used))


NETAWARE_DYNAMIC = DynamicTerm(_coloc_init, _netaware_row, _coloc_update)


# ---------------------------------------------------------------------------
# Migration (paper §3.5 algorithm 1, DRAPS-derived)
# ---------------------------------------------------------------------------
def _overload_source(sim: SimState, cfg: SimConfig):
    """Shared source/container selection for the migration policies.

    Returns (src, cont, src_c, dst_mask):
    * src: host with max over-threshold utilization on any resource (-1 none);
    * cont: RUNNING container on it consuming the most of the host's
      bottleneck resource;
    * dst_mask: feasible hosts with all utilizations < idle threshold.
    """
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)   # [H, 3]
    worst = util.max(axis=1)
    overloaded = worst > cfg.overload_threshold
    H = worst.shape[0]
    src = _first_true(-worst, overloaded)
    src_c = jnp.clip(src, 0, H - 1)
    bottleneck = jnp.argmax(util[src_c])                       # resource index

    st = sim.containers.status
    movable = (st == STATUS_RUNNING) & (sim.containers.host == src_c)
    usage = sim.containers.req[:, bottleneck]
    cont = _first_true(-usage, movable)
    C = movable.shape[0]
    cont_c = jnp.clip(cont, 0, C - 1)

    req = sim.containers.req[cont_c]
    feas = feasible_hosts(sim.hosts.cap, sim.hosts.used,
                          sim.hosts.n_containers, req, cfg)
    idle = (util < cfg.idle_threshold).all(axis=1)
    dst_mask = feas & idle & (jnp.arange(H) != src_c)
    return src, cont, src_c, dst_mask


def _migration_pair(src, cont, dst):
    ok = (src >= 0) & (cont >= 0) & (dst >= 0)
    return jnp.where(ok, cont, -1), jnp.where(ok, dst, -1)


def overload_migrate(sim: SimState, cfg: SimConfig):
    """Relieve the most overloaded host; first-fit destination.

    Returns (-1, -1) when no (source, container, destination) triple exists.
    """
    src, cont, src_c, dst_mask = _overload_source(sim, cfg)
    H = dst_mask.shape[0]
    dst = _first_true(jnp.arange(H, dtype=jnp.float32), dst_mask)
    return _migration_pair(src, cont, dst)


def congestion_migrate(sim: SimState, cfg: SimConfig):
    """Congestion-aware variant: same source/container selection, but the
    destination minimizes the bottleneck link utilization of the ECMP path
    the migration flow will traverse (index tie-break) — instead of blindly
    taking the first feasible idle host across a hot spine."""
    src, cont, src_c, dst_mask = _overload_source(sim, cfg)
    path_util = network.path_util_matrix(sim.net)[src_c]       # f32[H]
    dst = _first_true(path_util, dst_mask)
    return _migration_pair(src, cont, dst)


# ---------------------------------------------------------------------------
# Registry (paper: "easy extensibility of container scheduling algorithms")
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Policy:
    """Scheduling algorithm = selection key + placement score (+ migration).

    ``place_score`` may be omitted when ``dynamic`` fully determines the
    host preference (JobGroup, NetAware); ``dynamic`` may be omitted for
    purely static scores (FirstFit, PerformanceFirst).  The engine consumes
    either through :meth:`host_row`, identically on the batched and the
    derived sequential path.
    """

    name: str
    place_score: Callable | None = None  # (sim, cand, cfg) -> f32[K, H]
    select_key: Callable = select_key_fifo  # (sim) -> i32[C], INT_BIG = skip
    dynamic: DynamicTerm | None = None
    migrate: Callable | None = None      # (sim, cfg) -> (container, dst)

    def __post_init__(self):
        if self.place_score is None and self.dynamic is None:
            raise ValueError(
                f"policy {self.name!r} needs a place_score or a DynamicTerm")
        if self.place_score is not None and self.dynamic is not None:
            raise ValueError(
                f"policy {self.name!r}: a DynamicTerm replaces the static "
                "score row entirely — fold the static part into "
                "DynamicTerm.row instead of providing both")

    # -- engine hooks (no-ops when the policy has no dynamic term) ----------
    def host_row(self, sim, cfg, score, carry, k, cand, used) -> jnp.ndarray:
        """The one scoring rule both engine paths evaluate: the f32[H]
        preference row for candidate ``k`` given the round's live state."""
        if self.dynamic is None:
            return score[k]
        return self.dynamic.row(sim, cfg, carry, k, cand, used)

    def carry_init(self, sim, cand, cfg):
        return () if self.dynamic is None else self.dynamic.init(sim, cand, cfg)

    def carry_update(self, sim, cfg, carry, k, cand, hh, ok):
        if self.dynamic is None:
            return carry
        return self.dynamic.update(sim, cfg, carry, k, cand, hh, ok)

    def carry_commit(self, sched, carry):
        return sched if self.dynamic is None else self.dynamic.commit(
            sched, carry)


_REGISTRY: dict[str, Policy] = {}


def register(policy: Policy) -> Policy:
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> Policy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; known: {sorted(_REGISTRY)}") from None


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


register(Policy("firstfit", score_firstfit))
register(Policy("round", dynamic=ROUND_DYNAMIC))
register(Policy("performance_first", score_performance_first))
register(Policy("jobgroup", dynamic=JOBGROUP_DYNAMIC))
register(Policy("netaware", dynamic=NETAWARE_DYNAMIC,
                migrate=congestion_migrate))
register(Policy("overload_migrate", score_firstfit, migrate=overload_migrate))
