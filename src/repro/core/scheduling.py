"""Container scheduling module (paper §3.5).

Selection / Placement / Execution interfaces as pure functions over the SoA
state. All five paper algorithms are implemented; users extend by registering
a placement (and optionally a migration) function — exactly the paper's
"flexible and scalable interface for scheduling algorithms".

Placement signature:   place(sim, c_idx) -> (host_idx | -1, new_sched)
Migration signature:   migrate(sim)      -> (container | -1, dst | -1)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.datacenter import SimConfig
from repro.core.types import (
    STATUS_COMMUNICATING, STATUS_INACTIVE, STATUS_MIGRATING, STATUS_RUNNING,
    STATUS_WAITING, SimState,
)

BIG = jnp.float32(1e18)


# ---------------------------------------------------------------------------
# Shared predicates
# ---------------------------------------------------------------------------
def feasible_mask(sim: SimState, c: jnp.ndarray,
                  cfg: SimConfig) -> jnp.ndarray:
    """Hosts that can take container ``c``: resources + net-node cap."""
    req = sim.containers.req[c]                       # [3]
    fits = ((sim.hosts.used + req[None, :]) <= sim.hosts.cap).all(axis=1)
    slots = sim.hosts.n_containers < cfg.max_containers_per_host
    return fits & slots


def schedulable_mask(sim: SimState) -> jnp.ndarray:
    """Containers eligible for (re)placement: submitted+unscheduled or waiting."""
    st = sim.containers.status
    arrived = sim.containers.submit_t <= sim.t
    return arrived & ((st == STATUS_INACTIVE) | (st == STATUS_WAITING))


def select_key_fifo(sim: SimState) -> jnp.ndarray:
    """FIFO selection key over ALL containers: lower = scheduled earlier;
    ``BIG`` marks unschedulable slots.  Batched placement ranks by this key
    once per tick instead of re-running an argmin per placement."""
    mask = schedulable_mask(sim)
    C = mask.shape[0]
    return jnp.where(mask, sim.containers.submit_t * C + jnp.arange(C), BIG)


def select_fifo(sim: SimState) -> jnp.ndarray:
    """Paper default selection: earliest-submitted schedulable container."""
    key = select_key_fifo(sim)
    c = jnp.argmin(key)
    return jnp.where(key[c] < BIG, c, -1)


def _first_true(order_key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index minimizing order_key among mask; -1 if mask empty."""
    key = jnp.where(mask, order_key, BIG)
    return jnp.where(mask.any(), jnp.argmin(key), -1)


# ---------------------------------------------------------------------------
# Placement strategies (paper §3.5 algorithms 2-5)
# ---------------------------------------------------------------------------
def place_firstfit(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """FirstFit [36]: lowest-numbered host satisfying the constraints."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    return _first_true(jnp.arange(H, dtype=jnp.float32), mask), sim.sched


def place_round(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """Round [37]: first feasible host after the previously chosen one."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    offset = jnp.mod(jnp.arange(H) - sim.sched.rr_pointer - 1, H)
    h = _first_true(offset.astype(jnp.float32), mask)
    new_ptr = jnp.where(h >= 0, h, sim.sched.rr_pointer)
    return h, sim.sched._replace(rr_pointer=new_ptr)


def place_performance_first(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """PerformanceFirst (DRAPS-derived): fastest host for the container's
    primary resource among feasible hosts."""
    mask = feasible_mask(sim, c, cfg)
    ctype = sim.containers.ctype[c]
    speed = sim.hosts.speed[:, ctype]
    H = mask.shape[0]
    # maximize speed -> minimize (-speed); tie-break on host index
    key = -speed * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    return _first_true(key, mask), sim.sched


def place_jobgroup(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """JobGroup (CA-WFD-derived): host holding the most dependent containers
    (same job); if none deployed anywhere, worst-fit on available resources."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    job = sim.containers.job[c]
    st = sim.containers.status
    deployed = ((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                (st == STATUS_MIGRATING))
    same_job = deployed & (sim.containers.job == job) & (sim.containers.host >= 0)
    counts = jnp.zeros((H,), jnp.float32).at[
        jnp.clip(sim.containers.host, 0, H - 1)
    ].add(same_job.astype(jnp.float32))
    any_dep = counts.sum() > 0
    # worst-fit score: total normalized free resources
    free = (sim.hosts.cap - sim.hosts.used) / jnp.maximum(sim.hosts.cap, 1e-6)
    avail = free.sum(axis=1)
    key_dep = -counts * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    key_wf = -avail * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    key = jnp.where(any_dep, key_dep, key_wf)
    return _first_true(key, mask), sim.sched


# ---------------------------------------------------------------------------
# Batched placement scores (engine._place_batched)
#
# ``place_key(sim, cand, cfg) -> f32[K, H]``: per-candidate host preference
# (lower = better), computed ONCE per tick for the K ranked candidates.
# Feasibility is NOT baked in — the admit scan masks infeasible hosts against
# its live resource counters so intra-round decisions see each other.
# ``place_key_dynamic(sim, rr_pointer) -> f32[H]``, when present, REPLACES
# the candidate's row with one built from scheduler state carried through
# the admit scan (Round's rotating pointer is the one policy that needs
# this; its static ``place_key`` then only opts in to the batched path).
# ---------------------------------------------------------------------------
def place_key_firstfit(sim: SimState, cand: jnp.ndarray,
                       cfg: SimConfig) -> jnp.ndarray:
    H = sim.hosts.cap.shape[0]
    return jnp.broadcast_to(jnp.arange(H, dtype=jnp.float32),
                            (cand.shape[0], H))


def place_key_round_dynamic(sim: SimState,
                            rr_pointer: jnp.ndarray) -> jnp.ndarray:
    H = sim.hosts.cap.shape[0]
    return jnp.mod(jnp.arange(H) - rr_pointer - 1, H).astype(jnp.float32)


def place_key_performance_first(sim: SimState, cand: jnp.ndarray,
                                cfg: SimConfig) -> jnp.ndarray:
    H = sim.hosts.cap.shape[0]
    ctype = sim.containers.ctype[cand]                       # [K]
    speed = sim.hosts.speed.T[ctype]                         # [K, H]
    return -speed * H + jnp.arange(H, dtype=jnp.float32)[None, :] * 1e-3


def place_key_jobgroup(sim: SimState, cand: jnp.ndarray,
                       cfg: SimConfig) -> jnp.ndarray:
    """Same-job co-location counts + worst-fit fallback, per candidate.

    Counts are taken at the start of the round ([K, C] mask scattered onto
    hosts) — candidates admitted earlier in the same round do not re-raise
    the co-location score of later ones (documented approximation to the
    sequential reference; resource feasibility IS still live in the scan).
    """
    H = sim.hosts.cap.shape[0]
    ct = sim.containers
    st = ct.status
    deployed = (((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                 (st == STATUS_MIGRATING)) & (ct.host >= 0))
    same = deployed[None, :] & (ct.job[None, :] == ct.job[cand][:, None])
    hostc = jnp.clip(ct.host, 0, H - 1)
    counts = jax.vmap(
        lambda s: jnp.zeros((H,), jnp.float32).at[hostc].add(s)
    )(same.astype(jnp.float32))                              # [K, H]
    any_dep = counts.sum(axis=1, keepdims=True) > 0
    free = (sim.hosts.cap - sim.hosts.used) / jnp.maximum(sim.hosts.cap, 1e-6)
    avail = free.sum(axis=1)                                 # [H]
    tie = jnp.arange(H, dtype=jnp.float32) * 1e-3
    key_dep = -counts * H + tie[None, :]
    key_wf = (-avail * H + tie)[None, :]
    return jnp.where(any_dep, key_dep, key_wf)


# ---------------------------------------------------------------------------
# OverloadMigrate (paper §3.5 algorithm 1, DRAPS-derived)
# ---------------------------------------------------------------------------
def overload_migrate(sim: SimState, cfg: SimConfig):
    """Pick (container, destination) relieving the most overloaded host.

    * source: host with max over-threshold utilization on any resource;
    * container: deployed container on it consuming the most of the host's
      bottleneck resource (and not already migrating/communicating);
    * destination: feasible host with all utilizations < idle threshold.
    Returns (-1, -1) when no (source, container, destination) triple exists.
    """
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)   # [H, 3]
    worst = util.max(axis=1)
    overloaded = worst > cfg.overload_threshold
    H = worst.shape[0]
    src = _first_true(-worst * H + jnp.arange(H, dtype=jnp.float32) * 1e-3,
                      overloaded)
    src_c = jnp.clip(src, 0, H - 1)
    bottleneck = jnp.argmax(util[src_c])                       # resource index

    st = sim.containers.status
    movable = (st == STATUS_RUNNING) & (sim.containers.host == src_c)
    usage = sim.containers.req[:, bottleneck]
    C = movable.shape[0]
    cont = _first_true(-usage * C + jnp.arange(C, dtype=jnp.float32) * 1e-3,
                       movable)
    cont_c = jnp.clip(cont, 0, C - 1)

    req = sim.containers.req[cont_c]
    fits = ((sim.hosts.used + req[None, :]) <= sim.hosts.cap).all(axis=1)
    idle = (util < cfg.idle_threshold).all(axis=1)
    slots = sim.hosts.n_containers < cfg.max_containers_per_host
    dst_mask = fits & idle & slots & (jnp.arange(H) != src_c)
    dst = _first_true(jnp.arange(H, dtype=jnp.float32), dst_mask)

    ok = (src >= 0) & (cont >= 0) & (dst >= 0)
    return jnp.where(ok, cont, -1), jnp.where(ok, dst, -1)


# ---------------------------------------------------------------------------
# Registry (paper: "easy extensibility of container scheduling algorithms")
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Policy:
    """Scheduling algorithm = selection + placement (+ optional migration).

    ``place``/``select`` are the sequential per-container interface (the
    paper's).  ``select_key``/``place_key`` are the batched interface used
    by the engine's conflict-resolved placement round; policies without a
    ``place_key`` automatically run on the sequential reference path.
    """

    name: str
    place: Callable  # (sim, c, cfg) -> (host, sched)
    select: Callable = select_fifo
    migrate: Callable | None = None  # (sim, cfg) -> (container, dst)
    # batched interface
    select_key: Callable = select_key_fifo   # (sim) -> f32[C], BIG = skip
    place_key: Callable | None = None        # (sim, cand, cfg) -> f32[K, H]
    place_key_dynamic: Callable | None = None  # (sim, rr_pointer) -> f32[H]


_REGISTRY: dict[str, Policy] = {}


def register(policy: Policy) -> Policy:
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> Policy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; known: {sorted(_REGISTRY)}") from None


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


register(Policy("firstfit", place_firstfit, place_key=place_key_firstfit))
register(Policy("round", place_round, place_key=place_key_firstfit,
                place_key_dynamic=place_key_round_dynamic))
register(Policy("performance_first", place_performance_first,
                place_key=place_key_performance_first))
register(Policy("jobgroup", place_jobgroup, place_key=place_key_jobgroup))
register(Policy("overload_migrate", place_firstfit, migrate=overload_migrate,
                place_key=place_key_firstfit))
