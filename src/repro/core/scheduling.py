"""Container scheduling module (paper §3.5).

Selection / Placement / Execution interfaces as pure functions over the SoA
state. All five paper algorithms are implemented; users extend by registering
a placement (and optionally a migration) function — exactly the paper's
"flexible and scalable interface for scheduling algorithms".

Placement signature:   place(sim, c_idx) -> (host_idx | -1, new_sched)
Migration signature:   migrate(sim)      -> (container | -1, dst | -1)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.core.datacenter import SimConfig
from repro.core.types import (
    STATUS_COMMUNICATING, STATUS_INACTIVE, STATUS_MIGRATING, STATUS_RUNNING,
    STATUS_WAITING, SimState,
)

BIG = jnp.float32(1e18)


# ---------------------------------------------------------------------------
# Shared predicates
# ---------------------------------------------------------------------------
def feasible_mask(sim: SimState, c: jnp.ndarray,
                  cfg: SimConfig) -> jnp.ndarray:
    """Hosts that can take container ``c``: resources + net-node cap."""
    req = sim.containers.req[c]                       # [3]
    fits = ((sim.hosts.used + req[None, :]) <= sim.hosts.cap).all(axis=1)
    slots = sim.hosts.n_containers < cfg.max_containers_per_host
    return fits & slots


def schedulable_mask(sim: SimState) -> jnp.ndarray:
    """Containers eligible for (re)placement: submitted+unscheduled or waiting."""
    st = sim.containers.status
    arrived = sim.containers.submit_t <= sim.t
    return arrived & ((st == STATUS_INACTIVE) | (st == STATUS_WAITING))


def select_fifo(sim: SimState) -> jnp.ndarray:
    """Paper default selection: earliest-submitted schedulable container."""
    mask = schedulable_mask(sim)
    C = mask.shape[0]
    key = jnp.where(mask, sim.containers.submit_t * C + jnp.arange(C), BIG)
    c = jnp.argmin(key)
    return jnp.where(mask.any(), c, -1)


def _first_true(order_key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Index minimizing order_key among mask; -1 if mask empty."""
    key = jnp.where(mask, order_key, BIG)
    return jnp.where(mask.any(), jnp.argmin(key), -1)


# ---------------------------------------------------------------------------
# Placement strategies (paper §3.5 algorithms 2-5)
# ---------------------------------------------------------------------------
def place_firstfit(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """FirstFit [36]: lowest-numbered host satisfying the constraints."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    return _first_true(jnp.arange(H, dtype=jnp.float32), mask), sim.sched


def place_round(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """Round [37]: first feasible host after the previously chosen one."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    offset = jnp.mod(jnp.arange(H) - sim.sched.rr_pointer - 1, H)
    h = _first_true(offset.astype(jnp.float32), mask)
    new_ptr = jnp.where(h >= 0, h, sim.sched.rr_pointer)
    return h, sim.sched._replace(rr_pointer=new_ptr)


def place_performance_first(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """PerformanceFirst (DRAPS-derived): fastest host for the container's
    primary resource among feasible hosts."""
    mask = feasible_mask(sim, c, cfg)
    ctype = sim.containers.ctype[c]
    speed = sim.hosts.speed[:, ctype]
    H = mask.shape[0]
    # maximize speed -> minimize (-speed); tie-break on host index
    key = -speed * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    return _first_true(key, mask), sim.sched


def place_jobgroup(sim: SimState, c: jnp.ndarray, cfg: SimConfig):
    """JobGroup (CA-WFD-derived): host holding the most dependent containers
    (same job); if none deployed anywhere, worst-fit on available resources."""
    mask = feasible_mask(sim, c, cfg)
    H = mask.shape[0]
    job = sim.containers.job[c]
    st = sim.containers.status
    deployed = ((st == STATUS_RUNNING) | (st == STATUS_COMMUNICATING) |
                (st == STATUS_MIGRATING))
    same_job = deployed & (sim.containers.job == job) & (sim.containers.host >= 0)
    counts = jnp.zeros((H,), jnp.float32).at[
        jnp.clip(sim.containers.host, 0, H - 1)
    ].add(same_job.astype(jnp.float32))
    any_dep = counts.sum() > 0
    # worst-fit score: total normalized free resources
    free = (sim.hosts.cap - sim.hosts.used) / jnp.maximum(sim.hosts.cap, 1e-6)
    avail = free.sum(axis=1)
    key_dep = -counts * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    key_wf = -avail * H + jnp.arange(H, dtype=jnp.float32) * 1e-3
    key = jnp.where(any_dep, key_dep, key_wf)
    return _first_true(key, mask), sim.sched


# ---------------------------------------------------------------------------
# OverloadMigrate (paper §3.5 algorithm 1, DRAPS-derived)
# ---------------------------------------------------------------------------
def overload_migrate(sim: SimState, cfg: SimConfig):
    """Pick (container, destination) relieving the most overloaded host.

    * source: host with max over-threshold utilization on any resource;
    * container: deployed container on it consuming the most of the host's
      bottleneck resource (and not already migrating/communicating);
    * destination: feasible host with all utilizations < idle threshold.
    Returns (-1, -1) when no (source, container, destination) triple exists.
    """
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)   # [H, 3]
    worst = util.max(axis=1)
    overloaded = worst > cfg.overload_threshold
    H = worst.shape[0]
    src = _first_true(-worst * H + jnp.arange(H, dtype=jnp.float32) * 1e-3,
                      overloaded)
    src_c = jnp.clip(src, 0, H - 1)
    bottleneck = jnp.argmax(util[src_c])                       # resource index

    st = sim.containers.status
    movable = (st == STATUS_RUNNING) & (sim.containers.host == src_c)
    usage = sim.containers.req[:, bottleneck]
    C = movable.shape[0]
    cont = _first_true(-usage * C + jnp.arange(C, dtype=jnp.float32) * 1e-3,
                       movable)
    cont_c = jnp.clip(cont, 0, C - 1)

    req = sim.containers.req[cont_c]
    fits = ((sim.hosts.used + req[None, :]) <= sim.hosts.cap).all(axis=1)
    idle = (util < cfg.idle_threshold).all(axis=1)
    slots = sim.hosts.n_containers < cfg.max_containers_per_host
    dst_mask = fits & idle & slots & (jnp.arange(H) != src_c)
    dst = _first_true(jnp.arange(H, dtype=jnp.float32), dst_mask)

    ok = (src >= 0) & (cont >= 0) & (dst >= 0)
    return jnp.where(ok, cont, -1), jnp.where(ok, dst, -1)


# ---------------------------------------------------------------------------
# Registry (paper: "easy extensibility of container scheduling algorithms")
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    place: Callable  # (sim, c, cfg) -> (host, sched)
    select: Callable = select_fifo
    migrate: Callable | None = None  # (sim, cfg) -> (container, dst)


_REGISTRY: dict[str, Policy] = {}


def register(policy: Policy) -> Policy:
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> Policy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; known: {sorted(_REGISTRY)}") from None


def list_policies() -> list[str]:
    return sorted(_REGISTRY)


register(Policy("firstfit", place_firstfit))
register(Policy("round", place_round))
register(Policy("performance_first", place_performance_first))
register(Policy("jobgroup", place_jobgroup))
register(Policy("overload_migrate", place_firstfit, migrate=overload_migrate))
