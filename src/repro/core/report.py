"""Analysis-report module (paper §3.7): end-of-run evaluation metrics.

The paper reports average container response time, average container
runtime, and total cost; plus the per-tick series used in Figs 4-10.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.core.types import STATUS_COMPLETED, SimState, TickMetrics


def summarize(final: SimState, metrics: TickMetrics) -> Dict[str, Any]:
    ct = final.containers
    status = np.asarray(ct.status)
    completed = status == STATUS_COMPLETED
    born = np.isfinite(np.asarray(ct.submit_t))
    started = np.asarray(ct.start_t) >= 0

    submit = np.asarray(ct.submit_t)
    start = np.asarray(ct.start_t)
    finish = np.asarray(ct.finish_t)

    resp = np.where(started & born, start - submit, np.nan)
    runtime = np.where(completed, finish - submit, np.nan)
    exec_time = np.where(completed, finish - start, np.nan)

    def nanmean(x):
        x = x[np.isfinite(x)]
        return float(x.mean()) if x.size else float("nan")

    return {
        "n_containers": int(born.sum()),
        "n_completed": int(completed.sum()),
        "completion_rate": float(completed.sum() / max(born.sum(), 1)),
        "avg_response_time": nanmean(resp),
        "avg_runtime": nanmean(runtime),           # submit -> finish
        "avg_exec_time": nanmean(exec_time),       # deploy -> finish
        "avg_comm_time": float(np.asarray(ct.comm_time)[born].mean()),
        "total_cost": float(final.total_cost),
        "total_migrations": int(np.asarray(ct.n_migrations).sum()),
        "mean_util_variance": float(np.asarray(metrics.util_variance).mean()),
        "peak_running": int(np.asarray(metrics.n_running).max()),
        "peak_deployed": int(np.asarray(metrics.n_deployed).max()),
        "peak_overloaded": int(np.asarray(metrics.n_overloaded).max()),
        "final_t": float(final.t),
    }


def timeseries(metrics: TickMetrics) -> Dict[str, np.ndarray]:
    """Stacked per-tick series as a plain dict of numpy arrays (CSV-ready)."""
    return {k: np.asarray(v) for k, v in metrics._asdict().items()}


def to_csv(metrics: TickMetrics, path: str) -> None:
    ts = timeseries(metrics)
    keys = list(ts.keys())
    rows = np.stack([ts[k].astype(np.float64) for k in keys], axis=1)
    header = ",".join(keys)
    np.savetxt(path, rows, delimiter=",", header=header, comments="")
