"""Analysis-report module (paper §3.7): end-of-run evaluation metrics.

The paper reports average container response time, average container
runtime, and total cost; plus the per-tick series used in Figs 4-10.
``sweep_summaries``/``sweep_table`` extend that to the sweep driver's
[P, S, N]-batched outputs: one summary row per (policy, scenario, seed)
cell and a grouped text table (seed-averaged, scenario rows x policy
columns) for any summary metric.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence

import jax
import numpy as np

from repro.core.stats import online_from_metrics
from repro.core.types import (STATUS_COMPLETED, OnlineSummary, SimState,
                              TickMetrics)


def json_clean(obj):
    """Recursively replace non-finite floats with None so summary rows
    serialize to STRICTLY valid JSON (``json.dump`` would happily emit the
    ``NaN`` literal that jq / JSON.parse / pandas reject; a zero-completion
    run makes ``avg_runtime`` etc. NaN)."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: json_clean(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_clean(v) for v in obj]
    return obj


def _online_keys(os: OnlineSummary) -> Dict[str, Any]:
    """The metrics-derived summary entries, from the ONE shape both
    collection modes share (``stats.OnlineSummary``) — stacked runs are
    folded through ``stats.online_from_metrics`` first, so a streamed run
    reports exactly the same keys as its stacked oracle (integer sums and
    peaks bit-for-bit, float means to ~1 ulp)."""
    n = max(int(os.n_ticks), 1)
    var = float(os.w_m2_util) / n
    return {
        "mean_util_variance": float(os.sum_util_var) / n,
        "mean_util": float(os.sum_mean_util) / n,
        "mean_flow_rate": float(os.sum_flow_rate) / n,
        # variance of mean host utilization over TIME (vs the per-tick
        # across-host variance above) — Welford/Chan, exact in f64
        "util_time_variance": var,
        "total_arrivals": int(os.sum_arrivals),
        "total_decisions": int(os.sum_decisions),
        "total_migration_starts": int(os.sum_migrations),
        "flow_ticks": int(os.sum_active_flows),
        "peak_running": int(os.peak_running),
        "peak_deployed": int(os.peak_deployed),
        "peak_overloaded": int(os.peak_overloaded),
        "peak_queue": int(os.peak_inactive),
        # soft-placement surrogate means (docs/autodiff.md) — 0.0 with
        # soft placement off (no admits were soft-scored, counts are 0)
        "soft_expected_comm": (float(os.sum_soft_comm)
                               / max(float(os.sum_soft_n), 1.0)),
        "soft_expected_util": (float(os.sum_soft_util)
                               / max(float(os.sum_soft_n), 1.0)),
        "soft_expected_mig_util": (float(os.sum_soft_mig)
                                   / max(float(os.sum_soft_mig_n), 1.0)),
        "soft_blend": (float(os.sum_soft_comm + os.sum_soft_util)
                       / max(float(os.sum_soft_n), 1.0)),
    }


def summarize(final: SimState,
              metrics: TickMetrics | OnlineSummary) -> Dict[str, Any]:
    """End-of-run summary from the final state plus EITHER a stacked
    per-tick series (``TickMetrics``, the default engine output) or a
    streaming fold (``stats.OnlineSummary`` from ``run_sim(chunk=...)``)."""
    ct = final.containers
    status = np.asarray(ct.status)
    completed = status == STATUS_COMPLETED
    born = np.isfinite(np.asarray(ct.submit_t))
    started = np.asarray(ct.start_t) >= 0

    submit = np.asarray(ct.submit_t)
    start = np.asarray(ct.start_t)
    finish = np.asarray(ct.finish_t)

    resp = np.where(started & born, start - submit, np.nan)
    runtime = np.where(completed, finish - submit, np.nan)
    exec_time = np.where(completed, finish - start, np.nan)

    def nanmean(x):
        x = x[np.isfinite(x)]
        return float(x.mean()) if x.size else float("nan")

    comm_time = np.asarray(ct.comm_time)[born]
    rep = {
        "n_containers": int(born.sum()),
        "n_completed": int(completed.sum()),
        "completion_rate": float(completed.sum() / max(born.sum(), 1)),
        "avg_response_time": nanmean(resp),
        "avg_runtime": nanmean(runtime),           # submit -> finish
        "avg_exec_time": nanmean(exec_time),       # deploy -> finish
        # empty-slice mean warns and an all-unborn state has no comm series;
        # zero completions / zero arrivals must stay a summarizable run
        "avg_comm_time": float(comm_time.mean()) if comm_time.size
        else float("nan"),
        "total_cost": float(final.total_cost),
        "total_migrations": int(np.asarray(ct.n_migrations).sum()),
        "final_t": float(final.t),
    }
    if not isinstance(metrics, OnlineSummary):
        metrics = online_from_metrics(metrics)
    rep.update(_online_keys(metrics))
    return rep


def timeseries(metrics: TickMetrics) -> Dict[str, np.ndarray]:
    """Stacked per-tick series as a plain dict of numpy arrays (CSV-ready)."""
    return {k: np.asarray(v) for k, v in metrics._asdict().items()}


def to_csv(metrics: TickMetrics, path: str) -> None:
    ts = timeseries(metrics)
    keys = list(ts.keys())
    rows = np.stack([ts[k].astype(np.float64) for k in keys], axis=1)
    header = ",".join(keys)
    np.savetxt(path, rows, delimiter=",", header=header, comments="")


# ---------------------------------------------------------------------------
# Sweep reporting: [P, S, N]-batched finals/metrics -> rows -> grouped table
# ---------------------------------------------------------------------------
def sweep_summaries(finals: SimState, metrics: TickMetrics | OnlineSummary,
                    policies: Sequence[str], scenarios: Sequence[str],
                    seeds: Sequence[int]) -> List[Dict[str, Any]]:
    """One :func:`summarize` row per sweep cell, tagged with its coordinates.

    ``finals``/``metrics`` carry leading [P, S, N] axes (policy, scenario,
    seed) as returned by ``repro.launch.sweep.run_sweep`` — ``metrics`` is
    either the stacked [P, S, N, T] series or the streaming sweep's
    [P, S, N] ``OnlineSummary`` fold.  Each cell's row is numerically
    identical to summarizing the corresponding standalone ``run_sim`` —
    the sweep acceptance property.
    """
    finals_np = jax.tree.map(np.asarray, finals)
    metrics_np = jax.tree.map(np.asarray, metrics)
    rows = []
    for p, pol in enumerate(policies):
        for s, scen in enumerate(scenarios):
            for n, seed in enumerate(seeds):
                cell = lambda x: x[p, s, n]
                rep = summarize(jax.tree.map(cell, finals_np),
                                jax.tree.map(cell, metrics_np))
                rep.update(policy=pol, scenario=scen, seed=int(seed))
                rows.append(rep)
    return rows


def tune_table(weights, scores, objective: str = "avg_runtime",
               top: int = 10, minimize: bool = True) -> str:
    """Best-weights table for a weight search (``repro.launch.tune``).

    ``weights`` is the [W, NUM_POLICY_WEIGHTS] sample matrix, ``scores``
    the per-sample objective in the metric's TRUE sign (``minimize``
    gives the ranking direction; NaN = the sample failed the objective
    somewhere and sorts last either way).  Only the weight columns that
    actually vary across samples are shown — the searched dimensions.
    """
    from repro.core.types import WEIGHT_NAMES
    w = np.asarray(weights, np.float64)
    s = np.asarray(scores, np.float64)
    order = np.argsort(s if minimize else -s)  # NaNs sort last either way
    varying = [j for j in range(w.shape[1])
               if np.unique(w[:, j]).size > 1] or [0]
    cols = [WEIGHT_NAMES[j] for j in varying]
    width = max(12, max(len(c) for c in cols) + 2)
    direction = "lower = better" if minimize else "higher = better"
    lines = [f"best weights by {objective} ({direction})",
             "".join(["rank  sample  ", objective.rjust(14)]
                     + [c.rjust(width) for c in cols])]
    for rank, i in enumerate(order[:top]):
        val = f"{s[i]:.4f}" if np.isfinite(s[i]) else "nan"
        lines.append("".join([f"{rank:<6d}w{i:03d}    ", val.rjust(14)]
                             + [f"{w[i, j]:.4f}".rjust(width)
                                for j in varying]))
    return "\n".join(lines)


def sweep_table(rows: Sequence[Dict[str, Any]],
                value: str = "avg_runtime") -> str:
    """Grouped summary table: scenario rows x policy columns, the ``value``
    metric averaged over seeds — the sweep-level view of paper Figs 4-10.
    """
    policies = sorted({r["policy"] for r in rows})
    scenarios = list(dict.fromkeys(r["scenario"] for r in rows))
    cells = {}
    for r in rows:
        cells.setdefault((r["scenario"], r["policy"]), []).append(r[value])
    width = max(12, max(len(p) for p in policies) + 2)
    swidth = max(10, max(len(s) for s in scenarios) + 2)
    lines = [f"{value} (mean over seeds)",
             "".join([" " * swidth] + [p.rjust(width) for p in policies])]
    for scen in scenarios:
        cols = []
        for pol in policies:
            vals = np.asarray(cells.get((scen, pol), []), np.float64)
            vals = vals[np.isfinite(vals)]
            cols.append((f"{vals.mean():.3f}" if vals.size else "nan")
                        .rjust(width))
        lines.append("".join([scen.ljust(swidth)] + cols))
    return "\n".join(lines)
