"""Discrete event driver module (paper §3.6), tensor-native.

The paper drives eight SimPy processes, all with a 1-second period
(Table 3: generate_containers / schedule / run / communicate / migrate /
pre_treatment / save_stats / update_delay_matrix).  A set of processes that
all fire on the same period *is* a synchronous time-stepped simulation, so
the JAX port runs one ``lax.scan`` over ticks; each tick applies the paper's
processes as phase-ordered pure transitions:

    arrive -> schedule(+migrate decisions) -> flow rates -> communicate
           -> migrate(progress) -> execute(+comm triggers) -> complete
           -> cost/stats -> delay-matrix refresh (every K ticks)

Everything is masked SoA updates, so the whole simulation compiles to one
XLA program and ``vmap`` over seeds/scenarios is free — the capability the
paper's process-per-entity design fundamentally lacks (its Table 7 shows
0.8 s + ~1.3 MB of host overhead *per network node*).
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import network, scheduling, stats, workload
from repro.core.datacenter import SimConfig
from repro.kernels import resolve_kernel
from repro.core.scheduling import BIG, INT_BIG, feasible_hosts
from repro.core.types import (
    F_COMM, F_HOST_UTIL, STATUS_COMMUNICATING, STATUS_COMPLETED,
    STATUS_INACTIVE, STATUS_MIGRATING, STATUS_RUNNING, STATUS_UNBORN,
    STATUS_WAITING, W_CROSS_LEAF, W_MIG_ENABLE, W_UTIL, ContainerState,
    ExecPlan, HostState, NetState, PolicyParams, RunParams, SchedState,
    SimState, TickMetrics,
)

I32 = jnp.int32
F32 = jnp.float32


# ---------------------------------------------------------------------------
# State assembly
# ---------------------------------------------------------------------------
def init_sim(hosts: HostState, containers: ContainerState, net: NetState,
             seed: int = 0) -> SimState:
    return SimState(
        t=jnp.zeros((), F32),
        hosts=hosts,
        containers=containers,
        net=net,
        sched=SchedState(rr_pointer=jnp.array(-1, I32),
                         decisions=jnp.zeros((), I32),
                         migrations=jnp.zeros((), I32)),
        total_cost=jnp.zeros((), F32),
        rng=jax.random.PRNGKey(seed),
    )


# ---------------------------------------------------------------------------
# Resource bookkeeping helpers (masked, scan-safe for c == -1 / h == -1)
#
# The tick is SCATTER-FREE: every ``.at[idx].set/add`` state update is
# expressed as a where-mask (scalar/distinct indices — bit-exact, a
# single float add with identical operands) or a ``segment_sum`` reduction
# with the pad-slot trick (duplicate indices).  XLA:CPU lowers *batched*
# scatters off its fast path (~2x per sweep cell, docs/sweeps.md), so the
# scatter-heavy PR 3 tick forced ``lax.map`` over the policy/scenario sweep
# axes; the masked forms lower to elementwise selects that ``vmap``
# batches for free.  (The PR 3 scatter forms survived one deprecation
# cycle behind ``cfg.scatter_tick`` as the bit-for-bit oracle and are now
# gone; the cheap unit oracles that don't fork the tick remain —
# ``scheduling.same_job_host_counts_scatter``, dense ``flow_rates``.)
# ---------------------------------------------------------------------------
def _one_hot(n: int, idx: jnp.ndarray, ok: jnp.ndarray) -> jnp.ndarray:
    """bool[n] mask selecting ``idx`` when ``ok`` — the where-mask
    replacement for a scalar-index scatter."""
    return (jnp.arange(n) == idx) & ok


def _deploy(sim: SimState, c: jnp.ndarray, h: jnp.ndarray) -> SimState:
    C = sim.containers.status.shape[0]
    H = sim.hosts.cap.shape[0]
    cc = jnp.clip(c, 0, C - 1)
    hh = jnp.clip(h, 0, H - 1)
    ok = (c >= 0) & (h >= 0)
    ct = sim.containers
    hot_h = _one_hot(H, hh, ok)
    hot_c = _one_hot(C, cc, ok)
    req = ct.req[cc]
    hosts = sim.hosts._replace(
        used=jnp.where(hot_h[:, None], sim.hosts.used + req[None, :],
                       sim.hosts.used),
        n_containers=jnp.where(hot_h, sim.hosts.n_containers + 1,
                               sim.hosts.n_containers),
    )
    conts = ct._replace(
        status=jnp.where(hot_c, STATUS_RUNNING, ct.status),
        host=jnp.where(hot_c, hh, ct.host),
        start_t=jnp.where(hot_c & (ct.start_t < 0), sim.t, ct.start_t),
        retry=jnp.where(hot_c, 0, ct.retry),
    )
    return sim._replace(hosts=hosts, containers=conts)


def _free_resources(hosts: HostState, req: jnp.ndarray, host_idx: jnp.ndarray,
                    mask: jnp.ndarray) -> HostState:
    """Vectorized release of ``req[c]`` on ``host_idx[c]`` where ``mask``.

    Shared by both tick paths: per-host totals are accumulated with one
    ``segment_sum`` (pad slot H collects the unmasked rows) and subtracted
    in a single pass.  This regroups the float sum relative to the PR 3
    incremental ``.at[hh].add`` (delta first, then one subtract), which is
    exactly why it is shared — the scatter oracle and the scatter-free tick
    must agree bit-for-bit, and duplicate-index accumulation order is the
    one place the two formulations could round differently.
    """
    H = hosts.cap.shape[0]
    m = (mask & (host_idx >= 0))
    seg = jnp.where(m, host_idx, H)
    dreq = jax.ops.segment_sum(req * m.astype(F32)[:, None], seg,
                               num_segments=H + 1)[:H]
    dcnt = jax.ops.segment_sum(m.astype(I32), seg, num_segments=H + 1)[:H]
    return hosts._replace(
        used=hosts.used - dreq,
        n_containers=hosts.n_containers - dcnt,
    )


# ---------------------------------------------------------------------------
# Tick phases
# ---------------------------------------------------------------------------
def phase_arrive(sim: SimState) -> Tuple[SimState, jnp.ndarray]:
    """UNBORN -> INACTIVE once submit_t <= t (generate_containers process)."""
    ct = sim.containers
    arriving = (ct.status == STATUS_UNBORN) & (ct.submit_t <= sim.t)
    status = jnp.where(arriving, STATUS_INACTIVE, ct.status)
    return sim._replace(containers=ct._replace(status=status)), arriving.sum()


def _pick_host(sim: SimState, cfg: SimConfig, params: RunParams,
               policy: PolicyParams, carry, k, cand, used, feas):
    """Evaluate the policy's [H] preference row and argmin it over the
    feasible hosts — the single scoring step both placement paths share."""
    row = scheduling.host_row(sim, cfg, params, policy, carry, k, cand, used)
    return jnp.where(feas.any(), jnp.argmin(jnp.where(feas, row, BIG)), -1)


def _place_sequential(sim: SimState, cfg: SimConfig, params: RunParams,
                      policy: PolicyParams) -> SimState:
    """Sequential reference path, derived from the same scoring API.

    Each scan step is a K=1 degenerate placement round against the fully
    live state: re-evaluate the selection key, score the head candidate's
    hosts, deploy.  Because the hooks are shared with ``_place_batched``,
    the two paths produce identical placements whenever every candidate is
    feasible (an infeasible head blocks this path — the paper's semantics —
    while the batched round skips it).
    """
    H = sim.hosts.cap.shape[0]

    def place_body(s: SimState, _):
        key = scheduling.select_key(s, policy)
        c = jnp.argmin(key)
        valid = key[c] < INT_BIG
        cand = c[None]
        pcarry = scheduling.init_place_carry(s, cand, policy)
        feas = feasible_hosts(s.hosts.cap, s.hosts.used,
                              s.hosts.n_containers,
                              s.containers.req[c], cfg) & valid
        h = _pick_host(s, cfg, params, policy, pcarry, 0, cand,
                       s.hosts.used, feas)
        ok = h >= 0
        hh = jnp.clip(h, 0, H - 1)
        pcarry = scheduling.update_place_carry(s, policy, pcarry, 0, cand,
                                               hh, ok)
        s = s._replace(sched=scheduling.commit_place_carry(s.sched, pcarry))
        s = _deploy(s, jnp.where(valid, c, -1), h)
        s = s._replace(sched=s.sched._replace(
            decisions=s.sched.decisions + ok.astype(I32)))
        return s, None

    sim, _ = jax.lax.scan(place_body, sim, None,
                          length=cfg.placements_per_tick)
    return sim


def _scatter_to_containers(C: int, idx: jnp.ndarray, ok: jnp.ndarray):
    """Map a round's (distinct) per-decision indices onto the container
    axis WITHOUT a scatter: ``sel[c]`` marks containers hit by an admitted
    decision and ``slot_of[c]`` is the decision slot that hit them (0 where
    unhit — always masked by ``sel``).  O(C*K) compares, elementwise, so it
    vmaps for free where the ``.at[idx].set`` form forced XLA:CPU's slow
    batched-scatter lowering."""
    hit = (idx[None, :] == jnp.arange(C)[:, None]) & ok[None, :]   # [C, K]
    return hit.any(axis=1), jnp.argmax(hit, axis=1)


def _place_batched(sim: SimState, cfg: SimConfig, params: RunParams,
                   policy: PolicyParams):
    """Batched conflict-resolved placement round.

    Instead of ``placements_per_tick`` full select+score passes (each one
    O(C + H) work serialized by the scan), rank all schedulable containers
    once by the policy's selection key, take the top-K candidates
    (K = placements_per_tick << C), compute the policy's [K, H] placement
    score once, and admit the candidates with a short K-length scan that
    carries the live host ``used`` / slot counters plus the policy's
    dynamic-term carry — so later decisions observe both earlier ones'
    resource consumption AND their score impact (the rotating pointer, the
    co-location counts).  Container-state updates are applied in one
    vectorized pass of where-masks afterwards (top-k candidate indices are
    distinct).

    One deliberate semantic upgrade over the sequential reference: a
    candidate with no feasible host no longer blocks the rest of the round
    (the sequential argmin re-selected the same stuck head every step).

    Returns ``(sim', (soft_comm, soft_util, soft_n))``.  With
    ``cfg.soft_placement`` the admit scan ALSO carries the softmax
    expected-cost sums of the surrogate (``scheduling.soft_assign`` over
    the same score row the argmin consumes; docs/autodiff.md) — the
    decisions themselves are computed identically, so the final state is
    bit-for-bit the ``soft_placement=False`` state.  With it off the soft
    terms are constant 0.0 and this is exactly the old round.
    """
    C = sim.containers.status.shape[0]
    H = sim.hosts.cap.shape[0]
    K = min(cfg.placements_per_tick, C)
    soft_on = cfg.soft_placement

    key = scheduling.select_key(sim, policy)              # i32[C]
    neg_vals, cand = jax.lax.top_k(-key, K)               # K smallest keys
    valid = -neg_vals < INT_BIG                           # bool[K]
    req_k = sim.containers.req[cand]                      # [K, 3]
    pcarry0 = scheduling.init_place_carry(sim, cand, policy)

    def admit(carry, k):
        if soft_on:
            used, ncont, pcarry, s_comm, s_util, s_n = carry
        else:
            used, ncont, pcarry = carry
        feas = feasible_hosts(sim.hosts.cap, used, ncont,
                              req_k[k], cfg) & valid[k]
        if soft_on:
            row, cols = scheduling.host_row_cols(sim, cfg, params, policy,
                                                 pcarry, k, cand, used)
            h = jnp.where(feas.any(), jnp.argmin(jnp.where(feas, row, BIG)),
                          -1)
            q = scheduling.soft_assign(row, feas, params.tau)
            s_comm = s_comm + (q * cols[F_COMM]).sum()
            s_util = s_util + (q * cols[F_HOST_UTIL]).sum()
            s_n = s_n + feas.any().astype(F32)
        else:
            h = _pick_host(sim, cfg, params, policy, pcarry, k, cand, used,
                           feas)
        ok = h >= 0
        hh = jnp.clip(h, 0, H - 1)
        hot = _one_hot(H, hh, ok)
        used = jnp.where(hot[:, None], used + req_k[k][None, :], used)
        ncont = jnp.where(hot, ncont + 1, ncont)
        pcarry = scheduling.update_place_carry(sim, policy, pcarry, k, cand,
                                               hh, ok)
        if soft_on:
            return (used, ncont, pcarry, s_comm, s_util, s_n), h
        return (used, ncont, pcarry), h

    zero = jnp.zeros((), F32)
    if soft_on:
        init = (sim.hosts.used, sim.hosts.n_containers, pcarry0,
                zero, zero, zero)
        (used, ncont, pcarry, s_comm, s_util, s_n), chosen = jax.lax.scan(
            admit, init, jnp.arange(K))
    else:
        init = (sim.hosts.used, sim.hosts.n_containers, pcarry0)
        (used, ncont, pcarry), chosen = jax.lax.scan(admit, init,
                                                     jnp.arange(K))
        s_comm = s_util = s_n = zero

    ok = chosen >= 0
    hh = jnp.clip(chosen, 0, H - 1)
    ct = sim.containers
    sel, k_of = _scatter_to_containers(C, cand, ok)
    conts = ct._replace(
        status=jnp.where(sel, STATUS_RUNNING, ct.status),
        host=jnp.where(sel, hh[k_of], ct.host),
        start_t=jnp.where(sel & (ct.start_t < 0), sim.t, ct.start_t),
        retry=jnp.where(sel, 0, ct.retry),
    )
    hosts = sim.hosts._replace(used=used, n_containers=ncont)
    sched = scheduling.commit_place_carry(sim.sched, pcarry)._replace(
        decisions=sim.sched.decisions + ok.sum().astype(I32))
    return (sim._replace(hosts=hosts, containers=conts, sched=sched),
            (s_comm, s_util, s_n))


def _migrate_batched(sim: SimState, cfg: SimConfig, params: RunParams,
                     policy: PolicyParams):
    """Migration decision round.

    The decision scan carries only the fields a migration start can change
    (host ``used``/slot counters, container status) instead of threading the
    whole SimState; the chosen (container, destination) pairs are applied in
    one vectorized pass afterwards.  The migration rule is the weighted
    destination score of ``scheduling.migrate`` — a policy whose
    ``W_MIG_ENABLE`` weight is zero yields uniform (-1, -1) decisions and
    the round leaves the state untouched.

    Returns ``(sim', (soft_mig, soft_mig_n))``; with ``cfg.soft_placement``
    the scan also sums ``scheduling.migrate_soft``'s expected-path-util
    surrogate (hard decisions unchanged), otherwise constant 0.0.
    """
    C = sim.containers.status.shape[0]
    H = sim.hosts.cap.shape[0]
    soft_on = cfg.soft_placement

    def decide(carry, _):
        if soft_on:
            used, ncont, status, s_mig, s_n = carry
        else:
            used, ncont, status = carry
        view = sim._replace(
            hosts=sim.hosts._replace(used=used, n_containers=ncont),
            containers=sim.containers._replace(status=status))
        if soft_on:
            c, dst, sv, sc = scheduling.migrate_soft(view, cfg, params,
                                                     policy)
            s_mig, s_n = s_mig + sv, s_n + sc
        else:
            c, dst = scheduling.migrate(view, cfg, params, policy)
        ok = (c >= 0) & (dst >= 0)
        cc = jnp.clip(c, 0, C - 1)
        hh = jnp.clip(dst, 0, H - 1)
        # reserve destination resources for the duration of the transfer
        hot_h = _one_hot(H, hh, ok)
        used = jnp.where(hot_h[:, None],
                         used + sim.containers.req[cc][None, :], used)
        ncont = jnp.where(hot_h, ncont + 1, ncont)
        status = jnp.where(_one_hot(C, cc, ok), STATUS_MIGRATING, status)
        out = (jnp.where(ok, cc, -1), jnp.where(ok, hh, -1))
        if soft_on:
            return (used, ncont, status, s_mig, s_n), out
        return (used, ncont, status), out

    zero = jnp.zeros((), F32)
    if soft_on:
        init = (sim.hosts.used, sim.hosts.n_containers,
                sim.containers.status, zero, zero)
        (used, ncont, status, s_mig, s_n), (cs, dsts) = jax.lax.scan(
            decide, init, None, length=cfg.migrations_per_tick)
    else:
        init = (sim.hosts.used, sim.hosts.n_containers,
                sim.containers.status)
        (used, ncont, status), (cs, dsts) = jax.lax.scan(
            decide, init, None, length=cfg.migrations_per_tick)
        s_mig = s_n = zero

    ok = cs >= 0
    # chosen containers are distinct (STATUS_MIGRATING removes them from the
    # movable set mid-scan)
    sel, m_of = _scatter_to_containers(C, cs, ok)
    dst_arr = jnp.where(sel, dsts[m_of], -1)
    ct = sim.containers
    conts = ct._replace(
        status=status,                       # MIGRATING set inside the scan
        mig_dst=jnp.where(sel, dst_arr, ct.mig_dst),
        mig_bytes_left=jnp.where(sel, cfg.mig_kb_per_gb * ct.req[:, 1],
                                 ct.mig_bytes_left),
        retry=jnp.where(sel, 0, ct.retry),
    )
    hosts = sim.hosts._replace(used=used, n_containers=ncont)
    sched = sim.sched._replace(
        migrations=sim.sched.migrations + ok.sum().astype(I32))
    return (sim._replace(hosts=hosts, containers=conts, sched=sched),
            (s_mig, s_n))


def phase_schedule_soft(sim: SimState, cfg: SimConfig, policy: PolicyParams,
                        params: RunParams | None = None):
    """:func:`phase_schedule` plus the tick's soft-surrogate terms.

    Returns ``(sim', (soft_comm, soft_util, soft_n, soft_mig,
    soft_mig_n))`` — all exact 0.0 unless ``cfg.soft_placement``.  The
    state transition is identical to :func:`phase_schedule` either way.
    """
    params = cfg.run_params() if params is None else params
    if cfg.soft_placement and not cfg.batched_placement:
        raise ValueError(
            "SimConfig.soft_placement requires batched_placement: the "
            "sequential reference path has no admit round to relax")
    sim = sim._replace(sched=sim.sched._replace(
        decisions=jnp.zeros((), I32), migrations=jnp.zeros((), I32)))

    if cfg.batched_placement:
        sim, (s_comm, s_util, s_n) = _place_batched(sim, cfg, params, policy)
    else:
        sim = _place_sequential(sim, cfg, params, policy)
        s_comm = s_util = s_n = jnp.zeros((), F32)

    sim, (s_mig, s_mig_n) = _migrate_batched(sim, cfg, params, policy)
    return sim, (s_comm, s_util, s_n, s_mig, s_mig_n)


def phase_schedule(sim: SimState, cfg: SimConfig, policy: PolicyParams,
                   params: RunParams | None = None) -> SimState:
    """Paper ``schedule`` process: place up to ``placements_per_tick``
    containers, then start up to ``migrations_per_tick`` migrations.

    Both placement paths evaluate the same weighted scoring hooks
    (``scheduling.select_key`` / ``host_row`` / the ``PlaceCarry``);
    ``cfg.batched_placement`` selects the batched round or the K=1-derived
    sequential reference.  The migration round always runs — whether the
    policy migrates, and where to, is its weight vector, not Python
    structure.
    """
    return phase_schedule_soft(sim, cfg, policy, params)[0]


def pick_comm_peers(ct: ContainerState) -> jnp.ndarray:
    """Dependent-container peer: lowest-index *deployed* container of the same
    job.  Falls back to self (same-host => loopback-rate flow) when the
    container is the only deployed member of its job.

    Containers are grouped by job id, so the lowest-index deployed member of
    each job is a ``segment_min`` over job ids — O(C), no C x C candidate
    matrix.  The second-lowest member covers the case where a container *is*
    its job's lowest-index member (the dense version excluded self via the
    identity mask).
    """
    C = ct.status.shape[0]
    deployed = ((ct.status == STATUS_RUNNING) |
                (ct.status == STATUS_COMMUNICATING) |
                (ct.status == STATUS_MIGRATING)) & (ct.host >= 0)
    idx = jnp.arange(C)
    member = deployed & (ct.job >= 0)
    seg = jnp.clip(ct.job, 0, C - 1)                     # job ids < C
    key = jnp.where(member, idx, C)                      # C = "none" sentinel
    first = jax.ops.segment_min(key, seg, num_segments=C)    # [C] per job
    is_first = member & (idx == first[seg])
    key2 = jnp.where(member & ~is_first, idx, C)
    second = jax.ops.segment_min(key2, seg, num_segments=C)
    peer = jnp.where(first[seg] == idx, second[seg], first[seg])
    has = (ct.job >= 0) & (peer < C)
    return jnp.where(has, peer, idx)


def pick_comm_peers_dense(ct: ContainerState) -> jnp.ndarray:
    """O(C^2) reference implementation of :func:`pick_comm_peers` (oracle)."""
    C = ct.status.shape[0]
    deployed = ((ct.status == STATUS_RUNNING) |
                (ct.status == STATUS_COMMUNICATING) |
                (ct.status == STATUS_MIGRATING)) & (ct.host >= 0)
    same_job = (ct.job[:, None] == ct.job[None, :]) & (ct.job[:, None] >= 0)
    cand = same_job & deployed[None, :] & ~jnp.eye(C, dtype=bool)
    first = jnp.argmax(cand, axis=1)
    has = cand.any(axis=1)
    return jnp.where(has, first, jnp.arange(C))


def phase_flows(sim: SimState, cfg: SimConfig, use_kernel: bool = False):
    """Compute this tick's flow rates (paper: iperf transfers).

    Flow f in [0, C)    = container f's active communication flow.
    Flow f in [C, 2C)   = container (f - C)'s migration flow.
    ``use_kernel`` (resolved from ``cfg.waterfill_kernel`` by the tick
    builder) routes the sparse allocation through the fused Pallas kernel.
    """
    ct = sim.containers
    C = ct.status.shape[0]
    comm_active = ct.status == STATUS_COMMUNICATING
    mig_active = ct.status == STATUS_MIGRATING

    peer = jnp.clip(ct.comm_peer, 0, C - 1)
    comm_src = ct.host
    comm_dst = ct.host[peer]
    mig_src = ct.host
    mig_dst = ct.mig_dst

    src = jnp.concatenate([comm_src, mig_src])
    dst = jnp.concatenate([comm_dst, mig_dst])
    active = jnp.concatenate([comm_active, mig_active])
    rates, util = network.flow_rates(sim.net, src, dst, active,
                                     n_rounds=cfg.waterfill_rounds,
                                     sparse=cfg.sparse_flows,
                                     use_kernel=use_kernel)
    sim = sim._replace(net=sim.net._replace(link_util=util))
    return sim, rates[:C], rates[C:], active, rates


def phase_communicate(sim: SimState, cfg: SimConfig,
                      comm_rates: jnp.ndarray) -> SimState:
    """Progress communication flows; bounded retransmission -> WAITING."""
    ct = sim.containers
    comm = ct.status == STATUS_COMMUNICATING
    new_left = jnp.where(comm, ct.comm_bytes_left - comm_rates, ct.comm_bytes_left)
    done = comm & (new_left <= 0.0)
    stalled = comm & ~done & (comm_rates < cfg.stall_rate_floor)
    retry = jnp.where(stalled, ct.retry + 1,
                      jnp.where(comm, 0, ct.retry))
    failed = stalled & (retry > cfg.max_retries)

    # failure: paper Table 2 — waiting is *undeployed*; hand back to scheduler
    hosts = _free_resources(sim.hosts, ct.req, ct.host, failed)

    status = jnp.where(done, STATUS_RUNNING, ct.status)
    status = jnp.where(failed, STATUS_WAITING, status)
    conts = ct._replace(
        status=status,
        comm_bytes_left=jnp.where(done | failed, 0.0,
                                  jnp.maximum(new_left, 0.0)),
        n_comms_left=jnp.where(done, ct.n_comms_left - 1, ct.n_comms_left),
        next_comm_at=jnp.where(done, ct.next_comm_at + ct.comm_work_gap,
                               ct.next_comm_at),
        comm_peer=jnp.where(done | failed, -1, ct.comm_peer),
        comm_time=ct.comm_time + comm.astype(F32),
        retry=jnp.where(failed, 0, retry),
        host=jnp.where(failed, -1, ct.host),
    )
    return sim._replace(hosts=hosts, containers=conts)


def phase_migrate(sim: SimState, cfg: SimConfig,
                  mig_rates: jnp.ndarray) -> SimState:
    """Progress migration flows: done -> switch host; stalled out -> WAITING."""
    ct = sim.containers
    mig = ct.status == STATUS_MIGRATING
    new_left = jnp.where(mig, ct.mig_bytes_left - mig_rates, ct.mig_bytes_left)
    done = mig & (new_left <= 0.0)
    stalled = mig & ~done & (mig_rates < cfg.stall_rate_floor)
    retry = jnp.where(stalled, ct.retry + 1, jnp.where(mig, 0, ct.retry))
    failed = stalled & (retry > cfg.max_retries)

    # done: release source; container now lives on mig_dst (already reserved)
    hosts = _free_resources(sim.hosts, ct.req, ct.host, done)
    # failed: release BOTH source and reserved destination; back to queue
    hosts = _free_resources(hosts, ct.req, ct.host, failed)
    hosts = _free_resources(hosts, ct.req, ct.mig_dst, failed)

    status = jnp.where(done, STATUS_RUNNING, ct.status)
    status = jnp.where(failed, STATUS_WAITING, status)
    conts = ct._replace(
        status=status,
        host=jnp.where(done, ct.mig_dst, jnp.where(failed, -1, ct.host)),
        mig_dst=jnp.where(done | failed, -1, ct.mig_dst),
        mig_bytes_left=jnp.where(done | failed, 0.0,
                                 jnp.maximum(new_left, 0.0)),
        n_migrations=jnp.where(done, ct.n_migrations + 1, ct.n_migrations),
        retry=jnp.where(failed, 0, retry),
    )
    return sim._replace(hosts=hosts, containers=conts)


def phase_execute(sim: SimState, cfg: SimConfig) -> SimState:
    """Paper ``run`` process: run_at += speed-of-primary-resource per second;
    crossing a communication trigger point pauses into COMMUNICATING."""
    ct = sim.containers
    H = sim.hosts.cap.shape[0]
    running = ct.status == STATUS_RUNNING
    hh = jnp.clip(ct.host, 0, H - 1)
    speed = sim.hosts.speed[hh, ct.ctype]                    # [C]
    run_at = jnp.where(running, ct.run_at + speed, ct.run_at)

    trigger = (running & (ct.n_comms_left > 0) & (run_at >= ct.next_comm_at))
    peers = pick_comm_peers(ct)
    conts = ct._replace(
        run_at=run_at,
        status=jnp.where(trigger, STATUS_COMMUNICATING, ct.status),
        comm_bytes_left=jnp.where(trigger, ct.comm_bytes, ct.comm_bytes_left),
        comm_peer=jnp.where(trigger, peers, ct.comm_peer),
        retry=jnp.where(trigger, 0, ct.retry),
    )
    return sim._replace(containers=conts)


def phase_complete(sim: SimState) -> SimState:
    ct = sim.containers
    fin = ((ct.status == STATUS_RUNNING) & (ct.run_at >= ct.duration) &
           (ct.n_comms_left <= 0))
    hosts = _free_resources(sim.hosts, ct.req, ct.host, fin)
    conts = ct._replace(
        status=jnp.where(fin, STATUS_COMPLETED, ct.status),
        finish_t=jnp.where(fin, sim.t, ct.finish_t),
        host=jnp.where(fin, -1, ct.host),
    )
    return sim._replace(hosts=hosts, containers=conts)


def phase_cost(sim: SimState) -> SimState:
    busy = sim.hosts.n_containers > 0
    cost = (sim.hosts.price * busy.astype(F32)).sum()
    hosts = sim.hosts._replace(busy_time=sim.hosts.busy_time + busy.astype(F32))
    return sim._replace(hosts=hosts, total_cost=sim.total_cost + cost)


# ---------------------------------------------------------------------------
# The tick and the scan driver
# ---------------------------------------------------------------------------
class TickInfo(NamedTuple):
    """Side-channel outputs of one full tick the telescoping driver needs
    to judge quiescence (docs/events.md) — the frozen flow rates and the
    flow inputs ``phase_flows`` consumed, so the next tick's rates are
    provably the same values without re-running waterfilling."""
    comm_rates: jnp.ndarray     # f32[C] this tick's comm allocation
    mig_rates: jnp.ndarray      # f32[C] this tick's migration allocation
    flow_active: jnp.ndarray    # bool[2C]
    all_rates: jnp.ndarray      # f32[2C]
    mid_status: jnp.ndarray     # container fields phase_flows read
    mid_host: jnp.ndarray       #   (captured post-schedule, pre-flows)
    mid_peer: jnp.ndarray
    mid_mig_dst: jnp.ndarray
    refreshed: jnp.ndarray      # bool: delay refresh fired this tick


def make_refresh_fn(cfg: SimConfig, policy: PolicyParams, params: RunParams,
                    n_hosts: int, n_nodes: int):
    """The periodic delay-matrix rebuild as a ``net -> net`` branch fn —
    ONE definition for the per-tick cond and the telescoping driver's
    hoisted boundary cond, so both compile the identical XLA region."""
    use_fw_kernel = resolve_kernel(cfg.delay_kernel)

    def refresh(net):
        return network.update_delay_matrix(
            net, n_hosts, n_nodes, mode=cfg.delay_mode,
            use_kernel=use_fw_kernel, q_coef=params.queue_coef,
            util_weight=policy.weights[W_UTIL],
            cross_leaf_ms=policy.weights[W_CROSS_LEAF])

    return refresh


def make_tick_ext(cfg: SimConfig, policy: PolicyParams, params: RunParams,
                  n_hosts: int, n_nodes: int, refresh: bool = True):
    """Build the extended tick ``(sim, tt) -> (sim', metrics, TickInfo)``.

    The scan drivers wrap it through :func:`make_tick` (dropping the
    info); the telescoping driver consumes the info directly.  Both paths
    trace the IDENTICAL phase sequence — that is what keeps a telescoped
    full tick bit-for-bit equal to a scanned one.

    ``refresh=False`` statically drops the periodic delay-refresh cond:
    the telescoping driver segments its chunk at the refresh boundaries
    and applies the refresh OUTSIDE the tick through a real ``lax.cond``
    (the boundary clock is unbatched there — see ``simulate_telescoped``),
    so its in-loop ticks must not carry a second, select-lowered copy.
    ``stats.collect`` reads nothing the refresh writes (``net`` leaves
    only), so hoisting the refresh past it is bit-exact.
    """
    use_wf_kernel = cfg.sparse_flows and resolve_kernel(cfg.waterfill_kernel)

    def tick_ext(sim: SimState, tt: jnp.ndarray):
        sim, n_arrived = phase_arrive(sim)
        sim, soft = phase_schedule_soft(sim, cfg, policy, params)
        mid = sim.containers          # the state phase_flows consumes
        sim, comm_rates, mig_rates, flow_active, all_rates = \
            phase_flows(sim, cfg, use_kernel=use_wf_kernel)
        sim = phase_communicate(sim, cfg, comm_rates)
        sim = phase_migrate(sim, cfg, mig_rates)
        sim = phase_execute(sim, cfg)
        sim = phase_complete(sim)
        sim = phase_cost(sim)

        # paper ``update_delay_matrix`` process: periodic refresh
        # The predicate reads the scan's tick counter ``tt`` (== sim.t at
        # every step), NOT the carried clock: the carry is batched under a
        # vmapped sweep, and a batched predicate turns ``lax.cond`` into a
        # select that evaluates BOTH branches — every cell would pay the
        # O(H^2) refresh on every tick (measured ~1.6x per cell at
        # 500h/3000c).  ``tt`` comes from an unbatched xs, so the cond
        # survives every vmap and the refresh stays periodic.
        # ``delay_update_interval == 0`` = refresh once at t=0, then
        # frozen: a static branch, because ``mod(tt, 0)`` is undefined and
        # static-topology runs should not re-enter the O(H^2) rebuild at
        # all.
        if not refresh:
            every = jnp.asarray(False)
        elif cfg.delay_update_interval == 0:
            every = tt == 0
        else:
            every = jnp.mod(tt, cfg.delay_update_interval) == 0
        if refresh:
            sim = sim._replace(
                net=jax.lax.cond(every,
                                 make_refresh_fn(cfg, policy, params,
                                                 n_hosts, n_nodes),
                                 lambda n: n, sim.net))

        m = stats.collect(sim, n_arrived, sim.sched.decisions,
                          sim.sched.migrations, params,
                          flow_active, all_rates, soft=soft)
        sim = sim._replace(t=sim.t + 1.0)
        info = TickInfo(comm_rates=comm_rates, mig_rates=mig_rates,
                        flow_active=flow_active, all_rates=all_rates,
                        mid_status=mid.status, mid_host=mid.host,
                        mid_peer=mid.comm_peer, mid_mig_dst=mid.mig_dst,
                        refreshed=every)
        return sim, m, info

    return tick_ext


def make_tick(cfg: SimConfig, policy: PolicyParams, params: RunParams,
              n_hosts: int, n_nodes: int):
    """Build the jit-able tick function ``(sim, _) -> (sim', metrics)``.

    ``policy`` and ``params`` are traced pytrees closed over by the tick —
    the whole point of the policy-as-data split: a different policy id,
    weight vector, or runtime knob is new *data* through the SAME compiled
    tick, and a batch axis on either sweeps them under ``vmap``.

    The Pallas kernel flags are resolved at trace time in
    :func:`make_tick_ext` (``repro.kernels.resolve_kernel``: compiled
    kernel on TPU/GPU, jnp reference on CPU under 'auto') — they are
    static config, part of the jit cache key via ``cfg``, never traced
    values.
    """
    tick_ext = make_tick_ext(cfg, policy, params, n_hosts, n_nodes)

    def tick(sim: SimState, tt: jnp.ndarray) -> Tuple[SimState, TickMetrics]:
        sim, m, _ = tick_ext(sim, tt)
        return sim, m

    return tick


def simulate(sim0: SimState, cfg: SimConfig, policy: PolicyParams,
             n_hosts: int, n_nodes: int, horizon: int,
             params: RunParams) -> Tuple[SimState, TickMetrics]:
    """The un-jitted simulation core: apply the runtime link params, then
    scan ``horizon`` ticks.  ``run_sim`` jits it for standalone runs;
    ``repro/launch/sweep.py`` vmaps it over policy x scenario x seed and
    jits ONCE — both paths trace the identical function, which is what
    makes sweep cells bit-for-bit equal to standalone runs.
    """
    sim0 = sim0._replace(net=network.apply_link_params(
        sim0.net, params.bw_mbps, params.loss))
    tick = make_tick(cfg, policy, params, n_hosts, n_nodes)
    # xs = the tick counter, deliberately NOT part of the carried state: it
    # stays unbatched under the sweep's vmaps, so the periodic delay
    # refresh keeps its lax.cond (see make_tick).
    return jax.lax.scan(tick, sim0, jnp.arange(horizon, dtype=I32))


# ---------------------------------------------------------------------------
# Streaming (chunked) driver: O(state) memory at any horizon
# ---------------------------------------------------------------------------
def simulate_chunk(sim: SimState, acc, t0: jnp.ndarray, cfg: SimConfig,
                   policy: PolicyParams, n_hosts: int, n_nodes: int,
                   chunk: int, params: RunParams):
    """One streaming chunk: ``chunk`` ticks starting at tick ``t0``, folding
    each tick's metrics into the ``SummaryAcc`` carry instead of stacking
    them as scan ys — the scan emits NOTHING, so device memory is O(state)
    regardless of horizon.

    ``t0`` is a *traced* scalar (one compilation covers every chunk) and,
    like the tick counter xs, deliberately unbatched under the sweep's
    vmaps — both the periodic delay-refresh cond and the t0 == 0 cond below
    survive as real branches.  The runtime link params are applied inside
    the t0 == 0 cond, NOT unconditionally: ``apply_link_params`` rebuilds
    ``comm_cost`` from the static tables, so re-applying it at a chunk
    boundary would clobber the dynamically refreshed matrix mid-run and
    break chunked == unchunked equality.
    """
    sim = jax.lax.cond(
        t0 == 0,
        lambda s: s._replace(net=network.apply_link_params(
            s.net, params.bw_mbps, params.loss)),
        lambda s: s, sim)
    tick = make_tick(cfg, policy, params, n_hosts, n_nodes)

    def body(carry, tt):
        s, a = carry
        s, m = tick(s, tt)
        return (s, stats.acc_update(a, m)), None

    (sim, acc), _ = jax.lax.scan(body, (sim, acc),
                                 t0 + jnp.arange(chunk, dtype=I32))
    return sim, acc


# ---------------------------------------------------------------------------
# Telescoping (macro-tick) driver: closed-form advancement over quiescent
# intervals (docs/events.md)
# ---------------------------------------------------------------------------
def _event_horizon(sim: SimState, cfg: SimConfig, info: TickInfo,
                   t: jnp.ndarray, t_end: jnp.ndarray,
                   speed: jnp.ndarray) -> jnp.ndarray:
    """Closed-form event horizon after the full tick at ``t``: the first
    tick index that could be a non-quiescent event, as an f32 bound on the
    cheap-tick indices (cheap ticks allowed while ``t' < horizon``).

    Exact components (integer / monotone arithmetic):
    * segment end ``t_end`` — the telescoping driver segments its chunk at
      the ``delay_update_interval`` refresh boundaries, so the next
      refresh (and the chunk end) both arrive through this cap;
    * next container arrival — ``ceil`` of the min pending ``submit_t``
      (``phase_arrive`` fires at the first integer tick >= submit).

    Estimated components (ceil-divisions of remaining work by the frozen
    rates — the per-tick path subtracts the rate REPEATEDLY in f32, so
    these can be off by a tick either way from rounding):
    * earliest comm / migration flow finish;
    * earliest comm trigger or completion of a running container.

    The estimates are only a bound: the telescoping loop re-checks the
    exact one-step predicates (the same comparisons the per-tick phases
    make) before every cheap tick, so an overestimate stops early on the
    exact check and an underestimate merely costs one extra full tick.
    Equality with the per-tick path never rests on the divisions.
    """
    ct = sim.containers
    inf = jnp.float32(jnp.inf)

    def ceil_ticks(remaining, rate, mask):
        k = jnp.ceil(remaining / jnp.maximum(rate, 1e-30))
        return jnp.where(mask & (rate > 0), k, inf).min()

    comm = ct.status == STATUS_COMMUNICATING
    mig = ct.status == STATUS_MIGRATING
    running = ct.status == STATUS_RUNNING
    t_f = t.astype(F32)
    # arrivals after the full tick at t: phase_arrive at tick ti fires on
    # submit_t <= ti, so the first arrival event is ceil(min pending
    # submit).  Queried against t (NOT the post-tick clock t+1): a submit
    # inside (t, t+1] arrives at the very next tick.
    horizon = jnp.minimum(t_end.astype(F32),
                          jnp.ceil(workload.next_arrival_after(ct, t_f)))
    horizon = jnp.minimum(
        horizon, t_f + ceil_ticks(ct.comm_bytes_left, info.comm_rates, comm))
    horizon = jnp.minimum(
        horizon, t_f + ceil_ticks(ct.mig_bytes_left, info.mig_rates, mig))
    horizon = jnp.minimum(
        horizon, t_f + ceil_ticks(ct.next_comm_at - ct.run_at, speed,
                                  running & (ct.n_comms_left > 0)))
    horizon = jnp.minimum(
        horizon, t_f + ceil_ticks(ct.duration - ct.run_at, speed,
                                  running & (ct.n_comms_left <= 0)))
    return horizon


def simulate_telescoped(sim: SimState, acc, t0: jnp.ndarray, cfg: SimConfig,
                        policy: PolicyParams, n_hosts: int, n_nodes: int,
                        chunk: int, params: RunParams,
                        with_stats: bool = False):
    """:func:`simulate_chunk` twin with event-horizon tick telescoping.

    Each macro step runs ONE full tick, then — if the resulting state is
    *quiescent* (nothing schedulable, no migration trigger armed, no
    stalled flow, and the tick changed none of the inputs waterfilling
    reads, so the frozen rates provably carry forward) — advances up to
    the closed-form event horizon in cheap ticks: only the linear O(C+H)
    updates a quiescent full tick would make (work progress at frozen
    rates and speeds, busy/comm clocks, cost), each applying the SAME f32
    operations in the SAME order, so the final state is bit-for-bit the
    per-tick path's.  The dt skipped ticks' metrics — constant over the
    interval by construction — fold in closed form through
    ``stats.acc_update_weighted`` (dt-weighted Kahan, weighted Welford):
    integer sums/counts/peaks exact, float means to ~1 ulp.

    Under a vmapped sweep ``dt`` is per-cell: the while loops run until
    every lane's clock reaches the segment end (``max(t)`` across the
    batch), finished lanes riding along masked.  The chunk is SEGMENTED
    at the ``delay_update_interval`` refresh boundaries — every lane
    stops there (the event horizon is capped by the segment end), so the
    lanes re-synchronize at each boundary and the periodic delay refresh
    applies through a real ``lax.cond`` on an UNBATCHED boundary clock.
    That is a bitwise requirement, not a nicety: a batched predicate
    lowers the cond to a select whose branch fuses into the loop body,
    and XLA's fusion-dependent f32 contraction measurably shifted
    ``delay_matrix`` (~1 ulp) against the per-tick path; a real cond
    branch is its own XLA region and compiles identically in both
    drivers.  ``delay_update_interval=0`` (refresh once at t=0, then
    frozen) collapses the chunk to one segment.  docs/events.md walks
    the exactness argument and the honest list of what forces dt=1.

    ``cfg.soft_placement`` is rejected: ``lax.while_loop`` has no
    reverse-mode autodiff, so the surrogate's gradient path cannot thread
    a telescoped run — use the chunked scan for grad work.
    ``with_stats`` additionally returns the number of FULL ticks executed
    (i32; ``horizon - n_full`` ticks were telescoped) for benches/tests.
    """
    if cfg.soft_placement:
        raise ValueError(
            "telescope + soft_placement is unsupported: the surrogate "
            "exists for jax.grad, and lax.while_loop (the telescoping "
            "driver) has no reverse-mode autodiff — run grad work through "
            "the chunked scan (ExecPlan(chunk=...)) instead")
    sim = jax.lax.cond(
        t0 == 0,
        lambda s: s._replace(net=network.apply_link_params(
            s.net, params.bw_mbps, params.loss)),
        lambda s: s, sim)
    tick_ext = make_tick_ext(cfg, policy, params, n_hosts, n_nodes,
                             refresh=False)
    refresh_fn = make_refresh_fn(cfg, policy, params, n_hosts, n_nodes)
    K = cfg.delay_update_interval
    H = sim.hosts.cap.shape[0]
    t_end = t0 + chunk
    zero_i = jnp.zeros((), I32)
    # Topology leaves no phase ever writes (the sweep keeps them UNBATCHED
    # through its vmap for the fast-path gathers, sweep.py's
    # STATIC_TOPOLOGY_LEAVES).  The batched-cond while_loop select-masks
    # every carry leaf, which would swap in lane-batched copies and flip
    # the delay-refresh gathers to batched indices — a different f32
    # reduction order than the per-tick path, breaking bitwise equality.
    # Pin them to the closed-over inputs each step: values are identical
    # either way, the gathers keep unbatched operands, and the returned
    # state's topology leaves stay unbatched through the vmap.
    net0, leaf0 = sim.net, sim.hosts.leaf

    def pin(s):
        return s._replace(
            hosts=s.hosts._replace(leaf=leaf0),
            net=s.net._replace(link_u=net0.link_u, link_v=net0.link_v,
                               path_links=net0.path_links,
                               path_nlinks=net0.path_nlinks))

    def advance(sim, acc, t, info, blocked, seg_end):
        """Quiescence test + cheap-tick advancement after the full tick
        at ``t``: returns ``(sim, acc, t2)`` with ``t2`` in
        ``(t, seg_end]``.  ``blocked`` forces dt=1 when the caller just
        applied the boundary delay refresh — the rebuilt fabric means the
        frozen rates do not provably carry forward."""
        ct = sim.containers
        st = ct.status
        # Quiescence: the tick's own post-flow phases changed none of the
        # inputs waterfilling reads and no refresh touched the fabric, so
        # the frozen rates ARE the next tick's rates; nothing is waiting
        # for the scheduler; the migration trigger cannot arm (hosts.used
        # is constant over the interval); no active flow is stalling
        # (stalls increment retry every tick).
        quiet = ((st == info.mid_status).all()
                 & (ct.host == info.mid_host).all()
                 & (ct.comm_peer == info.mid_peer).all()
                 & (ct.mig_dst == info.mid_mig_dst).all()
                 & ~blocked)
        quiet &= ~((st == STATUS_INACTIVE) | (st == STATUS_WAITING)).any()
        util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)
        quiet &= ~((policy.weights[W_MIG_ENABLE] > 0)
                   & (util.max(axis=1) > params.overload_threshold).any())
        quiet &= ~(info.flow_active
                   & (info.all_rates < cfg.stall_rate_floor)).any()

        # Per-interval constants (statuses and placement are frozen).
        comm = st == STATUS_COMMUNICATING
        mig = st == STATUS_MIGRATING
        running = st == STATUS_RUNNING
        speed = sim.hosts.speed[jnp.clip(ct.host, 0, H - 1), ct.ctype]
        comm_f = comm.astype(F32)
        busy_f = (sim.hosts.n_containers > 0).astype(F32)
        cost_q = (sim.hosts.price * busy_f).sum()
        horizon = _event_horizon(sim, cfg, info, t, seg_end, speed)
        comm_rates, mig_rates = info.comm_rates, info.mig_rates

        def cheap_cond(c):
            s, ti = c
            cc = s.containers
            ok = ti.astype(F32) < horizon
            # exact one-step event predicates — the comparisons the
            # per-tick phases would make at tick ti, on the live state
            ok &= ~(comm & (cc.comm_bytes_left - comm_rates <= 0.0)).any()
            ok &= ~(mig & (cc.mig_bytes_left - mig_rates <= 0.0)).any()
            new_run = cc.run_at + speed
            ok &= ~(running & (cc.n_comms_left > 0)
                    & (new_run >= cc.next_comm_at)).any()
            ok &= ~(running & (cc.n_comms_left <= 0)
                    & (new_run >= cc.duration)).any()
            return quiet & ok

        def cheap_body(c):
            s, ti = c
            cc = s.containers
            # exactly the f32 updates a quiescent full tick makes, in the
            # per-tick operation order (phase_communicate / phase_migrate
            # clamp through maximum(new_left, 0); phase_execute adds the
            # speed gather; phase_cost re-adds the same cost scalar)
            conts = cc._replace(
                comm_bytes_left=jnp.maximum(
                    jnp.where(comm, cc.comm_bytes_left - comm_rates,
                              cc.comm_bytes_left), 0.0),
                mig_bytes_left=jnp.maximum(
                    jnp.where(mig, cc.mig_bytes_left - mig_rates,
                              cc.mig_bytes_left), 0.0),
                comm_time=cc.comm_time + comm_f,
                run_at=jnp.where(running, cc.run_at + speed, cc.run_at),
                retry=jnp.where(comm | mig, 0, cc.retry),
            )
            hosts = s.hosts._replace(busy_time=s.hosts.busy_time + busy_f)
            sched = s.sched._replace(decisions=zero_i, migrations=zero_i)
            s = s._replace(containers=conts, hosts=hosts, sched=sched,
                           total_cost=s.total_cost + cost_q,
                           t=s.t + 1.0)
            return s, ti + 1

        sim, t2 = jax.lax.while_loop(cheap_cond, cheap_body, (sim, t + 1))
        dt = t2 - (t + 1)
        # the skipped ticks' metrics, constant over the interval: no
        # arrivals/decisions/migrations, frozen flows, same state counts
        m_q = stats.collect(sim, zero_i, zero_i, zero_i, params,
                            info.flow_active, info.all_rates)
        acc = stats.acc_update_weighted(acc, m_q, dt)
        return sim, acc, t2

    def macro_of(seg_end):
        def macro(carry):
            sim, acc, t, n_full = carry
            sim = pin(sim)
            sim, m, info = tick_ext(sim, t)
            acc = stats.acc_update(acc, m)
            sim, acc, t2 = advance(sim, acc, t, info, jnp.asarray(False),
                                   seg_end)
            return sim, acc, t2, n_full + 1
        return macro

    def run_segment(carry, seg_start, seg_end, refresh_due):
        """One refresh-bounded segment ``[seg_start, seg_end)``.  Every
        lane enters at exactly ``seg_start`` — the previous segment's
        event horizon was capped there — so the first tick runs on the
        UNBATCHED boundary clock and the delay refresh applies through a
        real ``lax.cond``: the same insulated XLA branch region the
        per-tick path compiles (see the docstring's bitwise argument)."""
        sim, acc, n_full = carry
        sim = pin(sim)
        sim, m, info = tick_ext(sim, seg_start)
        sim = sim._replace(net=jax.lax.cond(refresh_due, refresh_fn,
                                            lambda n: n, sim.net))
        acc = stats.acc_update(acc, m)
        sim, acc, t2 = advance(sim, acc, seg_start, info, refresh_due,
                               seg_end)
        sim, acc, _, n_full = jax.lax.while_loop(
            lambda c: c[2] < seg_end, macro_of(seg_end),
            (sim, acc, t2, n_full + 1))
        return sim, acc, n_full

    if K == 0:
        # one segment: refresh once at t=0 (first chunk only), then the
        # fabric is frozen for the whole run — the documented fast path
        sim, acc, n_full = run_segment((sim, acc, zero_i), t0, t_end,
                                       t0 == 0)
    else:
        # chunk//K + 2 boundary-aligned segments cover [t0, t_end) for
        # ANY t0: a partial head segment up to the next multiple of K,
        # then K-sized segments; trailing empties are skipped below
        n_seg = chunk // K + 2

        def seg_step(carry, s):
            start = jnp.where(s == 0, t0, (t0 // K + s) * K)
            end = jnp.minimum((t0 // K + s + 1) * K, t_end)
            due = jnp.mod(start, K) == 0
            # real cond — s and t0 stay unbatched under the sweep's
            # vmap, so empty segments (start past t_end) skip entirely
            return jax.lax.cond(start < end,
                                lambda c: run_segment(c, start, end, due),
                                lambda c: c, carry), None

        (sim, acc, n_full), _ = jax.lax.scan(
            seg_step, (sim, acc, zero_i), jnp.arange(n_seg, dtype=I32))
    sim = pin(sim)
    if with_stats:
        return sim, acc, n_full
    return sim, acc


@functools.lru_cache(maxsize=None)
def _chunk_step_jit(telescope: bool = False):
    """The jitted per-chunk step, built lazily so the donation decision can
    read the active backend: donating the (state, accumulator) carry lets
    XLA reuse their buffers across chunks, but CPU does not implement
    donation and would warn on every compile.  ``telescope`` swaps the
    scan for the macro-tick driver — same signature, same carry."""
    fn = simulate_telescoped if telescope else simulate_chunk
    def step(sim, acc, t0, policy, params, cfg, n_hosts, n_nodes, chunk):
        return fn(sim, acc, t0, cfg, policy, n_hosts, n_nodes, chunk, params)
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    return jax.jit(step, static_argnames=("cfg", "n_hosts", "n_nodes",
                                          "chunk"),
                   donate_argnums=donate), bool(donate)


def run_sim_chunked(sim0: SimState, cfg: SimConfig, policy: PolicyParams,
                    n_hosts: int, n_nodes: int, horizon: int, chunk: int,
                    params: RunParams | None = None,
                    telescope: bool = False):
    """Streaming ``run_sim``: host loop over jit-per-chunk steps with a
    donated carry; returns (final state, ``OnlineSummary``).

    The device accumulator resets every chunk and the host folds it into
    f64/i64 totals (``stats.online_fold``), so integer sums stay exact and
    float sums hold ~f32-ulp accuracy out to arbitrary horizons —
    ``check_chunk`` bounds the chunk size so no i32 sum can overflow
    within one chunk (the dt-weighted telescoping folds total exactly what
    the repeated folds would, so the same bound covers both drivers).
    Final state is bit-for-bit the stacked path's (tests/test_streaming.py
    / test_telescope.py); only the metrics representation differs.
    """
    params = cfg.run_params() if params is None else params
    stats.check_chunk(chunk, int(sim0.containers.status.shape[-1]))
    step, donated = _chunk_step_jit(telescope)
    # donation consumes the caller's buffers on the first chunk — keep
    # sim0 valid for reuse (launch/sim.py shares one built state across
    # every policy run)
    sim = jax.tree.map(jnp.array, sim0) if donated else sim0
    online = stats.online_init()
    t0 = 0
    while t0 < horizon:
        sz = min(chunk, horizon - t0)       # tail chunk: one extra compile
        sim, acc = step(sim, stats.acc_init(), jnp.asarray(t0, I32),
                        policy, params, cfg=cfg, n_hosts=n_hosts,
                        n_nodes=n_nodes, chunk=sz)
        online = stats.online_fold(online, acc)   # syncs; promotes to 64-bit
        t0 += sz
    return sim, online


# Nothing about the policy registry is baked into compiled programs with
# branch-free scoring — a policy is a weight vector, so registering a new
# one after a compiled run simply feeds new data through the executable.
@functools.partial(jax.jit, static_argnames=("cfg", "n_hosts", "n_nodes",
                                             "horizon"))
def _run_sim_jit(sim0, cfg, policy, params, n_hosts, n_nodes, horizon):
    return simulate(sim0, cfg, policy, n_hosts, n_nodes, horizon, params)


def resolve_plan(plan: ExecPlan | None, cfg: SimConfig,
                 **legacy) -> tuple[ExecPlan, SimConfig]:
    """Shared plan/legacy-kwarg resolution for every run entry point.

    ``legacy`` maps old kwarg names to their (possibly None) values; any
    non-None value raises a loud ``DeprecationWarning`` and is folded into
    the plan (one deprecation cycle, then the kwargs go away).  Passing
    both a plan and a legacy kwarg is an error — silently preferring one
    would hide the conflict.  Returns the resolved plan and the config
    with the plan's kernel selectors applied (the jit cache key stays the
    config, exactly as before).
    """
    used = {k: v for k, v in legacy.items() if v is not None}
    if used:
        if plan is not None:
            raise TypeError(
                f"pass execution options via plan= OR the deprecated "
                f"kwargs {sorted(used)}, not both")
        warnings.warn(
            f"the {sorted(used)} kwargs are deprecated; pass "
            f"plan=ExecPlan({', '.join(f'{k}={v!r}' for k, v in sorted(used.items()))}) "
            f"instead", DeprecationWarning, stacklevel=3)
        plan = ExecPlan(**used)
    plan = ExecPlan() if plan is None else plan
    return plan, plan.apply_to_config(cfg)


def run_sim(sim0: SimState, cfg: SimConfig, policy: PolicyParams,
            n_hosts: int, n_nodes: int, horizon: int,
            params: RunParams | None = None, chunk: int | None = None,
            plan: ExecPlan | None = None
            ) -> Tuple[SimState, TickMetrics]:
    """Run ``horizon`` ticks; returns (final state, metrics).

    Execution options ride in ``plan`` (:class:`~repro.core.types.ExecPlan`
    — chunking and kernel selection apply here; sweep/dist fields are
    ignored).  ``plan=None`` (default, right for short horizons) stacks
    per-tick ``TickMetrics`` over the whole run — O(horizon) memory, the
    streaming path's oracle.  A ``plan.chunk`` streams the run through
    :func:`run_sim_chunked` instead: same final state bit-for-bit, an
    f64/i64 ``OnlineSummary`` instead of the stacked series, O(state)
    memory at any horizon.  ``report.summarize`` accepts either form.
    The bare ``chunk=`` kwarg is deprecated (one cycle).

    A ``plan.telescope`` routes the run through the macro-tick driver
    (:func:`simulate_telescoped`): quiescent intervals advance in cheap
    linear ticks up to the closed-form event horizon, metrics fold
    dt-weighted.  Telescoped runs always report an ``OnlineSummary``
    (skipped ticks have no per-tick rows to stack); without ``plan.chunk``
    the whole horizon runs as one span.  Final state stays bit-for-bit
    the per-tick path's; docs/events.md.

    Only ``cfg`` (after the plan's kernel selectors fold in), the shape
    arguments, and the chunk size are static.  ``policy`` (a weight
    vector) and ``params`` (bw/loss/queue/threshold knobs, defaulting from
    the config) are DATA: every policy — including ones registered after
    this call — and every runtime-parameter point reuses one compilation
    per (config, shapes) combination.
    """
    plan, cfg = resolve_plan(plan, cfg, chunk=chunk)
    params = cfg.run_params() if params is None else params
    if plan.telescope:
        return run_sim_chunked(sim0, cfg, policy, n_hosts, n_nodes, horizon,
                               plan.chunk or horizon, params=params,
                               telescope=True)
    if plan.chunk is not None:
        return run_sim_chunked(sim0, cfg, policy, n_hosts, n_nodes, horizon,
                               plan.chunk, params=params)
    return _run_sim_jit(sim0, cfg, policy, params, n_hosts, n_nodes, horizon)
