"""Struct-of-arrays state for the DCSim-JAX engine.

The paper's Container/Host/Job Python objects become fixed-capacity tensors
with masks; the six container states of paper Table 2 map to STATUS_* codes.
Every field is a leaf of a NamedTuple pytree so the whole simulator state can
be carried through ``lax.scan`` and ``vmap``-ed over scenarios.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Container lifecycle (paper Table 2)
# ---------------------------------------------------------------------------
STATUS_UNBORN = -1        # slot exists but the job has not been submitted yet
STATUS_INACTIVE = 0       # submitted, not scheduled            (undeployed)
STATUS_RUNNING = 1        # deployed and executing              (deployed)
STATUS_COMMUNICATING = 2  # paused on a network transfer        (deployed)
STATUS_MIGRATING = 3      # being moved to another host         (dep+undep)
STATUS_WAITING = 4        # suspended after comm/migration fail (undeployed)
STATUS_COMPLETED = 5      # finished                            (completed)

# Container primary resource types (paper §3.3)
CTYPE_CPU = 0
CTYPE_MEM = 1
CTYPE_GPU = 2

NUM_RESOURCES = 3  # cpu (%), mem (GB), gpu (%)


class HostState(NamedTuple):
    """Heterogeneous hosts (paper Table 5): capacity, *speed* and price."""

    cap: jnp.ndarray       # f32[H, 3]  resource capacity
    speed: jnp.ndarray     # f32[H, 3]  per-resource processing speed (1..4)
    price: jnp.ndarray     # f32[H]     $ per busy second
    used: jnp.ndarray      # f32[H, 3]  currently committed resources
    n_containers: jnp.ndarray  # i32[H] deployed container count (net-node cap)
    leaf: jnp.ndarray      # i32[H]     leaf switch this host hangs off
    busy_time: jnp.ndarray  # f32[H]    accumulated seconds with >=1 container


class ContainerState(NamedTuple):
    """Three-tier Job -> Task -> Container model, SoA over container slots."""

    status: jnp.ndarray        # i32[C] STATUS_*
    ctype: jnp.ndarray         # i32[C] CTYPE_* (primary resource)
    req: jnp.ndarray           # f32[C, 3] resource request
    duration: jnp.ndarray      # f32[C] total work units
    run_at: jnp.ndarray        # f32[C] executed work units
    host: jnp.ndarray          # i32[C] current host (-1 undeployed)
    job: jnp.ndarray           # i32[C] job id
    task: jnp.ndarray          # i32[C] task id
    submit_t: jnp.ndarray      # f32[C] arrival time
    start_t: jnp.ndarray       # f32[C] first deployment time (-1)
    finish_t: jnp.ndarray      # f32[C] completion time (-1)
    # --- communication schedule (paper: 1..5 comms of 100..102400 KB) ---
    n_comms_left: jnp.ndarray  # i32[C] remaining communication events
    comm_work_gap: jnp.ndarray # f32[C] work units between comm trigger points
    next_comm_at: jnp.ndarray  # f32[C] work-unit threshold of next comm
    comm_bytes: jnp.ndarray    # f32[C] KB per communication event
    comm_bytes_left: jnp.ndarray  # f32[C] KB outstanding on the active comm
    comm_peer: jnp.ndarray     # i32[C] partner container of active comm (-1)
    comm_time: jnp.ndarray     # f32[C] accumulated communicating seconds
    retry: jnp.ndarray         # i32[C] consecutive stalled ticks on the flow
    # --- migration ---
    mig_dst: jnp.ndarray       # i32[C] destination host while migrating (-1)
    mig_bytes_left: jnp.ndarray  # f32[C] KB outstanding on the migration flow
    n_migrations: jnp.ndarray  # i32[C] how many times this container migrated


class NetState(NamedTuple):
    """Spine-leaf network: static topology tables + dynamic delay matrix.

    Mininet's emulated fabric becomes link tables; the paper's ping-refreshed
    ``delay_matrix`` (eq. 1) is recomputed from congestion-adjusted link
    delays by min-plus Floyd-Warshall every ``delay_update_interval`` ticks.
    """

    # static link tables -------------------------------------------------
    link_bw: jnp.ndarray      # f32[E] Mbps
    link_delay: jnp.ndarray   # f32[E] ms base propagation+switching delay
    link_loss: jnp.ndarray    # f32[E] packet loss fraction
    # node graph: adjacency (node_u[e], node_v[e]) both directions implied
    link_u: jnp.ndarray       # i32[E]
    link_v: jnp.ndarray       # i32[E]
    # deterministic ECMP path between every host pair (<=4 links, -1 pad)
    path_links: jnp.ndarray   # i32[H, H, 4]
    path_nlinks: jnp.ndarray  # i32[H, H]
    # precomputed (derived from the static tables; kept on the state so the
    # per-tick sparse flow kernels are pure gathers + segment reductions)
    link_bw_kbps: jnp.ndarray  # f32[E] link_bw converted to KB/s
    path_loss: jnp.ndarray    # f32[H, H] end-to-end loss prob along ECMP path
    # dynamic ----------------------------------------------------------------
    link_util: jnp.ndarray    # f32[E] utilization from last tick's flows
    delay_matrix: jnp.ndarray  # f32[H, H] host-to-host delay (the paper's D)
    # expected cost of one unit of communication between every host pair:
    # delay + congestion along the ECMP path + a cross-leaf locality penalty.
    # Refreshed together with the delay matrix (network.pairwise_comm_cost);
    # consumed by the network-aware scheduling policies.
    comm_cost: jnp.ndarray    # f32[H, H]


class PolicyParams(NamedTuple):
    """A scheduling policy IS its weight vector.

    Since the branch-free scoring engine there is no code half left to
    dispatch: the engine computes ONE shared feature bank (selection
    features per container, placement features per candidate x host,
    migration-destination features per host) and every decision is a
    weighted sum ``features @ weights``.  What distinguishes FirstFit from
    NetAware is which entries of this vector are non-zero — so a *batch*
    of policies (or a learned-weight search, ``repro.launch.tune``) is a
    ``PolicyParams`` with a leading axis through one compiled program, and
    registering a new policy never retraces anything.
    """

    weights: jnp.ndarray     # f32[NUM_POLICY_WEIGHTS]


# ---------------------------------------------------------------------------
# PolicyParams.weights layout.  ONE canonical fixed-length vector; the
# blocks below are index-aligned with the feature banks scheduling.py
# computes.  All features are finite by construction, so a zero weight
# contributes an exact 0.0 and one-hot legacy vectors reproduce the old
# per-policy scores bit-for-bit.
# ---------------------------------------------------------------------------
# comm-cost model weights, consumed by the NetState.comm_cost refresh
# (network.pairwise_comm_cost) at every delay-matrix update:
W_UTIL = 0        # ms-equivalent per unit of bottleneck ECMP-path utilization
W_CROSS_LEAF = 1  # ms penalty for paths that transit the spine

# selection-key weights: priority[c] = sum_i w_i * feature_i(c), ranked by
# scheduling.rank_key (lower priority value = scheduled earlier):
W_SEL_SUBMIT = 2      # weight on submit_t  (1.0 = the paper's FIFO)
W_SEL_DURATION = 3    # weight on duration  (positive = shortest-job-first)

# placement-row weights: score[h] = row_features[h] @ weights[ROW_SLICE],
# lower = better.  Index-aligned with the F_* feature enum below
# (weight index = W_ROW0 + F_*).
W_ROW0 = 4
F_RECENCY = 0         # mod-distance past the rotating pointer; rr = -1
#                       (never tracked) makes this the host index = FirstFit
F_NEG_SPEED = 1       # -speed[h, ctype[cand]]           (PerformanceFirst)
F_WORST_FIT = 2       # -(free/cap).sum over resources   (worst fit)
F_COLOC = 3           # -same-job count per host, 0 while job has no peers
F_COMM = 4            # mean comm_cost to deployed peers, 0 while no peers
F_FALLBACK_WORST = 5  # worst-fit gated to the NO-peers case (the JobGroup/
#                       NetAware fallback; disjoint support with F_COLOC/F_COMM)
F_HOST_UTIL = 6       # bottleneck-resource utilization of the host
F_FREE_CPU = 7        # normalized free CPU
F_FREE_MEM = 8        # normalized free memory
F_UPLINK_UTIL = 9     # utilization of the host's access link (first hop)
F_CROSS_LEAF = 10     # fraction of deployed same-job peers on another leaf
NUM_ROW_FEATURES = 11

# carry-behavior weights:
W_RR_TRACK = W_ROW0 + NUM_ROW_FEATURES   # > 0: rotating pointer follows
#                                          admits (Round); 0: rr stays put

# migration weights: the trigger is the mask weight (> 0 enables the
# overload-source rule; 0 reproduces the old no-op branch exactly), the
# destination is scored dst_features @ weights[MIG_SLICE], lower = better,
# index-aligned with the M_* enum (weight index = W_MIG0 + M_*).
W_MIG_ENABLE = W_RR_TRACK + 1
W_MIG0 = W_MIG_ENABLE + 1
M_IDX = 0             # host index                  (first-fit destination)
M_PATH_UTIL = 1       # bottleneck ECMP-path utilization from the source
M_CROSS_LEAF = 2      # destination sits on another leaf than the source
M_WORST_FIT = 3       # -(free/cap).sum — prefer emptier destinations
NUM_MIG_FEATURES = 4

NUM_POLICY_WEIGHTS = W_MIG0 + NUM_MIG_FEATURES

# index-aligned names for the whole vector — the by-name construction /
# reporting surface (scheduling.weight_vector, report.tune_table)
WEIGHT_NAMES: tuple = (
    "util", "cross_leaf",
    "sel_submit", "sel_duration",
    "row_recency", "row_neg_speed", "row_worst_fit", "row_coloc",
    "row_comm", "row_fallback_worst", "row_host_util", "row_free_cpu",
    "row_free_mem", "row_uplink_util", "row_cross_leaf",
    "rr_track",
    "mig_enable", "mig_idx", "mig_path_util", "mig_cross_leaf",
    "mig_worst_fit",
)
assert len(WEIGHT_NAMES) == NUM_POLICY_WEIGHTS


class RunParams(NamedTuple):
    """Runtime simulation parameters — everything a sweep varies that is NOT
    shape- or control-flow-affecting.

    The static ``SimConfig`` keeps tensor shapes and compiled structure
    (horizon, scan lengths, engine flags); these knobs ride through the tick
    as traced scalars, so a ladder of (bw, loss, queue_coef, thresholds)
    points is a ``RunParams`` with a leading batch axis and ZERO extra
    compilations.  Defaults come from ``SimConfig.run_params()``.
    """

    bw_mbps: jnp.ndarray            # f32[] uniform link-bw override; <=0 keeps
    #                                       the topology's per-link bandwidth
    loss: jnp.ndarray               # f32[] uniform loss override; <0 keeps
    queue_coef: jnp.ndarray         # f32[] M/M/1 queueing-delay coefficient
    overload_threshold: jnp.ndarray  # f32[] migration source / stats threshold
    idle_threshold: jnp.ndarray     # f32[] migration destination threshold
    tau: jnp.ndarray                # f32[] soft-placement softmax temperature
    #                                       (only read when
    #                                       SimConfig.soft_placement; traced,
    #                                       so annealing never recompiles)


class SchedState(NamedTuple):
    """Mutable scheduler bookkeeping (e.g. Round pointer)."""

    rr_pointer: jnp.ndarray    # i32[] last host used by Round
    decisions: jnp.ndarray     # i32[] placement decisions made this tick
    migrations: jnp.ndarray    # i32[] migrations started this tick


class SimState(NamedTuple):
    t: jnp.ndarray             # f32[] simulation clock (seconds)
    hosts: HostState
    containers: ContainerState
    net: NetState
    sched: SchedState
    total_cost: jnp.ndarray    # f32[] accumulated host-price cost
    rng: jnp.ndarray           # PRNG key for stochastic tie-breaks


class TickMetrics(NamedTuple):
    """Per-tick observables (paper's data-collection module)."""

    t: jnp.ndarray
    n_overloaded: jnp.ndarray      # hosts with util > overload_threshold
    n_inactive: jnp.ndarray        # waiting-to-be-scheduled queue size
    n_running: jnp.ndarray
    n_deployed: jnp.ndarray        # paper's "running queue": run+comm+migrate
    n_communicating: jnp.ndarray
    n_waiting: jnp.ndarray
    n_completed: jnp.ndarray
    n_migrating: jnp.ndarray
    new_arrivals: jnp.ndarray      # containers that arrived this tick
    decisions: jnp.ndarray         # placements this tick (paper Fig 6)
    migrations: jnp.ndarray        # migrations started this tick (paper Fig 7)
    util_variance: jnp.ndarray     # variance of mean host utilization (Fig 10)
    mean_util: jnp.ndarray
    active_flows: jnp.ndarray
    mean_flow_rate: jnp.ndarray    # KB/s over active flows
    # --- soft-placement surrogate terms (SimConfig.soft_placement) ---
    # Expected feature costs under the softmax relaxation of each discrete
    # placement/migration decision: q = softmax(-score_row / tau) over the
    # feasible hosts.  The *dynamics* stay the hard argmin (bit-for-bit
    # identical to soft_placement=False); these extra observables are the
    # differentiable surrogate that jax.grad(objective)(weights) flows
    # through.  All exact 0.0 when soft placement is off.
    soft_comm: jnp.ndarray         # sum of E_q[comm-cost col] over admits
    soft_util: jnp.ndarray         # sum of E_q[host-util col] over admits
    soft_n: jnp.ndarray            # f32 count of admits with >=1 feasible host
    soft_mig: jnp.ndarray          # sum of E_q[path-util col] over migrations
    soft_mig_n: jnp.ndarray        # f32 count of soft-scored migrations


class SummaryAcc(NamedTuple):
    """Online per-run summary accumulator — the O(state) replacement for
    stacking ``TickMetrics`` over the horizon.

    Lives in the chunked scan's carry (``engine.run_sim(chunk=...)``):
    every tick folds its metrics in, nothing is ever stacked, so a 10^6-tick
    trace costs the same device memory as a 10^2-tick one.  All leaves are
    scalars in the tick's native 32-bit dtypes — integer sums stay exact
    because the host loop bounds the per-chunk tick count
    (``stats.max_chunk_ticks``) so no i32 sum can overflow, and float sums
    carry a Kahan compensation term; the 64-bit promotion happens host-side
    only, when ``stats.online_fold`` folds a finished chunk into an
    ``OnlineSummary`` (f64/i64) and resets this accumulator.
    """

    n_ticks: jnp.ndarray           # i32[] ticks folded into this chunk
    # Kahan-compensated f32 sums of the per-tick float series
    sum_util_var: jnp.ndarray      # f32[] sum of util_variance
    c_util_var: jnp.ndarray        # f32[] its compensation term
    sum_mean_util: jnp.ndarray     # f32[] sum of mean_util
    c_mean_util: jnp.ndarray       # f32[]
    sum_flow_rate: jnp.ndarray     # f32[] sum of mean_flow_rate
    c_flow_rate: jnp.ndarray       # f32[]
    # Welford moments of mean_util over time (per-chunk; chunks are merged
    # host-side with the Chan parallel-combine rule)
    w_mean_util: jnp.ndarray       # f32[] running mean of mean_util
    w_m2_util: jnp.ndarray         # f32[] running sum of squared deviations
    # integer sums (exact within the chunk bound) and peaks
    sum_active_flows: jnp.ndarray  # i32[] flow-ticks (= flow-seconds)
    sum_arrivals: jnp.ndarray      # i32[]
    sum_decisions: jnp.ndarray     # i32[]
    sum_migrations: jnp.ndarray    # i32[] migration *starts*
    peak_running: jnp.ndarray      # i32[]
    peak_deployed: jnp.ndarray     # i32[]
    peak_overloaded: jnp.ndarray   # i32[]
    peak_inactive: jnp.ndarray     # i32[] worst scheduling-queue depth
    # Kahan-compensated f32 sums of the soft-placement surrogate series
    # (all exact 0.0 when SimConfig.soft_placement is off)
    sum_soft_comm: jnp.ndarray     # f32[]
    c_soft_comm: jnp.ndarray       # f32[]
    sum_soft_util: jnp.ndarray     # f32[]
    c_soft_util: jnp.ndarray       # f32[]
    sum_soft_n: jnp.ndarray        # f32[]
    c_soft_n: jnp.ndarray          # f32[]
    sum_soft_mig: jnp.ndarray      # f32[]
    c_soft_mig: jnp.ndarray        # f32[]
    sum_soft_mig_n: jnp.ndarray    # f32[]
    c_soft_mig_n: jnp.ndarray      # f32[]


class OnlineSummary(NamedTuple):
    """Host-side (numpy, f64/i64) fold of ``SummaryAcc`` chunks.

    The streaming twin of stacked ``TickMetrics``: ``report.summarize``
    accepts either.  Leaves broadcast over leading batch axes, so a
    [P, S, N]-batched streaming sweep folds into one of these per grid.
    """

    n_ticks: np.ndarray            # i64
    sum_util_var: np.ndarray       # f64
    sum_mean_util: np.ndarray      # f64
    sum_flow_rate: np.ndarray      # f64
    w_mean_util: np.ndarray        # f64 Welford mean of mean_util over time
    w_m2_util: np.ndarray          # f64 Welford M2 of mean_util over time
    sum_active_flows: np.ndarray   # i64
    sum_arrivals: np.ndarray       # i64
    sum_decisions: np.ndarray      # i64
    sum_migrations: np.ndarray     # i64
    peak_running: np.ndarray       # i64
    peak_deployed: np.ndarray      # i64
    peak_overloaded: np.ndarray    # i64
    peak_inactive: np.ndarray      # i64
    # soft-placement surrogate sums (f64; 0.0 when soft placement is off)
    sum_soft_comm: np.ndarray      # f64
    sum_soft_util: np.ndarray      # f64
    sum_soft_n: np.ndarray         # f64
    sum_soft_mig: np.ndarray       # f64
    sum_soft_mig_n: np.ndarray     # f64


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """One object for every *execution* knob — how a run is executed, never
    what it simulates.

    PRs 6-8 grew these knobs one call-site at a time (``chunk=`` on
    ``run_sim``, ``slab=``/``overlap=``/``devices=`` on the sweep,
    ``procs=``/``devices_per_proc=`` on tune, kernel selectors on
    ``SimConfig``); this consolidates them so ``run_sim``/``run_sweep``/
    ``run_tune``/``launch.dist`` all accept ``plan=ExecPlan(...)`` and the
    old kwargs survive exactly one deprecation cycle.

    jit-cache-key semantics are unchanged: the plan is *resolved* at the
    call boundary — kernel selectors are folded into the static
    ``SimConfig`` (``apply_to_config``), chunk/slab shape the host loop and
    the compiled step's shapes, devices pick the sharding mesh — so the
    plan itself is never a traced value and never a jit static argument.

    ``None`` everywhere means "keep the current default" (stacked run,
    config's kernel selectors, all local devices, in-process).
    """

    chunk: int | None = None            # ticks per compiled scan segment;
    #                                     None = stacked single-scan run
    slab: int | None = None             # sweep cells per device per slab;
    #                                     None = whole grid in one slab
    delay_kernel: str | None = None     # override SimConfig.delay_kernel
    #                                     ('auto'|'on'|'off'); None keeps
    waterfill_kernel: str | None = None  # override SimConfig.waterfill_kernel
    devices: tuple | int | None = None  # jax devices for the sweep mesh
    #                                     (sequence, or a count of local
    #                                     devices); None = all local devices
    overlap: bool = True                # overlap slab gather behind compute
    telescope: bool = False             # macro-tick engine: advance dt >= 1
    #                                     ticks per step over quiescent
    #                                     intervals (docs/events.md)
    procs: int = 1                      # worker processes (launch.dist)
    devices_per_proc: int = 1           # devices each dist worker claims

    def __post_init__(self):
        if self.devices is not None \
                and not isinstance(self.devices, (tuple, int)):
            object.__setattr__(self, "devices", tuple(self.devices))
        for name in ("chunk", "slab"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"ExecPlan.{name} must be positive, "
                                 f"got {v}")
        if self.procs < 1 or self.devices_per_proc < 1:
            raise ValueError("ExecPlan.procs and devices_per_proc must be "
                             ">= 1")
        for name in ("delay_kernel", "waterfill_kernel"):
            v = getattr(self, name)
            if v is not None and v not in ("auto", "on", "off"):
                raise ValueError(f"ExecPlan.{name} must be one of "
                                 f"'auto'/'on'/'off'/None, got {v!r}")

    def apply_to_config(self, cfg):
        """Fold the kernel selectors into the static ``SimConfig``.

        The config stays the jit cache key: two plans that pick the same
        kernels hit the same compiled program, and a kernel change
        recompiles exactly as a config change always did.
        """
        updates = {}
        if self.delay_kernel is not None \
                and self.delay_kernel != cfg.delay_kernel:
            updates["delay_kernel"] = self.delay_kernel
        if self.waterfill_kernel is not None \
                and self.waterfill_kernel != cfg.waterfill_kernel:
            updates["waterfill_kernel"] = self.waterfill_kernel
        return dataclasses.replace(cfg, **updates) if updates else cfg

    @classmethod
    def from_args(cls, args) -> "ExecPlan":
        """Build a plan from an ``argparse`` namespace produced by
        ``repro.launch.execargs.add_exec_args`` — missing attributes fall
        back to the field defaults, so partial namespaces work."""
        defaults = cls()

        def get(name, fallback):
            v = getattr(args, name, None)
            return fallback if v is None else v

        return cls(
            chunk=getattr(args, "chunk", None),
            slab=getattr(args, "slab", None),
            delay_kernel=getattr(args, "delay_kernel", None),
            waterfill_kernel=getattr(args, "waterfill_kernel", None),
            devices=getattr(args, "devices", None),
            overlap=(not getattr(args, "no_overlap", False)),
            telescope=bool(getattr(args, "telescope", False)),
            procs=get("procs", defaults.procs),
            devices_per_proc=get("devices_per_proc",
                                 defaults.devices_per_proc),
        )


def empty_containers(capacity: int) -> ContainerState:
    C = capacity
    f = lambda fill: jnp.full((C,), fill, jnp.float32)
    i = lambda fill: jnp.full((C,), fill, jnp.int32)
    return ContainerState(
        status=i(STATUS_UNBORN), ctype=i(0),
        req=jnp.zeros((C, NUM_RESOURCES), jnp.float32),
        duration=f(0.0), run_at=f(0.0), host=i(-1), job=i(-1), task=i(-1),
        submit_t=f(jnp.inf), start_t=f(-1.0), finish_t=f(-1.0),
        n_comms_left=i(0), comm_work_gap=f(jnp.inf), next_comm_at=f(jnp.inf),
        comm_bytes=f(0.0), comm_bytes_left=f(0.0), comm_peer=i(-1),
        comm_time=f(0.0), retry=i(0), mig_dst=i(-1), mig_bytes_left=f(0.0),
        n_migrations=i(0),
    )


def make_hosts(cap: np.ndarray, speed: np.ndarray, price: np.ndarray,
               leaf: np.ndarray) -> HostState:
    H = cap.shape[0]
    return HostState(
        cap=jnp.asarray(cap, jnp.float32),
        speed=jnp.asarray(speed, jnp.float32),
        price=jnp.asarray(price, jnp.float32),
        used=jnp.zeros((H, NUM_RESOURCES), jnp.float32),
        n_containers=jnp.zeros((H,), jnp.int32),
        leaf=jnp.asarray(leaf, jnp.int32),
        busy_time=jnp.zeros((H,), jnp.float32),
    )
