"""Struct-of-arrays state for the DCSim-JAX engine.

The paper's Container/Host/Job Python objects become fixed-capacity tensors
with masks; the six container states of paper Table 2 map to STATUS_* codes.
Every field is a leaf of a NamedTuple pytree so the whole simulator state can
be carried through ``lax.scan`` and ``vmap``-ed over scenarios.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Container lifecycle (paper Table 2)
# ---------------------------------------------------------------------------
STATUS_UNBORN = -1        # slot exists but the job has not been submitted yet
STATUS_INACTIVE = 0       # submitted, not scheduled            (undeployed)
STATUS_RUNNING = 1        # deployed and executing              (deployed)
STATUS_COMMUNICATING = 2  # paused on a network transfer        (deployed)
STATUS_MIGRATING = 3      # being moved to another host         (dep+undep)
STATUS_WAITING = 4        # suspended after comm/migration fail (undeployed)
STATUS_COMPLETED = 5      # finished                            (completed)

# Container primary resource types (paper §3.3)
CTYPE_CPU = 0
CTYPE_MEM = 1
CTYPE_GPU = 2

NUM_RESOURCES = 3  # cpu (%), mem (GB), gpu (%)


class HostState(NamedTuple):
    """Heterogeneous hosts (paper Table 5): capacity, *speed* and price."""

    cap: jnp.ndarray       # f32[H, 3]  resource capacity
    speed: jnp.ndarray     # f32[H, 3]  per-resource processing speed (1..4)
    price: jnp.ndarray     # f32[H]     $ per busy second
    used: jnp.ndarray      # f32[H, 3]  currently committed resources
    n_containers: jnp.ndarray  # i32[H] deployed container count (net-node cap)
    leaf: jnp.ndarray      # i32[H]     leaf switch this host hangs off
    busy_time: jnp.ndarray  # f32[H]    accumulated seconds with >=1 container


class ContainerState(NamedTuple):
    """Three-tier Job -> Task -> Container model, SoA over container slots."""

    status: jnp.ndarray        # i32[C] STATUS_*
    ctype: jnp.ndarray         # i32[C] CTYPE_* (primary resource)
    req: jnp.ndarray           # f32[C, 3] resource request
    duration: jnp.ndarray      # f32[C] total work units
    run_at: jnp.ndarray        # f32[C] executed work units
    host: jnp.ndarray          # i32[C] current host (-1 undeployed)
    job: jnp.ndarray           # i32[C] job id
    task: jnp.ndarray          # i32[C] task id
    submit_t: jnp.ndarray      # f32[C] arrival time
    start_t: jnp.ndarray       # f32[C] first deployment time (-1)
    finish_t: jnp.ndarray      # f32[C] completion time (-1)
    # --- communication schedule (paper: 1..5 comms of 100..102400 KB) ---
    n_comms_left: jnp.ndarray  # i32[C] remaining communication events
    comm_work_gap: jnp.ndarray # f32[C] work units between comm trigger points
    next_comm_at: jnp.ndarray  # f32[C] work-unit threshold of next comm
    comm_bytes: jnp.ndarray    # f32[C] KB per communication event
    comm_bytes_left: jnp.ndarray  # f32[C] KB outstanding on the active comm
    comm_peer: jnp.ndarray     # i32[C] partner container of active comm (-1)
    comm_time: jnp.ndarray     # f32[C] accumulated communicating seconds
    retry: jnp.ndarray         # i32[C] consecutive stalled ticks on the flow
    # --- migration ---
    mig_dst: jnp.ndarray       # i32[C] destination host while migrating (-1)
    mig_bytes_left: jnp.ndarray  # f32[C] KB outstanding on the migration flow
    n_migrations: jnp.ndarray  # i32[C] how many times this container migrated


class NetState(NamedTuple):
    """Spine-leaf network: static topology tables + dynamic delay matrix.

    Mininet's emulated fabric becomes link tables; the paper's ping-refreshed
    ``delay_matrix`` (eq. 1) is recomputed from congestion-adjusted link
    delays by min-plus Floyd-Warshall every ``delay_update_interval`` ticks.
    """

    # static link tables -------------------------------------------------
    link_bw: jnp.ndarray      # f32[E] Mbps
    link_delay: jnp.ndarray   # f32[E] ms base propagation+switching delay
    link_loss: jnp.ndarray    # f32[E] packet loss fraction
    # node graph: adjacency (node_u[e], node_v[e]) both directions implied
    link_u: jnp.ndarray       # i32[E]
    link_v: jnp.ndarray       # i32[E]
    # deterministic ECMP path between every host pair (<=4 links, -1 pad)
    path_links: jnp.ndarray   # i32[H, H, 4]
    path_nlinks: jnp.ndarray  # i32[H, H]
    # precomputed (derived from the static tables; kept on the state so the
    # per-tick sparse flow kernels are pure gathers + segment reductions)
    link_bw_kbps: jnp.ndarray  # f32[E] link_bw converted to KB/s
    path_loss: jnp.ndarray    # f32[H, H] end-to-end loss prob along ECMP path
    # dynamic ----------------------------------------------------------------
    link_util: jnp.ndarray    # f32[E] utilization from last tick's flows
    delay_matrix: jnp.ndarray  # f32[H, H] host-to-host delay (the paper's D)
    # expected cost of one unit of communication between every host pair:
    # delay + congestion along the ECMP path + a cross-leaf locality penalty.
    # Refreshed together with the delay matrix (network.pairwise_comm_cost);
    # consumed by the network-aware scheduling policies.
    comm_cost: jnp.ndarray    # f32[H, H]


class PolicyParams(NamedTuple):
    """The *data* half of a scheduling policy (the code half is the branch
    table in ``repro.core.scheduling``).

    What distinguishes one policy from another in a compiled run is pure
    data: a branch index dispatched with ``lax.switch`` plus a weight vector
    consumed by the cost-model-driven scores.  Because both leaves are
    arrays, a *batch* of policies is just a ``PolicyParams`` with a leading
    axis — ``vmap`` sweeps every registered algorithm inside one XLA
    program instead of recompiling per policy.
    """

    policy_id: jnp.ndarray   # i32[]  branch index into the registry
    weights: jnp.ndarray     # f32[NUM_POLICY_WEIGHTS]


# PolicyParams.weights layout — the first entries are the cost-model-driven
# comm-cost weights the netaware score consumes (via NetState.comm_cost,
# re-weighted at every delay refresh).
W_UTIL = 0        # ms-equivalent per unit of bottleneck ECMP-path utilization
W_CROSS_LEAF = 1  # ms penalty for paths that transit the spine
NUM_POLICY_WEIGHTS = 2


class RunParams(NamedTuple):
    """Runtime simulation parameters — everything a sweep varies that is NOT
    shape- or control-flow-affecting.

    The static ``SimConfig`` keeps tensor shapes and compiled structure
    (horizon, scan lengths, engine flags); these knobs ride through the tick
    as traced scalars, so a ladder of (bw, loss, queue_coef, thresholds)
    points is a ``RunParams`` with a leading batch axis and ZERO extra
    compilations.  Defaults come from ``SimConfig.run_params()``.
    """

    bw_mbps: jnp.ndarray            # f32[] uniform link-bw override; <=0 keeps
    #                                       the topology's per-link bandwidth
    loss: jnp.ndarray               # f32[] uniform loss override; <0 keeps
    queue_coef: jnp.ndarray         # f32[] M/M/1 queueing-delay coefficient
    overload_threshold: jnp.ndarray  # f32[] migration source / stats threshold
    idle_threshold: jnp.ndarray     # f32[] migration destination threshold


class SchedState(NamedTuple):
    """Mutable scheduler bookkeeping (e.g. Round pointer)."""

    rr_pointer: jnp.ndarray    # i32[] last host used by Round
    decisions: jnp.ndarray     # i32[] placement decisions made this tick
    migrations: jnp.ndarray    # i32[] migrations started this tick


class SimState(NamedTuple):
    t: jnp.ndarray             # f32[] simulation clock (seconds)
    hosts: HostState
    containers: ContainerState
    net: NetState
    sched: SchedState
    total_cost: jnp.ndarray    # f32[] accumulated host-price cost
    rng: jnp.ndarray           # PRNG key for stochastic tie-breaks


class TickMetrics(NamedTuple):
    """Per-tick observables (paper's data-collection module)."""

    t: jnp.ndarray
    n_overloaded: jnp.ndarray      # hosts with util > overload_threshold
    n_inactive: jnp.ndarray        # waiting-to-be-scheduled queue size
    n_running: jnp.ndarray
    n_deployed: jnp.ndarray        # paper's "running queue": run+comm+migrate
    n_communicating: jnp.ndarray
    n_waiting: jnp.ndarray
    n_completed: jnp.ndarray
    n_migrating: jnp.ndarray
    new_arrivals: jnp.ndarray      # containers that arrived this tick
    decisions: jnp.ndarray         # placements this tick (paper Fig 6)
    migrations: jnp.ndarray        # migrations started this tick (paper Fig 7)
    util_variance: jnp.ndarray     # variance of mean host utilization (Fig 10)
    mean_util: jnp.ndarray
    active_flows: jnp.ndarray
    mean_flow_rate: jnp.ndarray    # KB/s over active flows


def empty_containers(capacity: int) -> ContainerState:
    C = capacity
    f = lambda fill: jnp.full((C,), fill, jnp.float32)
    i = lambda fill: jnp.full((C,), fill, jnp.int32)
    return ContainerState(
        status=i(STATUS_UNBORN), ctype=i(0),
        req=jnp.zeros((C, NUM_RESOURCES), jnp.float32),
        duration=f(0.0), run_at=f(0.0), host=i(-1), job=i(-1), task=i(-1),
        submit_t=f(jnp.inf), start_t=f(-1.0), finish_t=f(-1.0),
        n_comms_left=i(0), comm_work_gap=f(jnp.inf), next_comm_at=f(jnp.inf),
        comm_bytes=f(0.0), comm_bytes_left=f(0.0), comm_peer=i(-1),
        comm_time=f(0.0), retry=i(0), mig_dst=i(-1), mig_bytes_left=f(0.0),
        n_migrations=i(0),
    )


def make_hosts(cap: np.ndarray, speed: np.ndarray, price: np.ndarray,
               leaf: np.ndarray) -> HostState:
    H = cap.shape[0]
    return HostState(
        cap=jnp.asarray(cap, jnp.float32),
        speed=jnp.asarray(speed, jnp.float32),
        price=jnp.asarray(price, jnp.float32),
        used=jnp.zeros((H, NUM_RESOURCES), jnp.float32),
        n_containers=jnp.zeros((H,), jnp.int32),
        leaf=jnp.asarray(leaf, jnp.int32),
        busy_time=jnp.zeros((H,), jnp.float32),
    )
