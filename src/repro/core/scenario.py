"""Scenario layer: declarative sweep points -> batched simulator inputs.

A :class:`ScenarioSpec` names everything one cell of a sweep varies — link
bandwidth/loss, arrival pattern, host price/capacity mix, runtime
thresholds — WITHOUT touching anything shape- or compile-affecting.  The
builders turn a list of specs into exactly two batched pytrees:

* a ``SimState`` with leading axes ``[S, N]`` (scenario x seed): hosts,
  workload, base network (different host mixes and arrival processes are
  different *state*, which vmaps for free);
* a ``RunParams`` with leading axis ``[S]``: bw/loss overrides and the
  runtime knobs, applied inside ``engine.simulate`` at t=0.

``repro/launch/sweep.py`` feeds both (plus a policy batch) to one
``jax.jit(vmap(vmap(vmap(simulate))))`` call — the paper's Figs 4-10
evaluation grid as a single compiled program.  Since the scatter-free
tick (PR 4, docs/perf.md) that is literally the code: all three axes are
``vmap`` batch dimensions, so everything a spec varies must stay a value
change on a fixed-shape pytree — which is exactly what the keep-sentinel
design below guarantees.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.datacenter import SimConfig, mixed_hosts
from repro.core.engine import init_sim
from repro.core.network import SpineLeafSpec, build_network
from repro.core.types import RunParams, SimState
from repro.core.workload import bursty_workload, paper_workload, trace_workload

ARRIVALS: dict[str, Callable] = {
    "paper": paper_workload,
    "trace": trace_workload,
    "bursty": bursty_workload,
}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One sweep point.  ``None`` means "keep the config/topology default"
    — it maps onto the RunParams keep-sentinels, so every spec produces the
    same pytree structure and a ladder stacks into one batch axis."""

    name: str
    bw: float | None = None            # uniform link bandwidth (Mbps)
    loss: float | None = None          # uniform link loss fraction
    arrival: str = "paper"             # paper | trace | bursty
    host_mix: str = "paper"            # datacenter.HOST_MIXES key
    queue_coef: float | None = None
    overload_threshold: float | None = None
    idle_threshold: float | None = None
    tau: float | None = None           # soft-placement temperature override

    def __post_init__(self):
        # the RunParams sentinels (<=0 bw, <0 loss) mean "keep"; reject
        # spec values inside that domain instead of silently not overriding
        if self.bw is not None and self.bw <= 0:
            raise ValueError(f"{self.name}: bw must be > 0 Mbps, "
                             f"got {self.bw}")
        if self.loss is not None and self.loss < 0:
            raise ValueError(f"{self.name}: loss must be >= 0, "
                             f"got {self.loss}")
        if self.arrival not in ARRIVALS:
            raise KeyError(f"{self.name}: unknown arrival "
                           f"{self.arrival!r}; known: {sorted(ARRIVALS)}")
        if self.tau is not None and self.tau <= 0:
            raise ValueError(f"{self.name}: tau must be > 0, got {self.tau}")

    def run_params(self, cfg: SimConfig) -> RunParams:
        base = cfg.run_params()
        f32 = lambda v, dflt: dflt if v is None else jnp.asarray(
            v, jnp.float32)
        return RunParams(
            bw_mbps=f32(self.bw, base.bw_mbps),
            loss=f32(self.loss, base.loss),
            queue_coef=f32(self.queue_coef, base.queue_coef),
            overload_threshold=f32(self.overload_threshold,
                                   base.overload_threshold),
            idle_threshold=f32(self.idle_threshold, base.idle_threshold),
            tau=f32(self.tau, base.tau),
        )


def default_scenarios() -> list[ScenarioSpec]:
    """The paper's evaluation grid as data: a healthy fabric, the Fig 5/8
    degraded-network ladder, a flash-crowd arrival process, and a
    heterogeneous-fleet price/capacity mix."""
    return [
        ScenarioSpec("baseline"),
        ScenarioSpec("slow_net", bw=200.0),
        ScenarioSpec("lossy_net", bw=500.0, loss=0.02),
        ScenarioSpec("bursty", arrival="bursty"),
        ScenarioSpec("premium_hosts", host_mix="premium"),
    ]


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def build_scenario(spec: ScenarioSpec, cfg: SimConfig, n_hosts: int = 20,
                   n_spine: int = 2, n_leaf: int = 4,
                   seeds: Sequence[int] = (0,), net=None):
    """One scenario -> (SpineLeafSpec, SimState batched over seeds [N, ...],
    RunParams).  The network is built at topology defaults; the spec's
    bw/loss ride in the RunParams and hit the links inside the compiled run.
    ``net`` lets callers share one built topology across scenarios.
    """
    net_spec = SpineLeafSpec(n_spine=n_spine, n_leaf=n_leaf, n_hosts=n_hosts)
    net = build_network(net_spec) if net is None else net
    hosts = mixed_hosts(spec.host_mix, n_hosts, n_leaf)
    gen = ARRIVALS[spec.arrival]
    sims = [init_sim(hosts, gen(cfg, seed=s), net, seed=s) for s in seeds]
    return net_spec, _stack(sims), spec.run_params(cfg)


def build_scenarios(specs: Sequence[ScenarioSpec], cfg: SimConfig,
                    n_hosts: int = 20, n_spine: int = 2, n_leaf: int = 4,
                    seeds: Sequence[int] = (0,)):
    """Scenario list -> (SpineLeafSpec, SimState [S, N, ...], RunParams [S]).

    Every spec must share the topology shape (same host/leaf/spine counts)
    — that is the compile-relevant part; everything a spec *does* vary is
    state or RunParams, so the stacked batch runs under one compilation,
    and the O(H^2) topology build happens once, not once per scenario.
    """
    net = build_network(SpineLeafSpec(n_spine=n_spine, n_leaf=n_leaf,
                                      n_hosts=n_hosts))
    spec_net = None
    sims, params = [], []
    for spec in specs:
        net_spec, sim, rp = build_scenario(spec, cfg, n_hosts=n_hosts,
                                           n_spine=n_spine, n_leaf=n_leaf,
                                           seeds=seeds, net=net)
        spec_net = spec_net or net_spec
        sims.append(sim)
        params.append(rp)
    return spec_net, _stack(sims), _stack(params)
