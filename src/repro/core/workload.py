"""Workload generation (paper §3.3): Job -> Task -> Container three-tier model.

Three generators (the scenario layer selects one by name):
* ``paper_workload``     — paper Table 6 synthetic distribution.
* ``trace_workload``     — Alibaba GPU-trace-shaped generator (job sizes and
                           inter-arrival follow heavy-tailed draws like
                           cluster-trace-gpu-v2020), same SoA output.
* ``bursty_workload``    — flash-crowd arrivals: jobs land in a few tight
                           bursts instead of uniformly over the window.

All emit a fully-populated ``ContainerState`` with STATUS_UNBORN slots that
the engine activates when ``t >= submit_t``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.datacenter import SimConfig
from repro.core.types import STATUS_UNBORN, ContainerState, empty_containers


def next_arrival_after(containers: ContainerState,
                       t: jnp.ndarray) -> jnp.ndarray:
    """Earliest pending submit time strictly after tick ``t`` (f32 scalar,
    +inf when every slot has arrived).

    The telescoping engine's arrival component of the event horizon
    (docs/events.md): padded slots carry ``submit_t = inf`` and arrived
    slots have left STATUS_UNBORN, so the min over the still-unborn mask
    IS the next ``phase_arrive`` event.  Pure masked reduction — batches
    under the sweep vmap for free.
    """
    pending = (containers.status == STATUS_UNBORN) & (containers.submit_t > t)
    return jnp.min(jnp.where(pending, containers.submit_t, jnp.inf))


def _assign_jobs_tasks(rng: np.random.Generator, n_jobs: int, n_tasks: int,
                       n_containers: int):
    """Split tasks over jobs and containers over tasks (>=1 each)."""
    task_job = np.sort(rng.integers(0, n_jobs, size=n_tasks))
    # guarantee every job has >= 1 task
    task_job[:n_jobs] = np.arange(n_jobs)
    task_job = np.sort(task_job)
    cont_task = np.sort(rng.integers(0, n_tasks, size=n_containers))
    cont_task[:n_tasks] = np.arange(n_tasks)
    cont_task = np.sort(cont_task)
    cont_job = task_job[cont_task]
    return cont_job.astype(np.int32), cont_task.astype(np.int32)


def _comm_schedule(duration: np.ndarray, n_comms: np.ndarray) -> np.ndarray:
    """Work-unit gap between communication trigger points, for every slot.

    Trigger points are spread evenly through the work units; the first one
    sits at ``gap``.  Padded slots (duration 0) get inf = never trigger.
    The one place this rule lives — both generators and any duration
    rewrite must go through it so ``comm_work_gap``/``next_comm_at`` stay
    consistent.
    """
    return np.where(duration > 0, duration / (n_comms + 1),
                    np.inf).astype(np.float32)


def _fill(state: ContainerState, rng: np.random.Generator, cfg: SimConfig,
          cont_job: np.ndarray, cont_task: np.ndarray,
          submit: np.ndarray) -> ContainerState:
    C = state.status.shape[0]
    n = cont_job.shape[0]
    assert n <= C, f"workload ({n}) exceeds container capacity ({C})"

    req = np.zeros((C, 3), np.float32)
    req[:n, 0] = rng.uniform(*cfg.cpu_req_range, size=n)
    req[:n, 1] = rng.uniform(*cfg.mem_req_range, size=n)
    req[:n, 2] = rng.uniform(*cfg.gpu_req_range, size=n)
    # primary resource type: dominant normalized request (paper §3.3 classes)
    norm = req[:n] / np.array([[1700.0, 32.0, 200.0]], np.float32)
    ctype = np.argmax(norm, axis=1).astype(np.int32)

    duration = np.zeros(C, np.float32)
    duration[:n] = rng.uniform(*cfg.duration_range, size=n)
    n_comms = np.zeros(C, np.int32)
    n_comms[:n] = rng.integers(cfg.n_comms_range[0], cfg.n_comms_range[1] + 1,
                               size=n)
    comm_kb = np.zeros(C, np.float32)
    comm_kb[:n] = rng.uniform(*cfg.comm_kb_range, size=n)
    gap = _comm_schedule(duration, n_comms)
    first_at = gap.copy()

    submit_t = np.full(C, np.inf, np.float32)
    submit_t[:n] = submit

    job = np.full(C, -1, np.int32)
    task = np.full(C, -1, np.int32)
    job[:n] = cont_job
    task[:n] = cont_task

    return state._replace(
        req=state.req.at[:].set(req),
        ctype=state.ctype.at[:].set(ctype),
        duration=state.duration.at[:].set(duration),
        n_comms_left=state.n_comms_left.at[:].set(n_comms),
        comm_bytes=state.comm_bytes.at[:].set(comm_kb),
        comm_work_gap=state.comm_work_gap.at[:].set(gap),
        next_comm_at=state.next_comm_at.at[:].set(first_at),
        submit_t=state.submit_t.at[:].set(submit_t),
        job=state.job.at[:].set(job),
        task=state.task.at[:].set(task),
    )


def paper_workload(cfg: SimConfig, seed: int = 0,
                   capacity: int | None = None) -> ContainerState:
    """Paper Table 6 distribution; jobs arrive uniformly in the window."""
    rng = np.random.default_rng(seed)
    C = capacity or cfg.n_containers
    cont_job, cont_task = _assign_jobs_tasks(
        rng, cfg.n_jobs, cfg.n_tasks, cfg.n_containers)
    job_arrival = np.sort(rng.uniform(0.0, cfg.arrival_window,
                                      size=cfg.n_jobs)).astype(np.float32)
    submit = job_arrival[cont_job]
    return _fill(empty_containers(C), rng, cfg, cont_job, cont_task, submit)


def bursty_workload(cfg: SimConfig, seed: int = 0,
                    capacity: int | None = None, n_bursts: int = 4,
                    burst_width: float = 1.5) -> ContainerState:
    """Flash-crowd arrivals: jobs cluster around ``n_bursts`` burst centers
    spread over the arrival window (Gaussian jitter of ``burst_width`` s).

    The paper's uniform window exercises steady-state scheduling; bursts
    stress the placement round's burst capacity (``placements_per_tick``)
    and the waiting queue — the overload-recovery axis of a scenario sweep.
    """
    rng = np.random.default_rng(seed)
    C = capacity or cfg.n_containers
    cont_job, cont_task = _assign_jobs_tasks(
        rng, cfg.n_jobs, cfg.n_tasks, cfg.n_containers)
    centers = np.sort(rng.uniform(0.0, cfg.arrival_window, size=n_bursts))
    which = rng.integers(0, n_bursts, size=cfg.n_jobs)
    jitter = rng.normal(0.0, burst_width, size=cfg.n_jobs)
    job_arrival = np.clip(centers[which] + jitter, 0.0,
                          None).astype(np.float32)
    submit = job_arrival[cont_job]
    return _fill(empty_containers(C), rng, cfg, cont_job, cont_task, submit)


def trace_workload(cfg: SimConfig, seed: int = 0,
                   capacity: int | None = None) -> ContainerState:
    """Alibaba-trace-shaped: lognormal job sizes, exponential inter-arrival."""
    rng = np.random.default_rng(seed)
    C = capacity or cfg.n_containers
    cont_job, cont_task = _assign_jobs_tasks(
        rng, cfg.n_jobs, cfg.n_tasks, cfg.n_containers)
    inter = rng.exponential(cfg.arrival_window / max(cfg.n_jobs, 1),
                            size=cfg.n_jobs)
    job_arrival = np.cumsum(inter).astype(np.float32)
    submit = job_arrival[cont_job]
    state = _fill(empty_containers(C), rng, cfg, cont_job, cont_task, submit)
    # heavy-tailed durations typical of GPU training jobs; the comm schedule
    # is rebuilt through the same rule _fill used so padded slots stay inf
    n = cont_job.shape[0]
    dur = np.zeros(C, np.float32)
    dur[:n] = np.clip(rng.lognormal(np.log(25.0), 0.6, size=n), 5.0, 300.0)
    gap = _comm_schedule(dur, np.asarray(state.n_comms_left))
    return state._replace(
        duration=jnp.asarray(dur),
        comm_work_gap=jnp.asarray(gap),
        next_comm_at=jnp.asarray(gap),
    )
