"""Data collection module (paper §3.7): per-tick metric extraction.

The paper's ``Stat`` class samples host/container/network state once per
second (``save_stats`` process).  Two collection modes share one
``collect`` pass:

* stacked — each tick's metrics are the ``ys`` of the engine's
  ``lax.scan``, so the full time series materializes (O(horizon) memory;
  the default for short horizons and the oracle the streaming mode is
  tested against);
* streaming — the tick folds its metrics into a ``SummaryAcc`` carried
  through the scan (``acc_update``), and the host folds finished chunks
  into an f64/i64 ``OnlineSummary`` (``online_fold``), so memory is
  O(state) at any horizon.  ``online_from_metrics`` computes the SAME
  summary from a stacked series — integer sums/counts/peaks agree
  bit-for-bit, float sums to ~1 ulp (Kahan-compensated f32 on device,
  folded in f64 host-side).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import (
    STATUS_COMMUNICATING, STATUS_COMPLETED, STATUS_INACTIVE, STATUS_MIGRATING,
    STATUS_RUNNING, STATUS_WAITING, OnlineSummary, RunParams, SimState,
    SummaryAcc, TickMetrics,
)

I32 = jnp.int32
F32 = jnp.float32


def collect(sim: SimState, new_arrivals: jnp.ndarray, decisions: jnp.ndarray,
            migrations: jnp.ndarray, params: RunParams,
            flow_active: jnp.ndarray, flow_rates: jnp.ndarray,
            soft=None) -> TickMetrics:
    """Per-tick metrics; ``params`` carries the (traced, sweepable)
    overload threshold the ``n_overloaded`` count is judged against.

    ``soft`` is the scheduling round's surrogate 5-tuple ``(soft_comm,
    soft_util, soft_n, soft_mig, soft_mig_n)`` from
    ``engine.phase_schedule_soft`` — exact 0.0 scalars when soft placement
    is off (or when the caller omits it).

    Pure gathers and reductions — no scatters, so the whole collection
    phase batches cleanly when the sweep vmaps the tick.  All lifecycle
    counts come from ONE [C, 6] comparison pass instead of six [C] sweeps.
    """
    if soft is None:
        soft = (jnp.zeros((), F32),) * 5
    soft_comm, soft_util, soft_n, soft_mig, soft_mig_n = soft
    st = sim.containers.status
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)      # [H, 3]
    worst = util.max(axis=1)
    mean_util = util.mean(axis=1)                                 # per-host
    n_active_flows = flow_active.sum()
    mean_rate = jnp.where(
        n_active_flows > 0,
        (flow_rates * flow_active).sum() / jnp.maximum(n_active_flows, 1),
        0.0)
    codes = (STATUS_INACTIVE, STATUS_RUNNING, STATUS_COMMUNICATING,
             STATUS_MIGRATING, STATUS_WAITING, STATUS_COMPLETED)
    counts = (st[:, None] == jnp.array(codes)[None, :]).sum(axis=0)
    count = dict(zip(codes, counts)).__getitem__
    return TickMetrics(
        t=sim.t,
        n_overloaded=(worst > params.overload_threshold).sum(),
        n_inactive=count(STATUS_INACTIVE) + count(STATUS_WAITING),
        n_running=count(STATUS_RUNNING),
        n_deployed=(count(STATUS_RUNNING) + count(STATUS_COMMUNICATING)
                    + count(STATUS_MIGRATING)),
        n_communicating=count(STATUS_COMMUNICATING),
        n_waiting=count(STATUS_WAITING),
        n_completed=count(STATUS_COMPLETED),
        n_migrating=count(STATUS_MIGRATING),
        new_arrivals=new_arrivals,
        decisions=decisions,
        migrations=migrations,
        util_variance=jnp.var(mean_util),
        mean_util=mean_util.mean(),
        active_flows=n_active_flows,
        mean_flow_rate=mean_rate,
        soft_comm=soft_comm, soft_util=soft_util, soft_n=soft_n,
        soft_mig=soft_mig, soft_mig_n=soft_mig_n,
    )


# ---------------------------------------------------------------------------
# Streaming accumulation: SummaryAcc (device, per chunk) -> OnlineSummary
# (host, f64/i64, whole run)
# ---------------------------------------------------------------------------
def max_chunk_ticks(n_containers: int) -> int:
    """Largest chunk size whose i32 accumulator sums cannot overflow.

    The fastest-growing integer series is ``active_flows`` (at most one
    communication + one migration flow per container = 2C per tick); every
    other counted series is bounded by C per tick.  The bound is loose by
    design — hitting it means the caller asked for ~10^7-tick chunks.
    """
    return (2**31 - 1) // max(2 * n_containers, 1)


def check_chunk(chunk: int, n_containers: int) -> None:
    limit = max_chunk_ticks(n_containers)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if chunk > limit:
        raise ValueError(
            f"chunk={chunk} can overflow i32 accumulator sums at "
            f"C={n_containers} containers (2C flows/tick); use "
            f"chunk <= {limit} — the host-side fold promotes to i64 "
            f"between chunks, so total horizon is unbounded")


def acc_init() -> SummaryAcc:
    """Zero accumulator (peaks start at 0: every counted series is >= 0)."""
    z_i = jnp.zeros((), I32)
    z_f = jnp.zeros((), F32)
    return SummaryAcc(
        n_ticks=z_i,
        sum_util_var=z_f, c_util_var=z_f,
        sum_mean_util=z_f, c_mean_util=z_f,
        sum_flow_rate=z_f, c_flow_rate=z_f,
        w_mean_util=z_f, w_m2_util=z_f,
        sum_active_flows=z_i, sum_arrivals=z_i, sum_decisions=z_i,
        sum_migrations=z_i, peak_running=z_i, peak_deployed=z_i,
        peak_overloaded=z_i, peak_inactive=z_i,
        sum_soft_comm=z_f, c_soft_comm=z_f,
        sum_soft_util=z_f, c_soft_util=z_f,
        sum_soft_n=z_f, c_soft_n=z_f,
        sum_soft_mig=z_f, c_soft_mig=z_f,
        sum_soft_mig_n=z_f, c_soft_mig_n=z_f,
    )


def _kahan(s, c, x):
    """One compensated-summation step: returns (s', c')."""
    y = x - c
    t = s + y
    return t, (t - s) - y


def acc_update(acc: SummaryAcc, m: TickMetrics) -> SummaryAcc:
    """Fold one tick's metrics into the accumulator (pure, scan-carry safe).

    f32 sums are Kahan-compensated; ``mean_util`` additionally feeds a
    Welford (mean, M2) pair so the run's utilization variance over TIME is
    available without the stacked series.  Integer sums stay i32 — exact
    as long as the host loop respects ``max_chunk_ticks``.
    """
    su, cu = _kahan(acc.sum_util_var, acc.c_util_var, m.util_variance)
    sm, cm = _kahan(acc.sum_mean_util, acc.c_mean_util, m.mean_util)
    sf, cf = _kahan(acc.sum_flow_rate, acc.c_flow_rate, m.mean_flow_rate)
    ssc, csc = _kahan(acc.sum_soft_comm, acc.c_soft_comm, m.soft_comm)
    ssu, csu = _kahan(acc.sum_soft_util, acc.c_soft_util, m.soft_util)
    ssn, csn = _kahan(acc.sum_soft_n, acc.c_soft_n, m.soft_n)
    ssm, csm = _kahan(acc.sum_soft_mig, acc.c_soft_mig, m.soft_mig)
    ssmn, csmn = _kahan(acc.sum_soft_mig_n, acc.c_soft_mig_n, m.soft_mig_n)
    n = acc.n_ticks + 1
    delta = m.mean_util - acc.w_mean_util
    w_mean = acc.w_mean_util + delta / n.astype(F32)
    w_m2 = acc.w_m2_util + delta * (m.mean_util - w_mean)
    return SummaryAcc(
        n_ticks=n,
        sum_util_var=su, c_util_var=cu,
        sum_mean_util=sm, c_mean_util=cm,
        sum_flow_rate=sf, c_flow_rate=cf,
        w_mean_util=w_mean, w_m2_util=w_m2,
        sum_active_flows=acc.sum_active_flows + m.active_flows.astype(I32),
        sum_arrivals=acc.sum_arrivals + m.new_arrivals.astype(I32),
        sum_decisions=acc.sum_decisions + m.decisions.astype(I32),
        sum_migrations=acc.sum_migrations + m.migrations.astype(I32),
        peak_running=jnp.maximum(acc.peak_running, m.n_running),
        peak_deployed=jnp.maximum(acc.peak_deployed, m.n_deployed),
        peak_overloaded=jnp.maximum(acc.peak_overloaded, m.n_overloaded),
        peak_inactive=jnp.maximum(acc.peak_inactive, m.n_inactive),
        sum_soft_comm=ssc, c_soft_comm=csc,
        sum_soft_util=ssu, c_soft_util=csu,
        sum_soft_n=ssn, c_soft_n=csn,
        sum_soft_mig=ssm, c_soft_mig=csm,
        sum_soft_mig_n=ssmn, c_soft_mig_n=csmn,
    )


def acc_update_weighted(acc: SummaryAcc, m: TickMetrics,
                        dt: jnp.ndarray) -> SummaryAcc:
    """Fold ``dt`` identical ticks' metrics into the accumulator at once.

    The telescoping engine's closed-form fold (docs/events.md): over a
    quiescent interval the per-tick metrics are constant by construction,
    so ``dt`` repeated :func:`acc_update` calls collapse to one weighted
    update — Kahan steps absorb ``dt * x`` in one compensation, the
    Welford pair takes Chan's merge of a group of ``dt`` equal values
    (within-group M2 is exactly 0), integer sums add ``dt * v`` (exact in
    i32 under the same ``max_chunk_ticks`` bound: the weighted total
    equals the repeated total), and peaks are idempotent under repeats.
    Integer sums/counts/peaks match the repeated folds bit-for-bit; the
    float sums and moments agree to ~1 ulp (tests/test_telescope.py).

    ``dt == 0`` is an exact no-op — every field keeps its old value
    bitwise (a Kahan step with x = 0 would still fold the compensation
    term into the sum), so the engine can call this unconditionally after
    an interval that telescoped zero ticks.
    """
    w = dt.astype(F32)
    su, cu = _kahan(acc.sum_util_var, acc.c_util_var, w * m.util_variance)
    sm, cm = _kahan(acc.sum_mean_util, acc.c_mean_util, w * m.mean_util)
    sf, cf = _kahan(acc.sum_flow_rate, acc.c_flow_rate, w * m.mean_flow_rate)
    ssc, csc = _kahan(acc.sum_soft_comm, acc.c_soft_comm, w * m.soft_comm)
    ssu, csu = _kahan(acc.sum_soft_util, acc.c_soft_util, w * m.soft_util)
    ssn, csn = _kahan(acc.sum_soft_n, acc.c_soft_n, w * m.soft_n)
    ssm, csm = _kahan(acc.sum_soft_mig, acc.c_soft_mig, w * m.soft_mig)
    ssmn, csmn = _kahan(acc.sum_soft_mig_n, acc.c_soft_mig_n,
                        w * m.soft_mig_n)
    n = acc.n_ticks + dt.astype(I32)
    nf = jnp.maximum(n.astype(F32), 1.0)
    delta = m.mean_util - acc.w_mean_util
    # ratio-first like online_merge: w/nf is exactly 1.0 on an empty acc,
    # so the first fold lands mean_util bitwise.
    w_mean = acc.w_mean_util + delta * (w / nf)
    w_m2 = acc.w_m2_util + delta * delta * (acc.n_ticks.astype(F32) * w / nf)
    new = SummaryAcc(
        n_ticks=n,
        sum_util_var=su, c_util_var=cu,
        sum_mean_util=sm, c_mean_util=cm,
        sum_flow_rate=sf, c_flow_rate=cf,
        w_mean_util=w_mean, w_m2_util=w_m2,
        sum_active_flows=(acc.sum_active_flows
                          + dt * m.active_flows.astype(I32)),
        sum_arrivals=acc.sum_arrivals + dt * m.new_arrivals.astype(I32),
        sum_decisions=acc.sum_decisions + dt * m.decisions.astype(I32),
        sum_migrations=acc.sum_migrations + dt * m.migrations.astype(I32),
        peak_running=jnp.maximum(acc.peak_running, m.n_running),
        peak_deployed=jnp.maximum(acc.peak_deployed, m.n_deployed),
        peak_overloaded=jnp.maximum(acc.peak_overloaded, m.n_overloaded),
        peak_inactive=jnp.maximum(acc.peak_inactive, m.n_inactive),
        sum_soft_comm=ssc, c_soft_comm=csc,
        sum_soft_util=ssu, c_soft_util=csu,
        sum_soft_n=ssn, c_soft_n=csn,
        sum_soft_mig=ssm, c_soft_mig=csm,
        sum_soft_mig_n=ssmn, c_soft_mig_n=csmn,
    )
    keep = dt > 0
    return jax.tree.map(lambda old, upd: jnp.where(keep, upd, old), acc, new)


def online_init(batch_shape: tuple = ()) -> OnlineSummary:
    """Empty host-side summary (f64/i64, optional leading batch axes).

    Every field gets its OWN buffer — the streaming sweep fills summaries
    slab-by-slab with in-place slice writes, so shared zero arrays would
    alias every integer (or float) field onto one buffer.
    """
    z_i = lambda: np.zeros(batch_shape, np.int64)
    z_f = lambda: np.zeros(batch_shape, np.float64)
    return OnlineSummary(
        n_ticks=z_i(), sum_util_var=z_f(), sum_mean_util=z_f(),
        sum_flow_rate=z_f(), w_mean_util=z_f(), w_m2_util=z_f(),
        sum_active_flows=z_i(), sum_arrivals=z_i(), sum_decisions=z_i(),
        sum_migrations=z_i(), peak_running=z_i(), peak_deployed=z_i(),
        peak_overloaded=z_i(), peak_inactive=z_i(),
        sum_soft_comm=z_f(), sum_soft_util=z_f(), sum_soft_n=z_f(),
        sum_soft_mig=z_f(), sum_soft_mig_n=z_f(),
    )


def online_fold(host: OnlineSummary, acc: SummaryAcc) -> OnlineSummary:
    """Fold one finished device chunk into the host summary.

    This is the ONLY place 64-bit arithmetic happens (satellite: the tick
    stays f32/i32 end to end).  A Kahan pair folds as ``f64(s) + f64(c)``
    — the compensation term recovers the low bits the f32 sum dropped —
    and the per-chunk Welford moments merge with Chan's parallel-combine
    rule.  Broadcasts over leading batch axes.
    """
    a = SummaryAcc(*(np.asarray(x) for x in acc))
    na = host.n_ticks.astype(np.float64)
    nb = a.n_ticks.astype(np.float64)
    n = na + nb
    safe_n = np.where(n > 0, n, 1.0)
    delta = a.w_mean_util.astype(np.float64) - host.w_mean_util
    w_mean = host.w_mean_util + delta * nb / safe_n
    w_m2 = (host.w_m2_util + a.w_m2_util.astype(np.float64)
            + delta * delta * na * nb / safe_n)
    f64 = lambda s, c: s.astype(np.float64) + c.astype(np.float64)
    i64 = lambda x: x.astype(np.int64)
    return OnlineSummary(
        n_ticks=host.n_ticks + i64(a.n_ticks),
        sum_util_var=host.sum_util_var + f64(a.sum_util_var, a.c_util_var),
        sum_mean_util=(host.sum_mean_util
                       + f64(a.sum_mean_util, a.c_mean_util)),
        sum_flow_rate=(host.sum_flow_rate
                       + f64(a.sum_flow_rate, a.c_flow_rate)),
        w_mean_util=w_mean, w_m2_util=w_m2,
        sum_active_flows=host.sum_active_flows + i64(a.sum_active_flows),
        sum_arrivals=host.sum_arrivals + i64(a.sum_arrivals),
        sum_decisions=host.sum_decisions + i64(a.sum_decisions),
        sum_migrations=host.sum_migrations + i64(a.sum_migrations),
        peak_running=np.maximum(host.peak_running, i64(a.peak_running)),
        peak_deployed=np.maximum(host.peak_deployed, i64(a.peak_deployed)),
        peak_overloaded=np.maximum(host.peak_overloaded,
                                   i64(a.peak_overloaded)),
        peak_inactive=np.maximum(host.peak_inactive, i64(a.peak_inactive)),
        sum_soft_comm=(host.sum_soft_comm
                       + f64(a.sum_soft_comm, a.c_soft_comm)),
        sum_soft_util=(host.sum_soft_util
                       + f64(a.sum_soft_util, a.c_soft_util)),
        sum_soft_n=host.sum_soft_n + f64(a.sum_soft_n, a.c_soft_n),
        sum_soft_mig=(host.sum_soft_mig
                      + f64(a.sum_soft_mig, a.c_soft_mig)),
        sum_soft_mig_n=(host.sum_soft_mig_n
                        + f64(a.sum_soft_mig_n, a.c_soft_mig_n)),
    )


def online_merge(a: OnlineSummary, b: OnlineSummary) -> OnlineSummary:
    """Merge two host-side summaries (both already f64/i64).

    The cross-host reduction of the distributed sweep
    (``repro.launch.dist``): each process folds its owned cells into a
    grid-shaped partial summary whose non-owned cells are all-zero
    (``online_init``), and the coordinator reduces the partials with this
    combine.  It is the same Chan parallel-combine rule as
    :func:`online_fold`, but over two finished summaries instead of a
    summary and a device chunk — associative, and EXACT on zero cells
    (``n_ticks == 0`` makes the Welford delta term collapse to the other
    side's value bit-for-bit, sums add 0.0, peaks max with 0), so merging
    disjoint-support partials reproduces the single-process summary
    bit-identically, in any merge order.  Broadcasts over leading batch
    axes.
    """
    na = a.n_ticks.astype(np.float64)
    nb = b.n_ticks.astype(np.float64)
    n = na + nb
    safe_n = np.where(n > 0, n, 1.0)
    delta = b.w_mean_util - a.w_mean_util
    # the ratios are formed FIRST: on empty sides nb/n is exactly 1.0
    # (na == 0) or 0.0 (nb == 0), so the delta term collapses bitwise.
    # Left-to-right (delta * nb) / n would round twice and break the
    # zero-partial identity (caught by test_sweep_dist).
    w_mean = a.w_mean_util + delta * (nb / safe_n)
    w_m2 = (a.w_m2_util + b.w_m2_util
            + delta * delta * (na * nb / safe_n))
    return OnlineSummary(
        n_ticks=a.n_ticks + b.n_ticks,
        sum_util_var=a.sum_util_var + b.sum_util_var,
        sum_mean_util=a.sum_mean_util + b.sum_mean_util,
        sum_flow_rate=a.sum_flow_rate + b.sum_flow_rate,
        w_mean_util=w_mean, w_m2_util=w_m2,
        sum_active_flows=a.sum_active_flows + b.sum_active_flows,
        sum_arrivals=a.sum_arrivals + b.sum_arrivals,
        sum_decisions=a.sum_decisions + b.sum_decisions,
        sum_migrations=a.sum_migrations + b.sum_migrations,
        peak_running=np.maximum(a.peak_running, b.peak_running),
        peak_deployed=np.maximum(a.peak_deployed, b.peak_deployed),
        peak_overloaded=np.maximum(a.peak_overloaded, b.peak_overloaded),
        peak_inactive=np.maximum(a.peak_inactive, b.peak_inactive),
        sum_soft_comm=a.sum_soft_comm + b.sum_soft_comm,
        sum_soft_util=a.sum_soft_util + b.sum_soft_util,
        sum_soft_n=a.sum_soft_n + b.sum_soft_n,
        sum_soft_mig=a.sum_soft_mig + b.sum_soft_mig,
        sum_soft_mig_n=a.sum_soft_mig_n + b.sum_soft_mig_n,
    )


def online_from_metrics(metrics: TickMetrics) -> OnlineSummary:
    """The stacked-path twin: the same summary computed from a full
    [..., T] ``TickMetrics`` series in f64.

    ``report.summarize`` routes BOTH paths through this shape, so stacked
    and streaming runs report identical keys — integer sums/peaks agree
    bit-for-bit with the chunked fold, float sums to ~1 ulp of f32.
    """
    f = lambda x: np.asarray(x, np.float64)
    i = lambda x: np.asarray(x).astype(np.int64)
    mu = f(metrics.mean_util)
    n = np.full(mu.shape[:-1], mu.shape[-1], np.int64)
    w_mean = mu.mean(axis=-1) if mu.shape[-1] else np.zeros(mu.shape[:-1])
    w_m2 = ((mu - w_mean[..., None]) ** 2).sum(axis=-1)
    return OnlineSummary(
        n_ticks=n,
        sum_util_var=f(metrics.util_variance).sum(axis=-1),
        sum_mean_util=mu.sum(axis=-1),
        sum_flow_rate=f(metrics.mean_flow_rate).sum(axis=-1),
        w_mean_util=w_mean, w_m2_util=w_m2,
        sum_active_flows=i(metrics.active_flows).sum(axis=-1),
        sum_arrivals=i(metrics.new_arrivals).sum(axis=-1),
        sum_decisions=i(metrics.decisions).sum(axis=-1),
        sum_migrations=i(metrics.migrations).sum(axis=-1),
        peak_running=i(metrics.n_running).max(axis=-1),
        peak_deployed=i(metrics.n_deployed).max(axis=-1),
        peak_overloaded=i(metrics.n_overloaded).max(axis=-1),
        peak_inactive=i(metrics.n_inactive).max(axis=-1),
        sum_soft_comm=f(metrics.soft_comm).sum(axis=-1),
        sum_soft_util=f(metrics.soft_util).sum(axis=-1),
        sum_soft_n=f(metrics.soft_n).sum(axis=-1),
        sum_soft_mig=f(metrics.soft_mig).sum(axis=-1),
        sum_soft_mig_n=f(metrics.soft_mig_n).sum(axis=-1),
    )


# ---------------------------------------------------------------------------
# Differentiable surrogate objectives (SimConfig.soft_placement)
# ---------------------------------------------------------------------------
# name -> which surrogate sums form the mean.  'soft_blend' mixes the
# comm- and util-expectation columns: a single-column objective is
# invariant to scaling ITS one weight (softmax over a rescaled row moves,
# but for the disjoint-support legacy vectors the hard argmin does not),
# so the blend is the default the grad tuner descends.  Lower = better.
SOFT_OBJECTIVES: tuple = ("soft_blend", "soft_comm", "soft_util",
                          "soft_mig_util")


def soft_num_den(m, objective: str = "soft_blend"):
    """(numerator, denominator) of a named surrogate objective.

    ``m`` may be stacked ``TickMetrics`` (trailing time axis, summed
    here), a ``SummaryAcc`` (in-jit streaming carry — the Kahan pair is
    collapsed as ``sum + c``, matching ``online_fold``'s recovery), or a
    host-side ``OnlineSummary``.  Stays inside jit and is differentiable
    end to end — this is the reduction ``jax.grad`` flows through.
    """
    if objective not in SOFT_OBJECTIVES:
        raise KeyError(f"unknown soft objective {objective!r}; known: "
                       f"{list(SOFT_OBJECTIVES)}")
    if isinstance(m, SummaryAcc):
        comm = m.sum_soft_comm + m.c_soft_comm
        util = m.sum_soft_util + m.c_soft_util
        n = m.sum_soft_n + m.c_soft_n
        mig = m.sum_soft_mig + m.c_soft_mig
        mig_n = m.sum_soft_mig_n + m.c_soft_mig_n
    elif isinstance(m, OnlineSummary):
        comm, util, n = m.sum_soft_comm, m.sum_soft_util, m.sum_soft_n
        mig, mig_n = m.sum_soft_mig, m.sum_soft_mig_n
    elif isinstance(m, TickMetrics):
        comm = m.soft_comm.sum(axis=-1)
        util = m.soft_util.sum(axis=-1)
        n = m.soft_n.sum(axis=-1)
        mig = m.soft_mig.sum(axis=-1)
        mig_n = m.soft_mig_n.sum(axis=-1)
    else:
        raise TypeError(f"expected TickMetrics, SummaryAcc or "
                        f"OnlineSummary, got {type(m).__name__}")
    if objective == "soft_comm":
        return comm, n
    if objective == "soft_util":
        return util, n
    if objective == "soft_mig_util":
        return mig, mig_n
    return comm + util, n


def soft_objective(m, objective: str = "soft_blend"):
    """Mean surrogate cost (lower = better): numerator / max(count, 1).

    The count denominator comes from non-differentiable feasibility
    decisions, so it is piecewise-constant in the weights — the gradient
    is the exact gradient of the numerator scaled by it.
    """
    num, den = soft_num_den(m, objective)
    return num / jnp.maximum(den, 1.0)
