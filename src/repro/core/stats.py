"""Data collection module (paper §3.7): per-tick metric extraction.

The paper's ``Stat`` class samples host/container/network state once per
second (``save_stats`` process).  Here each tick's metrics are emitted as the
``ys`` of the engine's ``lax.scan``, so the full time series materializes as
stacked arrays with zero Python overhead.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import (
    STATUS_COMMUNICATING, STATUS_COMPLETED, STATUS_INACTIVE, STATUS_MIGRATING,
    STATUS_RUNNING, STATUS_WAITING, RunParams, SimState, TickMetrics,
)


def collect(sim: SimState, new_arrivals: jnp.ndarray, decisions: jnp.ndarray,
            migrations: jnp.ndarray, params: RunParams,
            flow_active: jnp.ndarray, flow_rates: jnp.ndarray) -> TickMetrics:
    """Per-tick metrics; ``params`` carries the (traced, sweepable)
    overload threshold the ``n_overloaded`` count is judged against.

    Pure gathers and reductions — no scatters, so the whole collection
    phase batches cleanly when the sweep vmaps the tick.  All lifecycle
    counts come from ONE [C, 6] comparison pass instead of six [C] sweeps.
    """
    st = sim.containers.status
    util = sim.hosts.used / jnp.maximum(sim.hosts.cap, 1e-6)      # [H, 3]
    worst = util.max(axis=1)
    mean_util = util.mean(axis=1)                                 # per-host
    n_active_flows = flow_active.sum()
    mean_rate = jnp.where(
        n_active_flows > 0,
        (flow_rates * flow_active).sum() / jnp.maximum(n_active_flows, 1),
        0.0)
    codes = (STATUS_INACTIVE, STATUS_RUNNING, STATUS_COMMUNICATING,
             STATUS_MIGRATING, STATUS_WAITING, STATUS_COMPLETED)
    counts = (st[:, None] == jnp.array(codes)[None, :]).sum(axis=0)
    count = dict(zip(codes, counts)).__getitem__
    return TickMetrics(
        t=sim.t,
        n_overloaded=(worst > params.overload_threshold).sum(),
        n_inactive=count(STATUS_INACTIVE) + count(STATUS_WAITING),
        n_running=count(STATUS_RUNNING),
        n_deployed=(count(STATUS_RUNNING) + count(STATUS_COMMUNICATING)
                    + count(STATUS_MIGRATING)),
        n_communicating=count(STATUS_COMMUNICATING),
        n_waiting=count(STATUS_WAITING),
        n_completed=count(STATUS_COMPLETED),
        n_migrating=count(STATUS_MIGRATING),
        new_arrivals=new_arrivals,
        decisions=decisions,
        migrations=migrations,
        util_variance=jnp.var(mean_util),
        mean_util=mean_util.mean(),
        active_flows=n_active_flows,
        mean_flow_rate=mean_rate,
    )
