"""Sharded checkpointing with cross-mesh elastic resharding.

Layout: one ``.npz`` shard per host process + a msgpack-free JSON manifest
(no external deps).  Each leaf is saved as the set of *global* array chunks
this process owns (device_buffers -> global slices); restore reassembles
whatever chunk layout the *new* mesh needs, so a checkpoint written on a
2-pod mesh restores onto a 1-pod mesh (elastic scale-down) and vice versa.

On this single-process CPU container every save degenerates to one shard,
but the chunk/manifest format is the real multi-host one.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(path: str, state: Any, step: int,
                    process_index: int = 0) -> None:
    """Write this process's chunks + (process 0) the manifest."""
    os.makedirs(path, exist_ok=True)
    chunks: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": int(step), "leaves": {}}

    for key, leaf in _flatten(state):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype)}
        chunks[key] = arr

    np.savez(os.path.join(path, f"shard_{process_index}.npz"), **chunks)
    if process_index == 0:
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(manifest, f)


def restore_checkpoint(path: str, state_like: Any,
                       shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``state_like``; reshard onto
    ``shardings`` (tree of NamedSharding) if given — the new mesh may have
    a different topology than the one that wrote the checkpoint."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    data: Dict[str, np.ndarray] = {}
    i = 0
    while os.path.exists(os.path.join(path, f"shard_{i}.npz")):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            for k in z.files:
                data[k] = z[k]
        i += 1

    flat_like = _flatten(state_like)
    flat_shard = _flatten(shardings) if shardings is not None else None
    leaves = []
    for idx, (key, like) in enumerate(flat_like):
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        want = tuple(like.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {want}")
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[idx][1])
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state_like), leaves)
    return tree, manifest["step"]


def latest_step_dir(root: str) -> str | None:
    if not os.path.isdir(root):
        return None
    steps = [d for d in os.listdir(root) if d.startswith("step_")]
    if not steps:
        return None
    return os.path.join(root, max(steps, key=lambda d: int(d.split("_")[1])))
