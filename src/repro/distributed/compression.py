"""int8 error-feedback gradient compression for the cross-pod all-reduce.

Intra-pod reduction stays full precision (ICI is cheap); the pod axis
crosses the DCN, where 4x byte reduction matters.  Error feedback keeps the
quantization residual locally and adds it to the next step's gradient, so
the compressed SGD trajectory tracks the exact one (Karimireddy et al.).

Implemented in shard_map: per-leaf blockwise absmax int8 quantize ->
psum over 'pod' -> dequantize -> add residual correction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import SM_NOCHECK as _SM_NOCHECK, shard_map


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor absmax int8.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-compressed mean over ``axis_name`` (inside shard_map)."""
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(x)
    # sum of int8 payloads (int32 accumulator) + per-member scales
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                         axis_name)
    return total / n


def pod_compressed_mean(grads: Any, mesh) -> Any:
    """Mean gradients across the pod axis with int8 EF payloads.

    Gradients arrive already correct within a pod (XLA inserted intra-pod
    reductions from the param shardings); this replaces the *cross-pod*
    mean.  Leaves keep their (data/model) shardings — only 'pod' is
    reduced.
    """
    if "pod" not in mesh.axis_names:
        return grads

    def leaf_mean(g):
        spec_dims = [None] * g.ndim
        in_spec = P(*spec_dims)     # replicated over pod: psum semantics

        def body(gl):
            return compressed_psum_mean(gl, "pod")

        return shard_map(body, mesh=mesh, in_specs=in_spec,
                         out_specs=in_spec, **_SM_NOCHECK)(g)

    return jax.tree.map(leaf_mean, grads)


class ErrorFeedback:
    """Residual-carrying wrapper: grads' = Q(grads + residual);
    residual' = (grads + residual) - grads'."""

    @staticmethod
    def init(grads_like: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads_like)

    @staticmethod
    def apply(grads: Any, residual: Any) -> Tuple[Any, Any]:
        def leaf(g, r):
            corrected = g.astype(jnp.float32) + r
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return deq.astype(g.dtype), corrected - deq

        pairs = jax.tree.map(leaf, grads, residual)
        new_grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_resid = jax.tree.map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_resid
