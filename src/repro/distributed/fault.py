"""Fault tolerance: heartbeat failure detection, straggler deadlines, and
the recovery policy that drives checkpoint/restart + elastic resharding.

On real multi-host TPU deployments the heartbeats are per-host processes
writing to a shared store; here the monitor is in-process but the state
machine (suspect -> dead -> recover), the straggler deadline logic and the
elastic re-mesh decision are the production logic, unit-tested in
tests/test_distributed.py and driven by launch/train.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

HEALTHY, SUSPECT, DEAD = "healthy", "suspect", "dead"


@dataclasses.dataclass
class FaultConfig:
    heartbeat_interval_s: float = 10.0
    suspect_after_s: float = 30.0      # missed heartbeats -> suspect
    dead_after_s: float = 120.0        # -> declared dead, trigger recovery
    straggler_factor: float = 2.0      # step slower than median x factor
    straggler_window: int = 20         # steps in the rolling median
    max_restarts: int = 100


class HeartbeatMonitor:
    """Tracks last-seen timestamps per worker; classifies liveness."""

    def __init__(self, workers: List[str], cfg: FaultConfig,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.last_seen: Dict[str, float] = {w: clock() for w in workers}

    def beat(self, worker: str, t: Optional[float] = None) -> None:
        self.last_seen[worker] = self.clock() if t is None else t

    def status(self, worker: str) -> str:
        dt = self.clock() - self.last_seen[worker]
        if dt >= self.cfg.dead_after_s:
            return DEAD
        if dt >= self.cfg.suspect_after_s:
            return SUSPECT
        return HEALTHY

    def dead_workers(self) -> List[str]:
        return [w for w in self.last_seen if self.status(w) == DEAD]

    def all_healthy(self) -> bool:
        return all(self.status(w) == HEALTHY for w in self.last_seen)


class StragglerDetector:
    """Rolling-median step-time deadline; flags chronically slow workers.

    The launcher treats a flagged worker like a soft failure: its shards
    are re-balanced at the next checkpoint boundary rather than stalling
    every step on the slowest participant.
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.history: Dict[str, List[float]] = {}

    def record(self, worker: str, step_time_s: float) -> None:
        h = self.history.setdefault(worker, [])
        h.append(step_time_s)
        if len(h) > self.cfg.straggler_window:
            h.pop(0)

    def median_step(self) -> float:
        all_t = sorted(t for h in self.history.values() for t in h)
        return all_t[len(all_t) // 2] if all_t else 0.0

    def stragglers(self) -> List[str]:
        med = self.median_step()
        if med <= 0:
            return []
        out = []
        for w, h in self.history.items():
            if len(h) >= 3:
                recent = sorted(h[-5:])[len(h[-5:]) // 2]
                if recent > med * self.cfg.straggler_factor:
                    out.append(w)
        return out


@dataclasses.dataclass
class RecoveryPlan:
    action: str                  # 'none' | 'restart' | 'elastic_downsize'
    reason: str = ""
    lost_workers: tuple = ()
    new_multi_pod: Optional[bool] = None


def plan_recovery(monitor: HeartbeatMonitor, n_pods: int,
                  workers_per_pod: int) -> RecoveryPlan:
    """Decide how to continue after failures.

    * all healthy              -> none
    * losses within spare set  -> restart from checkpoint on same mesh
    * a whole pod unreachable  -> elastic downsize (restore the same
      checkpoint onto the single-pod mesh; sharding specs are divisibility-
      checked so the same code path compiles on the smaller mesh)
    """
    dead = monitor.dead_workers()
    if not dead:
        return RecoveryPlan("none")
    pods_hit = {w.split(":")[0] for w in dead}
    for pod in pods_hit:
        pod_dead = sum(1 for w in dead if w.startswith(pod + ":"))
        if pod_dead >= workers_per_pod:
            return RecoveryPlan(
                "elastic_downsize",
                reason=f"pod {pod} lost ({pod_dead}/{workers_per_pod})",
                lost_workers=tuple(dead), new_multi_pod=False)
    return RecoveryPlan("restart", reason=f"{len(dead)} workers dead",
                        lost_workers=tuple(dead))


class TrainingSupervisor:
    """Glue used by launch/train.py: step loop + checkpoint cadence +
    recovery hooks.  Deterministic data pipeline (per-step index seeding)
    makes post-restore replay exact."""

    def __init__(self, cfg: FaultConfig, ckpt_every: int,
                 save_fn: Callable[[int], None],
                 restore_fn: Callable[[], int]):
        self.cfg = cfg
        self.ckpt_every = ckpt_every
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.restarts = 0

    def maybe_checkpoint(self, step: int) -> bool:
        if step > 0 and step % self.ckpt_every == 0:
            self.save_fn(step)
            return True
        return False

    def recover(self) -> int:
        if self.restarts >= self.cfg.max_restarts:
            raise RuntimeError("restart budget exhausted")
        self.restarts += 1
        return self.restore_fn()
