"""AdamW + cosine schedule + global-norm clipping, sharded like the params.

No optax dependency — the update is ~40 lines and having it explicit lets
the dry-run's memory analysis account for every optimizer byte (m, v in f32,
sharded with the same PartitionSpecs as their parameters).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(lambda z: z.copy(), zeros),
                    step=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: OptimizerConfig, params: Any, grads: Any,
                 state: OptState) -> Tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(F32)
        return (p - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {
        "lr": lr, "grad_norm": gnorm}
