"""Chunked cross-entropy: never materializes the [B, S, V] f32 logits.

The sequence axis is scanned in ``logit_chunk`` slices; each chunk computes
bf16 logits against the (vocab-padded, model-axis-sharded) unembedding,
masks padded vocab entries, and reduces log-probs in f32.  Label -1 marks
ignored positions.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import BF16, F32


def chunked_cross_entropy(hidden: jnp.ndarray, unembed: jnp.ndarray,
                          labels: jnp.ndarray, vocab_real: int,
                          chunk: int = 512, unroll: bool = False
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """hidden [B,S,d], unembed [d,Vp], labels [B,S] -> (sum_nll, n_valid).

    ``unroll`` replaces the chunk lax.scan with a python loop (cost-exact
    HLO for the roofline pass; see transformer._scan_or_unroll).
    """
    B, S, d = hidden.shape
    Vp = unembed.shape[1]
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def chunk_loss(h_c, l_c):
        logits = (h_c.astype(BF16) @ unembed.astype(BF16)).astype(F32)
        if vocab_real < Vp:
            pad_mask = jnp.arange(Vp) < vocab_real
            logits = jnp.where(pad_mask[None, None], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(l_c, 0, Vp - 1)[..., None], axis=-1)[..., 0]
        valid = (l_c >= 0).astype(F32)
        return ((lse - ll) * valid).sum(), valid.sum()

    if n_chunks > 0:
        h_main = hidden[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, d)
        l_main = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)

        if unroll:
            nll = n = jnp.zeros((), F32)
            for i in range(n_chunks):
                nll_i, n_i = chunk_loss(h_main[:, i], l_main[:, i])
                nll, n = nll + nll_i, n + n_i
        else:
            def body(carry, xs):
                h_c, l_c = xs
                nll, n = chunk_loss(h_c, l_c)
                return (carry[0] + nll, carry[1] + n), None

            (nll, n), _ = jax.lax.scan(
                body, (jnp.zeros((), F32), jnp.zeros((), F32)),
                (jnp.moveaxis(h_main, 1, 0), jnp.moveaxis(l_main, 1, 0)))
    else:
        nll = n = jnp.zeros((), F32)
    if rem:
        nll_r, n_r = chunk_loss(hidden[:, -rem:], labels[:, -rem:])
        nll, n = nll + nll_r, n + n_r
    return nll, n


def lm_loss(hidden, unembed, labels, vocab_real, chunk=512,
            aux=None, aux_weight: float = 0.01, unroll: bool = False):
    nll, n = chunked_cross_entropy(hidden, unembed, labels, vocab_real,
                                   chunk, unroll=unroll)
    loss = nll / jnp.maximum(n, 1.0)
    if aux is not None:
        loss = loss + aux_weight * aux
    return loss, {"nll": nll, "n_tokens": n, "ce": nll / jnp.maximum(n, 1.0)}
