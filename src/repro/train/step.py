"""train_step builder: loss -> grads -> (optional int8 EF compression on the
cross-pod axis) -> AdamW, with optional microbatch gradient accumulation.

The returned step is pure; ``repro/launch/train.py`` jits it with
in/out shardings from ``models/sharding.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import loss as loss_mod
from repro.train import optimizer as opt_mod

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.OptState


@dataclasses.dataclass(frozen=True)
class StepConfig:
    n_microbatches: int = 1
    aux_weight: float = 0.01
    compress_pod_grads: bool = False   # int8 error-feedback on the pod axis


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = transformer.init_params(cfg, key)
    return TrainState(params=params, opt=opt_mod.init_opt_state(params))


def make_loss_fn(cfg: ModelConfig, mesh=None, dp: tuple = ("data",),
                 aux_weight: float = 0.01) -> Callable:
    def loss_fn(params, batch):
        hidden, aux = transformer.forward_train(cfg, params, batch,
                                                mesh=mesh, dp=dp)
        labels = batch["labels"]
        if cfg.frontend == "patch_embeds":
            # loss only on text positions (prefix = image patches)
            hidden = hidden[:, cfg.n_prefix:]
        return loss_mod.lm_loss(hidden, params["unembed"], labels,
                                cfg.vocab, cfg.logit_chunk,
                                aux=aux, aux_weight=aux_weight,
                                unroll=not cfg.scan_layers)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: opt_mod.OptimizerConfig,
                    step_cfg: StepConfig = StepConfig(), mesh=None,
                    dp: tuple = ("data",)) -> Callable:
    loss_fn = make_loss_fn(cfg, mesh=mesh, dp=dp,
                           aux_weight=step_cfg.aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if step_cfg.n_microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        n = step_cfg.n_microbatches
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(F32), acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (acc, loss_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), F32)), micro)
        grads = jax.tree.map(lambda a: a / n, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, Any]
                   ) -> Tuple[TrainState, Dict[str, Any]]:
        loss, metrics, grads = compute_grads(state.params, batch)
        if step_cfg.compress_pod_grads and mesh is not None \
                and "pod" in mesh.axis_names:
            from repro.distributed import compression
            grads = compression.pod_compressed_mean(grads, mesh)
        params, opt, opt_metrics = opt_mod.adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params, opt), metrics

    return train_step
