"""Mamba2 SSD chunk scan — Pallas TPU kernel.

State-space duality splits the sequence into chunks of Q steps:

  intra-chunk (quadratic, MXU):  Y_q += sum_{s<=q} (C_q.B_s) e^{L_q-L_s} dt_s X_s
  inter-chunk (recurrence):      h   <- e^{L_Q} h + sum_s e^{L_Q-L_s} dt_s B_s (x) X_s
                                 Y_q += e^{L_q} C_q h_prev

Grid layout: (batch, head, chunk) with the chunk axis iterated sequentially
("arbitrary" semantics) so the [P, N] SSM state lives in a VMEM scratch
that carries across chunk steps — the TPU analogue of the paper's
chunk-parallel GPU kernel, but with the recurrence kept on-core instead of
a separate inter-block pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssd_kernel(xs_ref, b_ref, c_ref, dt_ref, alog_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, Q):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0]                    # [P, N]

    xs = xs_ref[0, 0].astype(jnp.float32)            # [Q, P]
    Bm = b_ref[0].astype(jnp.float32)                # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                # [Q, N]
    dt = dt_ref[0, 0].astype(jnp.float32)            # [Q, 1]
    A = -jnp.exp(alog_ref[0, 0])                     # scalar (per head)

    a_log = A * dt                                   # [Q, 1] (<= 0)
    cum = jnp.cumsum(a_log, axis=0)                  # [Q, 1]

    # ---- intra-chunk quadratic term (MXU) ---------------------------------
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    decay = cum - cum.T                              # cum[q] - cum[s]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(qi >= si, jnp.exp(decay), 0.0)
    M = G * L * dt.T                                 # [Q, Q] (dt_s on cols)
    y = jax.lax.dot_general(M, xs, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, P]

    # ---- inter-chunk: contribution of the carried state -------------------
    h = h_ref[...]                                   # [P, N]
    y += jnp.exp(cum) * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q, P]

    # ---- state update ------------------------------------------------------
    total = cum[Q - 1]                               # [1]
    w = jnp.exp(total[None, :] - cum) * dt           # [Q, 1]
    upd = jax.lax.dot_general(xs, w * Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    h_ref[...] = jnp.exp(total)[0] * h + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("Q", "interpret"))
def ssd_chunked_kernel(xs, Bm, Cm, dt, A_log, Q: int = 256, h0=None,
                       interpret: bool = True):
    """xs [B,S,H,P], Bm/Cm [B,S,N], dt [B,S,H], A_log [H]
    -> (y [B,S,H,P], h_final [B,H,P,N])."""
    B, S, H, P = xs.shape
    N = Bm.shape[-1]
    Q = min(Q, S)
    assert S % Q == 0
    nc = S // Q

    xs_t = xs.transpose(0, 2, 1, 3)                  # [B,H,S,P]
    dt_t = dt.transpose(0, 2, 1)[..., None]          # [B,H,S,1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    grid = (B, H, nc)
    y, h_fin = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), xs.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret, name="ssd_scan",
    )(xs_t, Bm, Cm, dt_t, A_log.reshape(H, 1), h0)
    return y.transpose(0, 2, 1, 3), h_fin
