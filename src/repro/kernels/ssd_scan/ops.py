"""Public SSD-scan op with custom VJP (bwd = jnp reference recompute)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ref import ssd_chunked_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_chunked_kernel


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd(xs, Bm, Cm, dt, A_log, Q):
    interpret = jax.default_backend() != "tpu"
    return ssd_chunked_kernel(xs, Bm, Cm, dt, A_log, Q=Q,
                              interpret=interpret)


def _fwd(xs, Bm, Cm, dt, A_log, Q):
    return _ssd(xs, Bm, Cm, dt, A_log, Q), (xs, Bm, Cm, dt, A_log)


def _bwd(Q, res, g):
    xs, Bm, Cm, dt, A_log = res
    _, vjp = jax.vjp(
        lambda xs, Bm, Cm, dt, A_log: ssd_chunked_ref(xs, Bm, Cm, dt,
                                                      A_log, Q),
        xs, Bm, Cm, dt, A_log)
    return vjp(g)


_ssd.defvjp(_fwd, _bwd)


def ssd_chunked(xs, Bm, Cm, dt, A_log, Q: int = 256, h0=None):
    """Kernel-backed SSD.  h0 (decode prefill chaining) falls back to the
    reference path — the kernel entry is the h0=None training hot path."""
    if h0 is not None:
        return ssd_chunked_ref(xs, Bm, Cm, dt, A_log, Q, h0=h0)
    return _ssd(xs, Bm, Cm, dt, A_log, Q)
