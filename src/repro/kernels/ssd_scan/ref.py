"""Oracle for the SSD chunk kernel = the model's chunked reference."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked_ref  # noqa: F401
