"""Fused ECMP waterfilling + Mathis cap — Pallas kernel.

The sparse flow engine's hottest loop (`network.max_min_fair_rates_sparse`)
runs ``n_rounds`` progressive-filling rounds, each of which is a chain of
XLA ops with HBM round-trips between them:

    gather [F,4] link ids -> segment_sum unfrozen counts onto [E]
      -> fair share per link -> per-flow bound (min over <= 4 links)
      -> global min -> freeze mask -> alloc update
      -> segment_sum newly-allocated load -> capacity update

This kernel fuses the WHOLE allocation — all rounds, the leftover-flow
tail, the Mathis TCP cap, and the final per-link load — into one
``pallas_call``: every array ([F,4] link ids, [F] flow state, [E] link
state) is VMEM-resident for the duration, and the only HBM traffic is one
read of the inputs and one write of (rates [F], load [E]).

TPU adaptation of the two segment reductions (scatter-add and
gather-then-min have no vectorized Mosaic lowering):

* ``per-link sum``  sum_f w[f] * [link(f) == e]  — blocked one-hot
  contraction: for each (flow-block, link-block) tile, compare the [bf]
  flattened link ids against the [be] link-id range (a [bf, be] one-hot
  tile that never leaves registers/VMEM) and reduce over flows.  This is
  the standard MXU-friendly segment_sum formulation; cost O(F*4*E/8)
  ops/round instead of a serialized scatter.
* ``per-flow bound``  min over a flow's <= 4 links of share[link] — the
  SAME tiling with a min-reduce over the link axis instead of a
  sum-reduce over the flow axis.

Numerics: counts are exact (sums of {0,1}); float sums (used capacity,
link load) are tree-reduced per tile instead of scatter-order — a
documented ~1 ulp association difference vs `jax.ops.segment_sum`
(docs/kernels.md), which is why the engine keeps the jnp path as the
default-on-CPU oracle rather than asserting bit-equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
I32 = jnp.int32


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _waterfill_kernel(links_ref, active_ref, cap_ref, tcp_ref,
                      rates_ref, load_ref, *,
                      n_rounds: int, n_links: int, bf: int, be: int,
                      local_rate: float, inf: float):
    """Single-invocation kernel: all refs whole-array VMEM resident.

    ``links`` [F, 4] i32 (pad slots -1), ``active`` [F] i32 mask,
    ``cap`` [E] f32 link capacity (KB/s), ``tcp`` [F] f32 Mathis ceiling.
    Outputs: ``rates`` [F] f32, ``load`` [E] f32 per-link allocated KB/s.
    """
    links = links_ref[...]
    active = active_ref[...] != 0
    cap0 = cap_ref[...]
    tcp = tcp_ref[...]
    F = links.shape[0]
    E = n_links
    F4 = F * 4

    # flattened tiling frame: pad the flow axis to a block multiple and the
    # link axis to a block multiple; invalid/pad slots point at E_pad (one
    # past every link block, so they never match a one-hot tile)
    F4p = _ceil_to(F4, bf)
    Ep = _ceil_to(E, be)
    nb_f = F4p // bf
    nb_e = Ep // be

    valid = (links >= 0) & active[:, None]                     # [F, 4]
    w_valid = valid.astype(F32).reshape(F4)
    lid = jnp.where(valid, links, Ep).reshape(F4)
    pad_f = F4p - F4
    if pad_f:
        w_valid = jnp.concatenate([w_valid, jnp.zeros((pad_f,), F32)])
        lid = jnp.concatenate([lid, jnp.full((pad_f,), Ep, I32)])
    cap_p = jnp.concatenate([cap0, jnp.zeros((Ep - E,), F32)]) \
        if Ep != E else cap0

    iota_e = jax.lax.broadcasted_iota(I32, (1, be), 1)          # [1, be]

    def per_link_sum(per_flow):
        """[F] flow weights -> [Ep] per-link sums (blocked one-hot)."""
        w = (jnp.broadcast_to(per_flow[:, None], (F, 4))
             .reshape(F4).astype(F32))
        if pad_f:
            w = jnp.concatenate([w, jnp.zeros((pad_f,), F32)])
        w = w * w_valid

        def ebody(eb, acc):
            ids = eb * be + iota_e                              # [1, be]

            def fbody(fb, part):
                l_blk = jax.lax.dynamic_slice(lid, (fb * bf,), (bf,))
                w_blk = jax.lax.dynamic_slice(w, (fb * bf,), (bf,))
                oh = l_blk[:, None] == ids                      # [bf, be]
                return part + jnp.where(oh, w_blk[:, None], 0.0).sum(0)

            part = jax.lax.fori_loop(0, nb_f, fbody,
                                     jnp.zeros((be,), F32))
            return jax.lax.dynamic_update_slice(acc, part, (eb * be,))

        return jax.lax.fori_loop(0, nb_e, ebody, jnp.zeros((Ep,), F32))

    def fair_bound(unfrozen, cap_rem):
        """Per-flow fair-share bound: min over its valid links of
        cap_rem[e] / count[e] (INF for flows with no valid link)."""
        cnt = per_link_sum(unfrozen.astype(F32))
        share = jnp.where(cnt > 0, cap_rem / jnp.maximum(cnt, 1.0), inf)

        def fbody(fb, bnd):
            l_blk = jax.lax.dynamic_slice(lid, (fb * bf,), (bf,))
            v_blk = jax.lax.dynamic_slice(w_valid, (fb * bf,), (bf,)) > 0

            def ebody(eb, b_blk):
                ids = eb * be + iota_e
                sh = jax.lax.dynamic_slice(share, (eb * be,), (be,))
                oh = (l_blk[:, None] == ids) & v_blk[:, None]
                cand = jnp.where(oh, sh[None, :], inf).min(1)   # [bf]
                return jnp.minimum(b_blk, cand)

            b_blk = jax.lax.fori_loop(0, nb_e, ebody,
                                      jnp.full((bf,), inf, F32))
            return jax.lax.dynamic_update_slice(bnd, b_blk, (fb * bf,))

        b4 = jax.lax.fori_loop(0, nb_f, fbody, jnp.full((F4p,), inf, F32))
        return b4[:F4].reshape(F, 4).min(1)                     # [F]

    # --- progressive filling, identical round structure to the jnp ref ---
    alloc0 = jnp.where(active, local_rate, 0.0)
    frozen0 = active & ~valid.any(1)          # no-link flows: local rate

    def round_body(_, carry):
        alloc, frozen, cap_rem = carry
        unfrozen = active & ~frozen
        bound = jnp.where(unfrozen, fair_bound(unfrozen, cap_rem), inf)
        m = bound.min()
        newly = unfrozen & (bound <= m * 1.000001 + 1e-6)
        new_alloc = jnp.where(newly, jnp.minimum(bound, local_rate), alloc)
        used = per_link_sum(jnp.where(newly, new_alloc, 0.0))
        return (new_alloc, frozen | newly,
                jnp.maximum(cap_rem - used, 0.0))

    alloc, frozen, cap_rem = jax.lax.fori_loop(
        0, n_rounds, round_body, (alloc0, frozen0, cap_p))

    # leftover tail (more bottleneck levels than rounds): current fair share
    leftover = active & ~frozen
    tail = jnp.minimum(fair_bound(leftover, cap_rem), local_rate)
    alloc = jnp.where(leftover, tail, alloc)
    fair = jnp.where(active, alloc, 0.0)

    # fused Mathis arm + final link load
    rates = jnp.minimum(fair, tcp) * active
    rates_ref[...] = rates
    load_ref[...] = per_link_sum(rates)[:E]


@functools.partial(jax.jit, static_argnames=("n_rounds", "bf", "be",
                                             "interpret", "local_rate",
                                             "inf"))
def seg_waterfill(links: jnp.ndarray, active: jnp.ndarray,
                  link_bw_kbps: jnp.ndarray, tcp_cap: jnp.ndarray,
                  n_rounds: int = 8, bf: int = 2048, be: int = 256,
                  interpret: bool = True, local_rate: float = 4.0e6,
                  inf: float = 1e9):
    """Fused max-min-fair + Mathis allocation.  Returns (rates [F], load [E]).

    ``links`` [F, 4] i32 ECMP link ids (-1 padded), ``active`` [F] bool/i32,
    ``link_bw_kbps`` [E] f32, ``tcp_cap`` [F] f32 per-flow Mathis ceiling
    (use ``inf`` for loss-free paths).  ``bf``/``be`` tile the flattened
    flow-slot and link axes; [bf, be] is the one-hot working tile.
    """
    F = links.shape[0]
    E = link_bw_kbps.shape[0]
    bf = min(bf, _ceil_to(F * 4, 8))
    be = min(be, _ceil_to(E, 8))
    kernel = functools.partial(
        _waterfill_kernel, n_rounds=n_rounds, n_links=E, bf=bf, be=be,
        local_rate=local_rate, inf=inf)
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((F,), jnp.float32),
                   jax.ShapeDtypeStruct((E,), jnp.float32)),
        interpret=interpret, name="seg_waterfill",
    )(links.astype(I32), active.astype(I32),
      link_bw_kbps.astype(F32), tcp_cap.astype(F32))
