"""Pure-jnp oracle for the fused waterfilling kernel.

Composes the engine's own sparse allocator (`network.max_min_fair_rates_sparse`
— the production default on CPU) with the Mathis min and the per-link load
``segment_sum``, i.e. exactly the op chain `network.flow_rates(sparse=True)`
runs when the kernel is off.  Single source of truth: the oracle IS the
engine path, so kernel-vs-oracle tests pin the kernel to production
semantics, not to a reimplementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import network


def seg_waterfill_ref(links: jnp.ndarray, active: jnp.ndarray,
                      link_bw_kbps: jnp.ndarray, tcp_cap: jnp.ndarray,
                      n_rounds: int = 8):
    """(rates [F], load [E]) from [F,4] link ids — the unfused op chain."""
    E = link_bw_kbps.shape[0]
    active = active.astype(bool)
    fair = network.max_min_fair_rates_sparse(links, active, link_bw_kbps,
                                             n_rounds=n_rounds)
    rates = jnp.minimum(fair, tcp_cap) * active
    valid = links >= 0
    seg = jnp.where(valid, links, E).reshape(-1)
    w = (rates[:, None] * valid.astype(jnp.float32)).reshape(-1)
    load = jax.ops.segment_sum(w, seg, num_segments=E + 1)[:E]
    return rates, load
