"""Public wrapper for the fused waterfilling kernel (backend dispatch)."""
from __future__ import annotations

from repro.kernels import use_interpret
from repro.kernels.seg_waterfill.seg_waterfill import seg_waterfill as _wf


def seg_waterfill(links, active, link_bw_kbps, tcp_cap, n_rounds: int = 8,
                  interpret: bool | None = None, local_rate: float = 4.0e6,
                  inf: float = 1e9):
    """Fused waterfilling + Mathis allocation; (rates [F], load [E]).

    interpret=None auto-selects the lowering: compiled (Mosaic on TPU,
    Triton on GPU), interpreter only on CPU.  Production dispatch on CPU
    should not land here at all — `repro.kernels.resolve_kernel('auto')`
    keeps the jnp reference path for CPU runs.
    """
    if interpret is None:
        interpret = use_interpret()
    return _wf(links, active, link_bw_kbps, tcp_cap, n_rounds=n_rounds,
               interpret=interpret, local_rate=local_rate, inf=inf)
