# Kernel layer: Pallas implementations of the tick's compute hot spots,
# each shipped as <name>/<name>.py (kernel) + ops.py (public wrapper with
# backend dispatch) + ref.py (pure-jnp oracle).  docs/kernels.md has the
# inventory, the dispatch rules, and the how-to-add-one recipe.
from __future__ import annotations

import jax

# Backends with a real Pallas lowering: compiled Mosaic on TPU, Triton on
# GPU.  Everything else (CPU, plugins we don't know) gets the interpreter
# for correctness tests and the jnp reference for production dispatch.
COMPILED_BACKENDS = ("tpu", "gpu")
KERNEL_FLAGS = ("auto", "on", "off")


def kernel_backend() -> str:
    """The backend kernels dispatch on (jax's default backend)."""
    return jax.default_backend()


def use_interpret(backend: str | None = None) -> bool:
    """Pallas lowering selector: compiled on TPU/GPU, interpreter elsewhere.

    The interpreter executes the kernel as a traced jnp program — exact
    semantics, none of the speed — so CPU runs can still *test* kernels
    against their oracles without an accelerator.
    """
    backend = kernel_backend() if backend is None else backend
    return backend not in COMPILED_BACKENDS


def resolve_kernel(flag: str | bool, backend: str | None = None) -> bool:
    """Resolve an 'auto' | 'on' | 'off' config flag to use-the-kernel.

    * ``'on'``  — always the Pallas kernel (interpreter-lowered on CPU;
      this is the oracle-test mode, NOT a fast path off-accelerator).
    * ``'off'`` — always the pure-jnp reference.
    * ``'auto'`` — the kernel exactly where it has a compiled lowering
      (TPU/GPU); the reference on CPU, where the interpreter would be
      orders of magnitude slower than the jnp path it emulates.

    Booleans pass through (back-compat with call sites that already
    resolved).  The result is Python-static: it participates in jit cache
    keys via SimConfig, never in traced values.
    """
    if isinstance(flag, bool):
        return flag
    if flag not in KERNEL_FLAGS:
        raise ValueError(
            f"kernel flag must be one of {KERNEL_FLAGS}, got {flag!r}")
    if flag == "on":
        return True
    if flag == "off":
        return False
    backend = kernel_backend() if backend is None else backend
    return backend in COMPILED_BACKENDS
