"""Blocked min-plus Floyd-Warshall APSP — Pallas TPU kernels.

The simulator's delay matrix (paper eq. 1) is APSP over the congestion-
adjusted link graph — the O(N^3) hot spot, refreshed every
``delay_update_interval`` ticks.  TPU adaptation: the classic 3-phase
blocked decomposition with (bs, bs) tiles resident in VMEM:

  phase 1: pivot block    D[k,k]  <- in-block FW           (sequential in p)
  phase 2: pivot row/col  D[k,j] / D[i,k]                  (panel updates)
  phase 3: everything     D[i,j] = min(D[i,j], D[i,k] (+) D[k,j])
           -- a min-plus "matmul": runs on the VPU as bs broadcast-add-mins.

All phases are bandwidth-friendly: each tile is read/written once per pivot
step, and phase 3 (the bulk) has arithmetic intensity ~bs/8 ops/byte.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inblock_fw(d):
    """Sequential in-block FW over a [bs, bs] tile (returns updated tile)."""
    bs = d.shape[0]

    def body(p, d):
        return jnp.minimum(d, d[:, p][:, None] + d[p, :][None, :])

    return jax.lax.fori_loop(0, bs, body, d)


def _minplus(a, b):
    """min-plus product  out[r,c] = min_p a[r,p] + b[p,c]  ([bs,bs] tiles).

    Loops p to keep the VMEM working set at 3 tiles (no [bs,bs,bs]
    intermediate)."""
    bs = a.shape[0]
    init = a[:, 0][:, None] + b[0, :][None, :]

    def body(p, acc):
        return jnp.minimum(acc, a[:, p][:, None] + b[p, :][None, :])

    return jax.lax.fori_loop(1, bs, body, init)


# --- phase kernels ----------------------------------------------------------
def _phase1_kernel(d_ref, o_ref):
    o_ref[...] = _inblock_fw(d_ref[...])


def _phase2_row_kernel(kk_ref, d_ref, o_ref, *, bs):
    """D[k,j] update: out = min(out, kk (+) D[k,j]) with in-block order."""
    kk = kk_ref[...]
    d = d_ref[...]

    def body(p, d):
        return jnp.minimum(d, kk[:, p][:, None] + d[p, :][None, :])

    o_ref[...] = jax.lax.fori_loop(0, bs, body, d)


def _phase2_col_kernel(kk_ref, d_ref, o_ref, *, bs):
    """D[i,k] update: out = min(out, D[i,k] (+) kk)."""
    kk = kk_ref[...]
    d = d_ref[...]

    def body(p, d):
        return jnp.minimum(d, d[:, p][:, None] + kk[p, :][None, :])

    o_ref[...] = jax.lax.fori_loop(0, bs, body, d)


def _phase3_kernel(row_ref, col_ref, d_ref, o_ref):
    o_ref[...] = jnp.minimum(d_ref[...], _minplus(col_ref[...], row_ref[...]))


# --- driver -----------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def floyd_warshall(A: jnp.ndarray, bs: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """Blocked APSP.  A [n,n] f32; n padded up to a multiple of ``bs``."""
    n = A.shape[0]
    bs = min(bs, n)
    n_pad = ((n + bs - 1) // bs) * bs
    if n_pad != n:
        big = jnp.float32(1e9)
        A = jnp.pad(A, ((0, n_pad - n), (0, n_pad - n)),
                    constant_values=big)
        # keep the padded diagonal at 0 so padding never relays paths
        idx = jnp.arange(n, n_pad)
        A = A.at[idx, idx].set(0.0)
    nb = n_pad // bs

    tile = lambda i, j: pl.BlockSpec((bs, bs), lambda *_: (i, j))

    def phase1(D, k):
        return pl.pallas_call(
            _phase1_kernel,
            out_shape=jax.ShapeDtypeStruct((bs, bs), D.dtype),
            in_specs=[pl.BlockSpec((bs, bs), lambda: (k, k))],
            out_specs=pl.BlockSpec((bs, bs), lambda: (0, 0)),
            interpret=interpret, name="fw_phase1",
        )(D)

    def phase2(D, kk, k, row: bool):
        kern = _phase2_row_kernel if row else _phase2_col_kernel
        grid = (nb,)
        if row:
            d_spec = pl.BlockSpec((bs, bs), lambda j: (k, j))
        else:
            d_spec = pl.BlockSpec((bs, bs), lambda i: (i, k))
        return pl.pallas_call(
            functools.partial(kern, bs=bs),
            grid=grid,
            out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), D.dtype),
            in_specs=[pl.BlockSpec((bs, bs), lambda j: (0, 0)), d_spec],
            out_specs=d_spec,
            # alias D -> out: the grid only writes the pivot row/col panel,
            # every other tile must carry through unchanged
            input_output_aliases={1: 0},
            interpret=interpret, name="fw_phase2",
        )(kk, D)

    def phase3(D, k):
        return pl.pallas_call(
            _phase3_kernel,
            grid=(nb, nb),
            out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), D.dtype),
            in_specs=[
                pl.BlockSpec((bs, bs), lambda i, j: (k, j)),   # pivot row
                pl.BlockSpec((bs, bs), lambda i, j: (i, k)),   # pivot col
                pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
            ],
            out_specs=pl.BlockSpec((bs, bs), lambda i, j: (i, j)),
            interpret=interpret, name="fw_phase3",
        )(D, D, D)

    D = A.astype(jnp.float32)
    for k in range(nb):                     # nb pivot steps (static unroll)
        kk = phase1(D, k)
        D = jax.lax.dynamic_update_slice(D, kk, (k * bs, k * bs))
        D = phase2(D, kk, k, row=True)
        D = phase2(D, kk, k, row=False)
        D = phase3(D, k)
    return D[:n, :n]
