"""jit'd public wrapper for the blocked Floyd-Warshall kernel."""
from __future__ import annotations

import jax

from repro.kernels.fw_minplus.fw_minplus import floyd_warshall as _fw


def floyd_warshall(A, bs: int = 128, interpret: bool | None = None):
    """APSP over adjacency A.  interpret=None auto-selects: compiled Mosaic
    on TPU, interpreter everywhere else (CPU correctness mode)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _fw(A, bs=bs, interpret=interpret)
