"""jit'd public wrapper for the blocked Floyd-Warshall kernel."""
from __future__ import annotations

from repro.kernels import use_interpret
from repro.kernels.fw_minplus.fw_minplus import floyd_warshall as _fw


def floyd_warshall(A, bs: int = 128, interpret: bool | None = None):
    """APSP over adjacency A.  interpret=None auto-selects the lowering:
    compiled (Mosaic on TPU, Triton on GPU) wherever Pallas has one,
    interpreter only on CPU.  (The old ``backend != "tpu"`` rule wrongly
    sent GPUs through the interpreter.)"""
    if interpret is None:
        interpret = use_interpret()
    return _fw(A, bs=bs, interpret=interpret)
