"""Pure-jnp min-plus Floyd-Warshall oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def floyd_warshall_ref(A: jnp.ndarray) -> jnp.ndarray:
    """All-pairs shortest paths over adjacency A [n,n] (INF = no edge)."""
    n = A.shape[0]

    def body(D, k):
        return jnp.minimum(D, D[:, k, None] + D[None, k, :]), None

    D, _ = jax.lax.scan(body, A, jnp.arange(n))
    return D
