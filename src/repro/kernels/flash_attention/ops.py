"""Public flash-attention op with custom VJP.

Forward: Pallas kernel (Mosaic on TPU; interpreter on CPU).
Backward: recompute-from-inputs via the jnp reference — the kernels stay
forward-only while training still works end-to-end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention as ref_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal=True, scale=None):
    interpret = jax.default_backend() != "tpu"
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)


def _fwd(q, k, v, causal, scale):
    return flash_attention(q, k, v, causal, scale), (q, k, v)


def _bwd(causal, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref_attention(q, k, v, causal=causal,
                                                   scale=scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
