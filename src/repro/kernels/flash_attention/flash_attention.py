"""Causal flash attention (forward) — Pallas TPU kernel.

Streaming-softmax attention with (bq, bkv) tiling: for each query block the
kv blocks stream through VMEM; running max m, normalizer l and the output
accumulator live in VMEM scratch across the (sequentially iterated) kv grid
dimension.  Causality skips kv blocks strictly above the diagonal
(``pl.when``), so the kernel does ~half the work of dense attention.

GQA is expressed through the BlockSpec index_map (kv head = q head // G) —
K/V are never materialized per-q-head.

The backward pass recomputes with the jnp reference via ``custom_vjp``
(numerically identical oracle; keeps the kernel surface minimal).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, bq, bkv, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks strictly above the diagonal
    run = (qi * bq + bq - 1 >= ki * bkv) if causal \
        else (ki >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bkv, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                       (bq, bkv), 0)
            k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32,
                                                        (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]                          # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)              # rescale old state
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "bq", "bkv", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, scale=None, bq=128,
                        bkv=128, interpret=True):
    """q [B,Sq,Hq,D], k/v [B,Skv,Hkv,D] -> out [B,Sq,Hq,D]."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0

    qT = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kT = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vT = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    grid = (B * Hq, Sq // bq, Skv // bkv)

    def kv_index(h, qi, ki):
        # fold GQA: q-head h -> kv-head h // G (within its batch)
        b = h // Hq
        kvh = (h % Hq) // G
        return (b * Hkv + kvh, ki, 0)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, bq=bq, bkv=bkv,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bkv, D), kv_index),
            pl.BlockSpec((1, bkv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret, name="flash_attention",
    )(qT, kT, vT)
    return out.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
