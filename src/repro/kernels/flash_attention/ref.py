"""Pure-jnp f32 oracle for the flash-attention kernel.

(models.layers.attention_ref intentionally runs its dots in bf16 to mimic
MXU numerics; the kernel accumulates in f32, so the oracle must too.)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, causal=True, scale=None):
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", att, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
