"""Version-skew shims for jax APIs that moved/renamed across releases.

Keep all cross-version logic here so the next rename is a one-file fix.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.4.35 re-export
    from jax import shard_map  # type: ignore  # noqa: F401
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore  # noqa: F401

# the "don't check replication" kwarg was renamed check_rep -> check_vma
SM_NOCHECK = ({"check_vma": False}
              if "check_vma" in inspect.signature(shard_map).parameters
              else {"check_rep": False})
