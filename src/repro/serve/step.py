"""Serving steps: batched prefill and single-token decode with greedy/top-k
sampling.  The decode cache layouts live in ``models/transformer.init_cache``
and their shardings in ``models/sharding.cache_specs``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, mesh=None,
                      dp: tuple = ("data",)) -> Callable:
    def prefill_step(params, batch: Dict[str, Any]):
        logits, cache, seq_len = transformer.prefill(cfg, params, batch,
                                                     mesh=mesh, dp=dp)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None,
                     dp: tuple = ("data",)) -> Callable:
    def decode_one(params, tokens: jnp.ndarray, cache, cache_len):
        """tokens [B,1] -> (next token [B], logits, cache')."""
        logits, cache = transformer.decode_step(cfg, params, tokens, cache,
                                                cache_len, mesh=mesh, dp=dp)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return decode_one


def sample_top_k(key, logits: jnp.ndarray, k: int = 40,
                 temperature: float = 1.0) -> jnp.ndarray:
    vals, idx = jax.lax.top_k(logits / jnp.maximum(temperature, 1e-4), k)
    choice = jax.random.categorical(key, vals)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0]


def generate(cfg: ModelConfig, params, batch, n_steps: int, mesh=None,
             dp: tuple = ("data",), max_len: int | None = None):
    """Greedy generation loop (prefill + lax.scan over decode steps)."""
    prefill_step = make_prefill_step(cfg, mesh, dp)
    decode = make_decode_step(cfg, mesh, dp)

    first_tok, _, pf_cache = prefill_step(params, batch)
    seq_len = _batch_seq_len(cfg, batch)
    max_len = max_len or (seq_len + n_steps)
    B = first_tok.shape[0]
    cache = transformer.init_cache(cfg, B, max_len)
    cache = _load_prefill(cfg, cache, pf_cache, seq_len)

    def body(carry, _):
        tok, cache, pos = carry
        nxt, _, cache = decode(params, tok[:, None], cache, pos)
        return (nxt, cache, pos + 1), nxt

    (_, _, _), toks = jax.lax.scan(
        body, (first_tok, cache, jnp.array(seq_len, jnp.int32)),
        None, length=n_steps)
    return jnp.moveaxis(toks, 0, 1)                  # [B, n_steps]


def _batch_seq_len(cfg, batch) -> int:
    if cfg.frontend == "patch_embeds":
        return batch["patch_embeds"].shape[1] + batch["tokens"].shape[1]
    if cfg.frontend == "frame_embeds":
        return batch["frame_embeds"].shape[1]
    return batch["tokens"].shape[1]


def _load_prefill(cfg, cache, pf_cache, seq_len: int):
    """Copy prefill-sized cache entries into the max_len decode cache."""
    def load(full, part):
        if full.ndim >= 3 and part.ndim == full.ndim \
                and part.shape[2] <= full.shape[2] and full.ndim >= 4:
            return jax.lax.dynamic_update_slice_in_dim(
                full, part.astype(full.dtype), 0, axis=2)
        return part.astype(full.dtype)               # ssm states: replace

    out = {}
    for k in cache:
        if k == "ssm":
            out[k] = jax.tree.map(lambda f, p: p.astype(f.dtype),
                                  cache[k], pf_cache[k])
        else:
            out[k] = jax.tree.map(load, cache[k], pf_cache[k])
    return out
