"""Report module: CSV round-trip fidelity, the zero-completions edge case,
and the summary/timeseries contracts (ISSUE 3 satellite)."""
import numpy as np

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim,
                        summarize, timeseries, to_csv)
from repro.core.types import TickMetrics


def run_small(horizon=30, seed=0):
    cfg = SimConfig(n_jobs=8, n_tasks=30, n_containers=30, horizon=horizon,
                    arrival_window=8.0, placements_per_tick=16,
                    migrations_per_tick=2)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    final, metrics = run_sim(sim0, cfg, get_policy("firstfit"), spec.n_hosts,
                             spec.n_nodes, cfg.horizon)
    return final, metrics


def test_csv_round_trip_preserves_every_field(tmp_path):
    final, metrics = run_small()
    path = str(tmp_path / "ticks.csv")
    to_csv(metrics, path)
    data = np.genfromtxt(path, delimiter=",", names=True)
    assert set(data.dtype.names) == set(TickMetrics._fields)
    ts = timeseries(metrics)
    assert len(data) == len(ts["t"])
    for field in TickMetrics._fields:
        np.testing.assert_allclose(data[field], ts[field].astype(np.float64),
                                   rtol=0, atol=0, err_msg=field)


def test_timeseries_covers_every_tick_metric():
    _, metrics = run_small(horizon=12)
    ts = timeseries(metrics)
    assert set(ts) == set(TickMetrics._fields)
    assert all(len(v) == 12 for v in ts.values())


def test_summarize_zero_completions_does_not_raise():
    """A horizon too short for anything to finish (or even arrive) must
    still summarize cleanly — the all-NaN means stay NaN, counts zero."""
    final, metrics = run_small(horizon=1)
    with np.errstate(all="raise"):                 # surface numpy warnings
        rep = summarize(final, metrics)
    assert rep["n_completed"] == 0
    assert rep["completion_rate"] == 0.0
    assert np.isnan(rep["avg_runtime"]) and np.isnan(rep["avg_exec_time"])
    assert rep["total_cost"] >= 0.0


def test_summarize_no_arrivals_does_not_raise():
    """Zero *born* containers: every population is empty, including the
    comm-time slice whose bare ``.mean()`` used to warn on empty input."""
    cfg = SimConfig(arrival_window=1.0)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    # push every submit time beyond the horizon: nothing is ever born
    wl = paper_workload(cfg, seed=0)
    wl = wl._replace(submit_t=wl.submit_t + np.inf)
    sim0 = init_sim(hosts, wl, net, seed=0)
    final, metrics = run_sim(sim0, cfg, get_policy("firstfit"), spec.n_hosts,
                             spec.n_nodes, 3)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rep = summarize(final, metrics)
    assert rep["n_containers"] == 0
    assert np.isnan(rep["avg_comm_time"])


def test_summarize_matches_known_counts():
    final, metrics = run_small(horizon=60)
    rep = summarize(final, metrics)
    assert rep["n_containers"] == 30
    assert rep["n_completed"] == rep["completion_rate"] * 30
    assert rep["final_t"] == 60.0
