"""Network-aware scheduling: the netaware placement policy and the
congestion-aware migration destination picker (paper thesis: scheduling
must react to the fabric, not just to CPU/MEM/GPU headroom)."""
import jax.numpy as jnp
import numpy as np

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim,
                        summarize)
from repro.core.engine import phase_arrive, phase_schedule
from repro.core.network import (SpineLeafSpec, build_network,
                                pairwise_comm_cost, path_util_matrix)
from repro.core.scheduling import congestion_migrate, overload_migrate
from repro.core.types import STATUS_RUNNING

N_LEAF = 4


def congested_spine_cfg(**kw):
    """Chatty jobs (6 containers each, heavy comms) on a fabric whose
    leaf-spine links have 10% of the host-leaf bandwidth."""
    base = dict(n_jobs=6, n_tasks=36, n_containers=36, horizon=120,
                arrival_window=5.0, placements_per_tick=16,
                max_containers_per_host=3,
                n_comms_range=(3, 5), comm_kb_range=(20000.0, 60000.0))
    base.update(kw)
    return SimConfig(**base)


def congested_spine_net():
    spec = SpineLeafSpec(n_spine=2, n_leaf=N_LEAF, n_hosts=20,
                         host_leaf_bw=1000.0, leaf_spine_bw=100.0)
    return spec, build_network(spec)


# ---------------------------------------------------------------------------
# comm-cost helpers
# ---------------------------------------------------------------------------
def test_path_util_matrix_reflects_hot_spine():
    spec, net = congested_spine_net()
    H = spec.n_hosts
    net = net._replace(link_util=net.link_util.at[H:].set(0.9))  # spine hot
    U = np.asarray(path_util_matrix(net))
    leaf = np.arange(H) % N_LEAF
    same_leaf = leaf[:, None] == leaf[None, :]
    assert np.allclose(U[same_leaf], 0.0)          # never touches the spine
    assert np.allclose(U[~same_leaf], 0.9)
    assert np.allclose(np.diag(U), 0.0)


def test_pairwise_comm_cost_orders_locality():
    """Same host < same leaf < cross-spine, and spine congestion only
    raises the cross-spine entries."""
    spec, net = congested_spine_net()
    H = spec.n_hosts
    cost0 = np.asarray(pairwise_comm_cost(net))
    leaf = np.arange(H) % N_LEAF
    same_leaf = (leaf[:, None] == leaf[None, :]) & ~np.eye(H, dtype=bool)
    cross = leaf[:, None] != leaf[None, :]
    assert np.allclose(np.diag(cost0), 0.0)
    assert cost0[same_leaf].max() < cost0[cross].min()
    hot = net._replace(link_util=net.link_util.at[H:].set(0.9))
    cost_hot = np.asarray(pairwise_comm_cost(hot))
    np.testing.assert_allclose(cost_hot[same_leaf], cost0[same_leaf])
    assert (cost_hot[cross] > cost0[cross]).all()


# ---------------------------------------------------------------------------
# netaware placement
# ---------------------------------------------------------------------------
def test_netaware_colocates_with_deployed_peer():
    """A candidate whose job already has a deployed container lands on that
    container's host (comm cost 0) while slots remain."""
    cfg = congested_spine_cfg()
    spec, net = congested_spine_net()
    hosts = build_paper_hosts()
    sim = init_sim(hosts, paper_workload(cfg, seed=0), net, seed=0)
    ct = sim.containers
    anchor_host = 7
    jobs = np.asarray(ct.job)
    biggest = np.bincount(jobs[jobs >= 0]).argmax()   # chattiest job
    members = np.where(jobs == biggest)[0]
    assert len(members) >= 3
    c0 = int(members[0])
    ct = ct._replace(status=ct.status.at[c0].set(STATUS_RUNNING),
                     host=ct.host.at[c0].set(anchor_host))
    hs = sim.hosts._replace(
        used=sim.hosts.used.at[anchor_host].add(ct.req[c0]),
        n_containers=sim.hosts.n_containers.at[anchor_host].add(1))
    sim = sim._replace(containers=ct, hosts=hs, t=sim.t + 20.0)
    sim, _ = phase_arrive(sim)
    out = phase_schedule(sim, cfg, get_policy("netaware"))
    placed = np.asarray(out.containers.host)[members[1:]]
    # first same-job placements join the anchor until its slots run out
    assert (placed == anchor_host).sum() >= cfg.max_containers_per_host - 1
    # the overflow stays on the anchor's leaf rather than crossing the spine
    leaf = placed[placed >= 0] % N_LEAF
    assert (leaf == anchor_host % N_LEAF).all(), placed


def test_netaware_beats_firstfit_under_congested_spine():
    """Acceptance: on the congested-spine scenario netaware must beat
    firstfit on both mean flow rate and accumulated communication time
    (firstfit splits 6-container jobs across adjacent hosts, which sit on
    different leaves, so its flows cross the skinny spine)."""
    cfg = congested_spine_cfg()
    hosts = build_paper_hosts()
    rep, mfr = {}, {}
    for pol in ("firstfit", "netaware"):
        spec, net = congested_spine_net()
        sim0 = init_sim(hosts, paper_workload(cfg, seed=0), net, seed=0)
        final, m = run_sim(sim0, cfg, get_policy(pol), spec.n_hosts,
                           spec.n_nodes, cfg.horizon)
        rep[pol] = summarize(final, m)
        rates, act = np.asarray(m.mean_flow_rate), np.asarray(m.active_flows)
        mfr[pol] = float((rates * act).sum() / max(act.sum(), 1))
    assert rep["netaware"]["n_completed"] == cfg.n_containers
    assert (rep["netaware"]["avg_comm_time"]
            < 0.5 * rep["firstfit"]["avg_comm_time"]), rep
    assert mfr["netaware"] > 2.0 * mfr["firstfit"], mfr


# ---------------------------------------------------------------------------
# congestion-aware migration
# ---------------------------------------------------------------------------
def _overloaded_sim():
    """Host 0 overloaded with one movable container; every other host idle;
    every leaf-spine link hot (0.9 utilization)."""
    cfg = SimConfig()
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim = init_sim(hosts, paper_workload(cfg, seed=0), net, seed=0)
    H = spec.n_hosts
    ct = sim.containers
    ct = ct._replace(status=ct.status.at[0].set(STATUS_RUNNING),
                     host=ct.host.at[0].set(0),
                     req=ct.req.at[0].set(jnp.array([100.0, 1.0, 50.0])))
    hs = sim.hosts._replace(
        used=sim.hosts.used.at[0].set(0.8 * sim.hosts.cap[0]),
        n_containers=sim.hosts.n_containers.at[0].set(1))
    lu = sim.net.link_util.at[H:].set(0.9)
    sim = sim._replace(containers=ct, hosts=hs,
                       net=sim.net._replace(link_util=lu))
    return cfg, sim


def test_congestion_migrate_avoids_hot_links():
    """With the spine at 0.9 utilization, the congestion-aware picker keeps
    the migration flow on the source's leaf (host 4 = next same-leaf host)
    while the first-fit reference crosses the hot spine to host 1."""
    cfg, sim = _overloaded_sim()
    c_ff, d_ff = overload_migrate(sim, cfg)
    c_na, d_na = congestion_migrate(sim, cfg)
    assert int(c_ff) == 0 and int(c_na) == 0      # same container selection
    assert int(d_ff) == 1                          # first feasible, leaf 1
    assert int(d_na) == 4                          # same-leaf destination
    assert int(d_na) % N_LEAF == 0                 # source's leaf


def test_congestion_migrate_falls_back_without_congestion():
    """On an idle fabric every path costs the same, so the congestion-aware
    picker degenerates to the first feasible destination."""
    cfg, sim = _overloaded_sim()
    sim = sim._replace(net=sim.net._replace(
        link_util=jnp.zeros_like(sim.net.link_util)))
    c, d = congestion_migrate(sim, cfg)
    assert int(c) == 0 and int(d) == 1
