"""Macro-tick telescoping acceptance: telescoped == per-tick (PR 10).

The tentpole property — ``ExecPlan.telescope`` must be a pure execution
change, never a dynamics change:

* final state bit-for-bit equal to the per-tick path, for ALL six
  registered policies, stacked and chunked, and under the sweep vmap;
* integer summary keys (sums, counts, peaks) EXACTLY equal;
* float summary keys equal to ~f32-ulp (dt-weighted Kahan/Welford folds);
* a single dt-weighted ``SummaryAcc`` fold equals dt repeated unit folds
  (bit-exact integers, ~1-ulp float means), across chunk boundaries and
  under vmap;
* ``delay_update_interval=0`` ("refresh once at t=0, then frozen") is
  bitwise the periodic refresh when the refresh is idempotent;
* the engine actually telescopes (full-tick count << horizon on a
  quiescent-tail config) — a speedup claim needs skipped ticks to exist.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, get_policy, list_policies, run_sim,
                        summarize)
from repro.core import stats
from repro.core.engine import simulate_telescoped
from repro.core.scenario import ScenarioSpec, build_scenario, build_scenarios
from repro.core.types import ExecPlan, OnlineSummary, TickMetrics
from repro.launch.sweep import run_sim_vmapped, run_sweep

from test_streaming import (assert_rows_match, assert_trees_bitwise_equal,
                            build_small, small_cfg)

I32 = jnp.int32
F32 = jnp.float32

SEEDS = (0, 3)


# ---------------------------------------------------------------------------
# weighted SummaryAcc folds (satellite: fold correctness in isolation)
# ---------------------------------------------------------------------------

def synth_metrics(seed=0):
    """One fully-populated TickMetrics sample (scalar leaves)."""
    rng = np.random.default_rng(seed)
    i = lambda v: jnp.asarray(v, I32)
    f = lambda v: jnp.asarray(v, F32)
    return TickMetrics(
        t=f(7.0), n_overloaded=i(2), n_inactive=i(1), n_running=i(9),
        n_deployed=i(11), n_communicating=i(4), n_waiting=i(3),
        n_completed=i(5), n_migrating=i(1), new_arrivals=i(0),
        decisions=i(0), migrations=i(0),
        util_variance=f(rng.uniform(0.0, 0.2)),
        mean_util=f(rng.uniform(0.2, 0.9)), active_flows=i(6),
        mean_flow_rate=f(rng.uniform(1.0, 50.0)),
        soft_comm=f(rng.uniform(0.0, 2.0)), soft_util=f(rng.uniform(0, 1)),
        soft_n=f(3.0), soft_mig=f(rng.uniform(0, 1)), soft_mig_n=f(2.0))


def unit_folds(acc, m, dt):
    for _ in range(dt):
        acc = stats.acc_update(acc, m)
    return acc


def assert_acc_close(weighted, repeated, rtol=1e-5):
    wd, rd = weighted._asdict(), repeated._asdict()
    for name, a in wd.items():
        a, b = np.asarray(a), np.asarray(rd[name])
        if name.startswith("c_"):
            continue   # Kahan compensation is summation-order detail;
            #            what must agree is the RECOVERED total below
        if name.startswith("sum_") and ("c_" + name[4:]) in wd:
            a = a.astype(np.float64) + np.asarray(wd["c_" + name[4:]],
                                                  np.float64)
            b = b.astype(np.float64) + np.asarray(rd["c_" + name[4:]],
                                                  np.float64)
            np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-7,
                                       err_msg=name)
        elif a.dtype.kind == "i":
            assert (a == b).all(), (name, a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-7,
                                       err_msg=name)


@pytest.mark.parametrize("dt", [1, 2, 7, 100])
def test_weighted_fold_equals_unit_folds(dt):
    """ONE dt-weighted fold == dt repeated unit folds: integer sums and
    peaks bit-exact, Kahan sums and Welford moments to ~1 ulp."""
    m = synth_metrics()
    # start from a non-trivial accumulator so the Welford merge sees an
    # existing (n, mean, m2) — the dt=constant merge must be order-exact
    acc0 = unit_folds(stats.acc_init(), synth_metrics(seed=9), 3)
    weighted = stats.acc_update_weighted(acc0, m, jnp.asarray(dt, I32))
    repeated = unit_folds(acc0, m, dt)
    assert_acc_close(weighted, repeated)


def test_weighted_fold_dt_zero_is_bitwise_noop():
    acc0 = unit_folds(stats.acc_init(), synth_metrics(seed=4), 2)
    out = stats.acc_update_weighted(acc0, synth_metrics(), jnp.asarray(0, I32))
    assert_trees_bitwise_equal(acc0, out)


def test_weighted_fold_across_chunk_boundary():
    """Splitting one quiescent interval across two accumulators joined by
    the host ``online_fold`` matches the single-accumulator fold — the
    streaming chunk boundary mid-interval changes nothing."""
    m = synth_metrics(seed=2)
    one = stats.online_fold(
        stats.online_init(),
        stats.acc_update_weighted(stats.acc_init(), m, jnp.asarray(10, I32)))
    split = stats.online_init()
    for dt in (4, 6):
        acc = stats.acc_update_weighted(stats.acc_init(), m,
                                        jnp.asarray(dt, I32))
        split = stats.online_fold(split, acc)
    for name, a, b in zip(one._fields, one, split):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "i":
            assert (a == b).all(), name
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6, err_msg=name)


def test_weighted_fold_under_vmap():
    """Batched dt (the sweep's per-cell horizon): each lane folds exactly
    as its unbatched twin, dt=0 lanes included."""
    m = synth_metrics(seed=5)
    dts = jnp.asarray([0, 1, 3, 11], I32)
    accs = jax.vmap(lambda dt: stats.acc_update_weighted(
        stats.acc_init(), m, dt))(dts)
    for k, dt in enumerate(np.asarray(dts)):
        lane = jax.tree.map(lambda x: x[k], accs)
        assert_acc_close(lane, unit_folds(stats.acc_init(), m, int(dt)))


# ---------------------------------------------------------------------------
# tentpole: telescoped == per-tick, all policies, stacked/chunked/vmapped
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", list_policies())
def test_telescope_equals_stacked_all_policies(policy):
    cfg = small_cfg()
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy(policy)
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_tl, os_tl = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp,
                          plan=ExecPlan(telescope=True))
    assert isinstance(os_tl, OnlineSummary)
    assert int(os_tl.n_ticks) == cfg.horizon
    assert_trees_bitwise_equal(f_st, f_tl)
    assert_rows_match(summarize(f_st, m_st), summarize(f_tl, os_tl))


@pytest.mark.parametrize("chunk", [17, 64])
def test_telescope_chunked(chunk):
    """Telescoping under non-dividing and > horizon chunk sizes: chunk
    boundaries land mid-quiescent-interval and must only split the fold."""
    cfg = small_cfg()
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy("netaware")
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_tl, os_tl = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp,
                          plan=ExecPlan(telescope=True, chunk=chunk))
    assert int(os_tl.n_ticks) == cfg.horizon
    assert_trees_bitwise_equal(f_st, f_tl)
    assert_rows_match(summarize(f_st, m_st), summarize(f_tl, os_tl))


def test_telescope_longer_horizon_quiescent_tail():
    """A horizon long past the last completion: the all-idle tail must
    telescope without drifting state or miscounting summary ticks."""
    cfg = small_cfg(horizon=200)
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy("firstfit")
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_tl, os_tl = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp,
                          plan=ExecPlan(telescope=True, chunk=64))
    assert int(os_tl.n_ticks) == cfg.horizon
    assert_trees_bitwise_equal(f_st, f_tl)
    assert_rows_match(summarize(f_st, m_st), summarize(f_tl, os_tl))


def test_telescope_vmapped_equals_stacked():
    """Per-lane dt under the sweep vmap (the batched while_loop runs to
    max(t) with finished lanes select-masked): every lane bit-exact."""
    cfg = small_cfg()
    net_spec, sims, rps = build_scenarios([ScenarioSpec("baseline")], cfg,
                                          n_hosts=8, n_spine=2, n_leaf=4,
                                          seeds=(0, 1, 2))
    sims1 = jax.tree.map(lambda x: x[0], sims)
    rp1 = jax.tree.map(lambda x: x[0], rps)
    pol = get_policy("jobgroup")
    f_st, m_st = run_sim_vmapped(sims1, cfg, pol, net_spec.n_hosts,
                                 net_spec.n_nodes, cfg.horizon, rp1)
    f_tl, os_tl = run_sim_vmapped(sims1, cfg, pol, net_spec.n_hosts,
                                  net_spec.n_nodes, cfg.horizon, rp1,
                                  chunk=13, telescope=True)
    assert_trees_bitwise_equal(f_st, f_tl)
    ref = stats.online_from_metrics(m_st)
    for name in OnlineSummary._fields:
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(os_tl, name))
        if a.dtype.kind == "i":
            assert (a == b).all(), name
        else:
            np.testing.assert_allclose(a, b, rtol=3e-6, err_msg=name)


def test_telescope_sweep_equals_stacked_sweep():
    """Full grid, telescoped slabs: finals bit-exact, summary rows
    int-exact / float to f32 ulp, and still at most main + tail compiles."""
    cfg = small_cfg()
    scens = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0)]
    kw = dict(scenarios=scens, seeds=SEEDS, cfg=cfg, n_hosts=8, n_spine=2,
              n_leaf=4)
    st = run_sweep(policies=["firstfit", "netaware"], **kw)
    tl = run_sweep(policies=["firstfit", "netaware"],
                   plan=ExecPlan(telescope=True, chunk=17, slab=5), **kw)
    assert tl.metrics is None and isinstance(tl.summary, OnlineSummary)
    assert tl.compile_cache_misses <= 2   # main chunk + tail
    assert_trees_bitwise_equal(st.finals, tl.finals)
    for a, b in zip(st.summaries(), tl.summaries()):
        assert_rows_match(a, b)


def test_telescope_sweep_without_chunk():
    """``ExecPlan(telescope=True)`` alone rides the streaming path with
    the whole horizon as one chunk."""
    cfg = small_cfg()
    kw = dict(scenarios=[ScenarioSpec("baseline")], seeds=(0,), cfg=cfg,
              n_hosts=8, n_spine=2, n_leaf=4)
    st = run_sweep(policies=["firstfit"], **kw)
    tl = run_sweep(policies=["firstfit"], plan=ExecPlan(telescope=True), **kw)
    assert tl.metrics is None and isinstance(tl.summary, OnlineSummary)
    assert_trees_bitwise_equal(st.finals, tl.finals)
    for a, b in zip(st.summaries(), tl.summaries()):
        assert_rows_match(a, b)


def test_telescope_actually_telescopes():
    """The speedup claim needs skipped ticks to exist: on a config with a
    long quiescent tail the full-tick count must be a small fraction of
    the horizon (``with_stats`` exposes it)."""
    cfg = small_cfg(horizon=400, delay_update_interval=100)
    net_spec, sim0, rp = build_small(cfg)
    acc = stats.acc_init()
    _, _, n_full = simulate_telescoped(
        sim0, acc, jnp.asarray(0, I32), cfg, get_policy("firstfit"),
        net_spec.n_hosts, net_spec.n_nodes, cfg.horizon, rp,
        with_stats=True)
    assert int(n_full) < cfg.horizon // 2, int(n_full)


def test_telescope_rejects_soft_placement():
    """The macro step is a ``lax.while_loop`` — no reverse-mode autodiff,
    and the soft surrogate's per-tick sums are exactly what telescoping
    skips.  Loud error, not silent dt=1."""
    cfg = small_cfg(soft_placement=True)
    net_spec, sim0, rp = build_small(cfg)
    with pytest.raises(ValueError, match="soft_placement"):
        simulate_telescoped(sim0, stats.acc_init(), jnp.asarray(0, I32),
                            cfg, get_policy("netaware"), net_spec.n_hosts,
                            net_spec.n_nodes, cfg.horizon, rp)


def test_csv_with_telescope_rejected():
    """launch/sim.py must refuse --csv under telescoping the same way it
    refuses --csv with --chunk — skipped ticks have no per-tick rows."""
    from repro.launch.sim import run_one
    with pytest.raises(ValueError, match="telescop"):
        run_one("firstfit", small_cfg(), None, None, None, csv="x.csv",
                plan=ExecPlan(telescope=True))


# ---------------------------------------------------------------------------
# satellite: delay_update_interval=0 — refresh once at t=0, then frozen
# ---------------------------------------------------------------------------

def test_frozen_refresh_oracle_matches_periodic():
    """frozen == periodic when every refresh is idempotent: constant
    bw/loss (baseline scenario), ``queue_coef=0`` (no utilization term in
    the link delay) and zeroed util/cross-leaf comm-cost weights (every
    built-in carries the ``weight_vector`` defaults, so override them by
    name) make each periodic rebuild recompute the same matrix —
    interval=0 must then be bitwise the interval=K run."""
    rp_kw = dict(queue_coef=jnp.asarray(0.0, F32))
    pol = get_policy("firstfit", dict(util=0.0, cross_leaf=0.0))
    results = []
    for interval in (10, 0):
        cfg = small_cfg(delay_update_interval=interval)
        net_spec, sim0, rp = build_small(cfg)
        rp = rp._replace(**rp_kw)
        results.append(run_sim(sim0, cfg, pol,
                               net_spec.n_hosts, net_spec.n_nodes,
                               cfg.horizon, params=rp))
    (f_per, m_per), (f_fr, m_fr) = results
    assert_trees_bitwise_equal(f_per, f_fr)
    assert_rows_match(summarize(f_per, m_per), summarize(f_fr, m_fr))


def test_frozen_refresh_telescopes_bitwise():
    """interval=0 under telescoping: the horizon loses its refresh
    component entirely and the run still matches per-tick bitwise."""
    cfg = small_cfg(delay_update_interval=0)
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy("netaware")
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_tl, os_tl = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp,
                          plan=ExecPlan(telescope=True))
    assert_trees_bitwise_equal(f_st, f_tl)
    assert_rows_match(summarize(f_st, m_st), summarize(f_tl, os_tl))


def test_frozen_refresh_smoke_still_simulates():
    """interval=0 with the DEFAULT queue_coef is a behavior change by
    design (delays freeze at their t=0 values); it must still run and
    complete work."""
    cfg = small_cfg(delay_update_interval=0)
    net_spec, sim0, rp = build_small(cfg)
    f, m = run_sim(sim0, cfg, get_policy("netaware"), net_spec.n_hosts,
                   net_spec.n_nodes, cfg.horizon, params=rp)
    assert summarize(f, m)["n_completed"] > 0
