import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS);
# keep any user flags but never inherit a forced device count.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
