"""Sweep driver acceptance: the policy x scenario x seed grid must run as
ONE compiled call (1 jit cache miss) and every cell must be bit-for-bit the
corresponding standalone ``run_sim`` — for all six registered policies.

Also covers the scenario layer (bursty arrivals, host mixes, RunParams
ladders) and the seed-vmapped batch against per-seed runs (the former
``run_sim_vmapped``, subsumed into the sweep driver).
"""
import jax
import numpy as np
import pytest

from repro.core import (SimConfig, get_policy, list_policies, run_sim,
                        summarize)
from repro.core.datacenter import HOST_MIXES, mixed_hosts
from repro.core.scenario import (ScenarioSpec, build_scenario,
                                 build_scenarios, default_scenarios)
from repro.core.workload import bursty_workload
from repro.launch.sweep import run_sim_vmapped, run_sweep, stack_policies

SEEDS = (0, 3)


def small_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=40,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


def sweep_scenarios():
    """>= 4 scenarios spanning every axis the layer supports: bw/loss
    ladder, arrival pattern, host mix, runtime thresholds."""
    return [
        ScenarioSpec("baseline"),
        ScenarioSpec("slow_net", bw=200.0),
        ScenarioSpec("lossy", bw=500.0, loss=0.02),
        ScenarioSpec("bursty_premium", arrival="bursty",
                     host_mix="premium"),
        ScenarioSpec("tight_overload", overload_threshold=0.5,
                     idle_threshold=0.4),
    ]


@pytest.fixture(scope="module")
def sweep_result():
    return run_sweep(policies=list_policies(), scenarios=sweep_scenarios(),
                     seeds=SEEDS, cfg=small_cfg())


def test_sweep_compiles_exactly_once(sweep_result):
    """6 policies x 5 scenarios x 2 seeds = 60 cells, ONE XLA compilation
    (the jit cache-miss counter of the sweep function)."""
    assert sweep_result.compile_cache_misses == 1
    assert len(sweep_result.policies) == 6
    assert len(sweep_result.scenarios) >= 4
    assert len(sweep_result.seeds) >= 2


def test_sweep_cells_match_standalone_bit_for_bit(sweep_result):
    """Acceptance: every sweep cell's summarize output equals the
    corresponding standalone run_sim bit-for-bit, all six policies."""
    cfg = small_cfg()
    rows = sweep_result.summaries()
    by_cell = {(r["policy"], r["scenario"], r["seed"]): r for r in rows}
    for spec in sweep_scenarios():
        net_spec, sims, rp = build_scenario(spec, cfg, seeds=SEEDS)
        for n, seed in enumerate(SEEDS):
            sim0 = jax.tree.map(lambda x: x[n], sims)
            for pol in sweep_result.policies:
                final, metrics = run_sim(sim0, cfg, get_policy(pol),
                                         net_spec.n_hosts, net_spec.n_nodes,
                                         cfg.horizon, params=rp)
                want = summarize(final, metrics)
                got = dict(by_cell[(pol, spec.name, seed)])
                for extra in ("policy", "scenario", "seed"):
                    got.pop(extra)
                assert set(got) == set(want)
                for k in want:
                    np.testing.assert_array_equal(
                        got[k], want[k],
                        err_msg=f"{pol}/{spec.name}/seed{seed}/{k}")


def test_fully_vmapped_grid_matches_standalone_state_exactly():
    """PR 4 acceptance: with ALL THREE axes on ``vmap`` (scatter-free
    tick), every cell's FULL final state and per-tick metrics — not just
    the summary rows — equal the standalone ``run_sim`` bit-for-bit, and
    the grid still compiles exactly once."""
    cfg = small_cfg()
    specs = sweep_scenarios()[:3]
    res = run_sweep(policies=["firstfit", "netaware"], scenarios=specs,
                    seeds=SEEDS, cfg=cfg)
    assert res.compile_cache_misses == 1
    assert res.finals.t.shape == (2, len(specs), len(SEEDS))
    for s, spec in enumerate(specs):
        net_spec, sims, rp = build_scenario(spec, cfg, seeds=SEEDS)
        for n in range(len(SEEDS)):
            sim0 = jax.tree.map(lambda x: x[n], sims)
            for p, pol in enumerate(res.policies):
                final, metrics = run_sim(sim0, cfg, get_policy(pol),
                                         net_spec.n_hosts, net_spec.n_nodes,
                                         cfg.horizon, params=rp)
                cell = jax.tree.map(lambda x: x[p, s, n],
                                    (res.finals, res.metrics))
                for got, want in zip(jax.tree.leaves(cell),
                                     jax.tree.leaves((final, metrics))):
                    np.testing.assert_array_equal(
                        np.asarray(got), np.asarray(want),
                        err_msg=f"{pol}/{spec.name}/seed{n}")


def test_vmapped_seed_batch_matches_per_seed_runs():
    """The seed-batched runner (ex run_sim_vmapped) is exact vs per-seed
    standalone runs — state and metrics, not just summaries."""
    cfg = small_cfg()
    spec = ScenarioSpec("baseline")
    net_spec, sims, rp = build_scenario(spec, cfg, seeds=SEEDS)
    pol = get_policy("jobgroup")
    bat_final, bat_metrics = run_sim_vmapped(
        sims, cfg, pol, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
        params=rp)
    for n in range(len(SEEDS)):
        sim0 = jax.tree.map(lambda x: x[n], sims)
        final, metrics = run_sim(sim0, cfg, pol, net_spec.n_hosts,
                                 net_spec.n_nodes, cfg.horizon, params=rp)
        for got, want in zip(jax.tree.leaves((bat_final, bat_metrics)),
                             jax.tree.leaves((final, metrics))):
            np.testing.assert_array_equal(np.asarray(got)[n],
                                          np.asarray(want))


def test_sweep_table_emits_grid(sweep_result):
    table = sweep_result.table("avg_runtime")
    for pol in sweep_result.policies:
        assert pol in table
    for spec in sweep_result.scenarios:
        assert spec.name in table
    # header + one line per scenario
    assert len(table.splitlines()) == 2 + len(sweep_result.scenarios)


# ---------------------------------------------------------------------------
# Scenario layer
# ---------------------------------------------------------------------------
def test_scenario_run_params_override_and_keep():
    cfg = small_cfg()
    rp = ScenarioSpec("x", bw=250.0, overload_threshold=0.5).run_params(cfg)
    assert float(rp.bw_mbps) == 250.0
    assert float(rp.loss) == -1.0                     # keep sentinel
    assert float(rp.overload_threshold) == 0.5
    assert float(rp.queue_coef) == cfg.queue_coef     # config default


def test_build_scenarios_stacks_axes():
    cfg = small_cfg()
    specs = default_scenarios()
    net_spec, sims, rps = build_scenarios(specs, cfg, seeds=SEEDS)
    S, N = len(specs), len(SEEDS)
    assert sims.t.shape == (S, N)
    assert sims.hosts.cap.shape[:2] == (S, N)
    assert rps.bw_mbps.shape == (S,)
    # host mixes really differ across scenarios sharing one shape
    prem = [i for i, s in enumerate(specs) if s.host_mix == "premium"][0]
    assert not np.allclose(np.asarray(sims.hosts.price[0, 0]),
                           np.asarray(sims.hosts.price[prem, 0]))


def test_bursty_workload_clusters_arrivals():
    cfg = small_cfg(n_jobs=40, n_tasks=120, n_containers=120)
    state = bursty_workload(cfg, seed=1, n_bursts=3, burst_width=0.5)
    submit = np.asarray(state.submit_t)
    submit = submit[np.isfinite(submit)]
    assert submit.size == 120 and (submit >= 0).all()
    # 3 tight bursts: arrival times collapse onto ~3 distinct clusters, so
    # rounding to the nearest 2 s leaves far fewer distinct values than jobs
    assert len(np.unique(np.round(submit / 2.0))) <= 8


def test_registration_after_compile_is_pure_data():
    """With branch-free scoring a policy is a weight vector: registering a
    NEW policy after a compiled run must reuse the warm executable — zero
    new jit cache entries — and still run the new policy's semantics (the
    old switch design baked branch tables into the program and had to
    invalidate every compiled run on registration)."""
    from repro.core import register
    from repro.core import scheduling as sched
    from repro.core.engine import _run_sim_jit

    cfg = small_cfg(horizon=5)
    net_spec, sims, rp = build_scenario(ScenarioSpec("baseline"), cfg,
                                        seeds=(0,))
    sim0 = jax.tree.map(lambda x: x[0], sims)
    # warm the (cfg, shapes) cache
    run_sim(sim0, cfg, get_policy("firstfit"), net_spec.n_hosts,
            net_spec.n_nodes, cfg.horizon)
    misses = _run_sim_jit._cache_size()

    # lastfit: negative recency weight reverses FirstFit's host order
    name = "lastfit_regression"
    register(name, dict(row_recency=-1.0))
    try:
        final, _ = run_sim(sim0, cfg, get_policy(name), net_spec.n_hosts,
                           net_spec.n_nodes, cfg.horizon)
        assert _run_sim_jit._cache_size() == misses, \
            "new policy must ride the existing compilation"
        host = np.asarray(final.containers.host)
        placed = host[host >= 0]
        # last-fit fills from the top of the host range
        assert placed.size > 0
        assert placed.min() >= net_spec.n_hosts // 2, placed
    finally:
        del sched._REGISTRY[name]


def test_canonical_weight_length_enforced():
    """The fixed-length layout's loud-error guarantee: short/long vectors
    and unknown weight names are rejected up front (a short vector would
    silently clamp jit-mode gathers; a ragged batch breaks stacking)."""
    import jax.numpy as jnp
    import pytest

    from repro.core import (NUM_POLICY_WEIGHTS, PolicyParams, register,
                            weight_vector)

    with pytest.raises(ValueError):
        get_policy("firstfit", weights=[1.0, 0.05])     # the old 2-slot form
    with pytest.raises(ValueError):
        get_policy("firstfit", weights=np.zeros(NUM_POLICY_WEIGHTS + 1))
    with pytest.raises(ValueError):
        register("bad_length", np.zeros(3, np.float32))
    with pytest.raises(KeyError):
        get_policy("firstfit", weights={"no_such_weight": 1.0})
    with pytest.raises(KeyError):
        weight_vector(no_such_weight=1.0)
    with pytest.raises(ValueError):
        stack_policies([PolicyParams(weights=jnp.zeros(3))])
    assert weight_vector().shape == (NUM_POLICY_WEIGHTS,)


def test_stack_policies_stacks_names_and_params():
    from repro.core import NUM_POLICY_WEIGHTS

    pol = stack_policies(["firstfit", get_policy("netaware")])
    assert pol.weights.shape == (2, NUM_POLICY_WEIGHTS)
    np.testing.assert_array_equal(
        np.asarray(pol.weights[1]),
        np.asarray(get_policy("netaware").weights))


def test_host_mixes_share_shapes():
    for mix in HOST_MIXES:
        hosts = mixed_hosts(mix, 20, 4)
        assert hosts.cap.shape == (20, 3), mix
        assert hosts.price.shape == (20,), mix
