"""Training substrate: optimizer math, chunked CE, microbatching, roofline
accounting units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch import roofline
from repro.models.config import SHAPES
from repro.train.loss import chunked_cross_entropy
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state,
                                   lr_schedule)
from repro.train.step import StepConfig, init_train_state, make_train_step


def test_chunked_ce_matches_direct():
    rng = np.random.default_rng(0)
    B, S, d, V = 2, 48, 16, 50
    Vp = 64
    hidden = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    unembed = jnp.asarray(rng.standard_normal((d, Vp)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    labels = labels.at[0, :5].set(-1)          # masked positions

    nll_c, n_c = chunked_cross_entropy(hidden, unembed, labels, V, chunk=16)
    nll_u, n_u = chunked_cross_entropy(hidden, unembed, labels, V, chunk=16,
                                       unroll=True)
    # direct reference
    logits = hidden @ unembed
    logits = jnp.where(jnp.arange(Vp) < V, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.clip(labels, 0)[..., None],
                             axis=-1)[..., 0]
    valid = labels >= 0
    nll_ref = float(((lse - ll) * valid).sum())

    assert abs(float(nll_c) - nll_ref) < 0.35          # bf16 logits tolerance
    assert abs(float(nll_c) - float(nll_u)) < 1e-3
    assert float(n_c) == float(n_u) == float(valid.sum())


def test_chunk_size_invariance():
    rng = np.random.default_rng(1)
    hidden = jnp.asarray(rng.standard_normal((1, 64, 8)), jnp.float32)
    unembed = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 32, (1, 64)), jnp.int32)
    outs = [float(chunked_cross_entropy(hidden, unembed, labels, 32,
                                        chunk=c)[0]) for c in (8, 16, 64)]
    np.testing.assert_allclose(outs, outs[0], rtol=1e-3)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9           # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4               # peak after warmup
    assert lrs[-1] < lrs[50] < lrs[11]              # cosine decays
    assert lrs[-1] >= 1e-4 - 1e-6                   # floor at min_lr


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    total = sum(float(jnp.sum(x ** 2)) for x in jax.tree.leaves(clipped))
    assert abs(total - 1.0) < 1e-4


def test_adamw_decoupled_decay():
    """Zero grads + weight decay must still shrink params (AdamW not Adam)."""
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                          clip_norm=1e9)
    p = {"w": jnp.ones((3,))}
    state = init_opt_state(p)
    g = {"w": jnp.zeros((3,))}
    p2, state, _ = adamw_update(cfg, p, g, state)
    assert float(p2["w"][0]) < 1.0


def test_microbatch_equivalence():
    """n_microbatches=2 must equal 1 up to numerical tolerance."""
    cfg = get_reduced("smollm_360m")
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    out = {}
    for n in (1, 2):
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, OptimizerConfig(),
                                       StepConfig(n_microbatches=n)))
        state, metrics = step(state, batch)
        out[n] = (float(metrics["loss"]),
                  np.asarray(jax.tree.leaves(state.params)[0]))
    assert abs(out[1][0] - out[2][0]) < 5e-3
    np.testing.assert_allclose(out[1][1], out[2][1], atol=5e-3)


# --- roofline accounting -----------------------------------------------------
def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[4,4]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = roofline.collective_bytes(hlo)
    # all-gather: result 8*128*2 = 2048 B over 16 -> (15/16)*2048
    assert abs(out["all-gather"] - 2048 * 15 / 16) < 1e-6
    # all-reduce: 4*4*4 = 64 B over 4 -> 2*(3/4)*64
    assert abs(out["all-reduce"] - 2 * 0.75 * 64) < 1e-6
    assert out["collective-permute"] == 16.0
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_probe_extrapolation():
    c1 = {"flops": 10.0, "hbm_bytes": 100.0, "coll_bytes": 4.0,
          "coll_breakdown": {"all-reduce": 4.0, "total": 4.0}}
    c2 = {"flops": 16.0, "hbm_bytes": 140.0, "coll_bytes": 6.0,
          "coll_breakdown": {"all-reduce": 6.0, "total": 6.0}}
    t = roofline.from_probes(c1, c2, 2, 4, 10, 256, model_flops=0.0)
    assert abs(t.flops - (10 + 3.0 * 8)) < 1e-6          # slope 3/layer
    assert abs(t.hbm_bytes - (100 + 20 * 8)) < 1e-6
    assert abs(t.coll_bytes - (4 + 1.0 * 8)) < 1e-6


def test_model_flops_kinds():
    cfg = get_reduced("smollm_360m")
    tr = roofline.model_flops_for(cfg, SHAPES["train_4k"])
    pf = roofline.model_flops_for(cfg, SHAPES["prefill_32k"])
    dc = roofline.model_flops_for(cfg, SHAPES["decode_32k"])
    assert tr == 6.0 * cfg.active_param_count() * 4096 * 256
    assert pf == 2.0 * cfg.active_param_count() * 32768 * 32
    assert dc == 2.0 * cfg.active_param_count() * 128
