"""Distributed substrate: checkpoint roundtrip/reshard, int8 EF
compression properties, fault state machine, deterministic data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (ErrorFeedback, dequantize_int8,
                                           quantize_int8)
from repro.distributed.fault import (DEAD, HEALTHY, SUSPECT, FaultConfig,
                                     HeartbeatMonitor, StragglerDetector,
                                     plan_recovery)
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_train_state, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("smollm_360m")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "step_7")
    ckpt.save_checkpoint(d, state, 7)
    restored, step = ckpt.restore_checkpoint(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_training_continuity(tmp_path):
    """Save at step k, keep training, restore, replay -> identical loss
    (deterministic pipeline + exact state restore)."""
    cfg = get_reduced("qwen2_5_3b")
    data = SyntheticLM(DataConfig(seq_len=32, global_batch=2,
                                  vocab=cfg.vocab, seed=1))
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig()))
    state = init_train_state(cfg, jax.random.PRNGKey(1))

    losses_a = []
    for s in range(6):
        if s == 3:
            ckpt.save_checkpoint(str(tmp_path / "step_3"), state, 3)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, m = step_fn(state, batch)
        losses_a.append(float(m["loss"]))

    restored, step0 = ckpt.restore_checkpoint(str(tmp_path / "step_3"),
                                              state)
    losses_b = []
    st = restored
    for s in range(step0, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        st, m = step_fn(st, batch)
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5)


def test_latest_step_dir(tmp_path):
    for s in (10, 200, 30):
        os.makedirs(tmp_path / f"step_{s}")
    assert ckpt.latest_step_dir(str(tmp_path)).endswith("step_200")
    assert ckpt.latest_step_dir(str(tmp_path / "nope")) is None


# --- compression -------------------------------------------------------------
def test_int8_quantize_bounds():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 3)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6      # round-to-nearest bound


def test_error_feedback_reduces_bias():
    """With EF, the accumulated compressed sum tracks the true sum much
    closer than independent quantization."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)) * 0.01)
    resid = ErrorFeedback.init(g_true)
    acc_ef = np.zeros(64)
    acc_nv = np.zeros(64)
    for _ in range(50):
        comp, resid = ErrorFeedback.apply(g_true, resid)
        acc_ef += np.asarray(comp)
        q, s = quantize_int8(g_true)
        acc_nv += np.asarray(dequantize_int8(q, s))
    true = np.asarray(g_true) * 50
    assert np.abs(acc_ef - true).max() <= np.abs(acc_nv - true).max() + 1e-6
    assert np.abs(acc_ef - true).max() < 0.01


# --- fault machinery ---------------------------------------------------------
def make_clock():
    t = [0.0]
    return t, lambda: t[0]


def test_heartbeat_state_machine():
    t, clock = make_clock()
    cfg = FaultConfig(suspect_after_s=30, dead_after_s=120)
    mon = HeartbeatMonitor(["pod0:0", "pod0:1"], cfg, clock=clock)
    assert mon.status("pod0:0") == HEALTHY
    t[0] = 40.0
    assert mon.status("pod0:0") == SUSPECT
    mon.beat("pod0:1")
    assert mon.status("pod0:1") == HEALTHY
    t[0] = 200.0
    assert mon.status("pod0:0") == DEAD
    assert mon.dead_workers() == ["pod0:0", "pod0:1"]


def test_recovery_plan_restart_vs_elastic():
    t, clock = make_clock()
    cfg = FaultConfig(dead_after_s=10)
    workers = [f"pod{p}:{i}" for p in range(2) for i in range(4)]
    mon = HeartbeatMonitor(workers, cfg, clock=clock)
    assert plan_recovery(mon, 2, 4).action == "none"

    t[0] = 100.0
    for w in workers:
        if w != "pod1:2":
            mon.beat(w)
    plan = plan_recovery(mon, 2, 4)
    assert plan.action == "restart"
    assert plan.lost_workers == ("pod1:2",)

    t[0] = 200.0
    for w in workers:
        if not w.startswith("pod1"):
            mon.beat(w)
    plan = plan_recovery(mon, 2, 4)
    assert plan.action == "elastic_downsize"
    assert plan.new_multi_pod is False


def test_straggler_detector():
    det = StragglerDetector(FaultConfig(straggler_factor=2.0))
    for _ in range(10):
        det.record("fast0", 1.0)
        det.record("fast1", 1.1)
        det.record("slow", 5.0)
    assert det.stragglers() == ["slow"]


# --- data pipeline -----------------------------------------------------------
def test_pipeline_deterministic_per_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=1000, seed=9)
    a = SyntheticLM(cfg).batch_at(5)
    b = SyntheticLM(cfg).batch_at(5)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = SyntheticLM(cfg).batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=500, seed=0)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    assert (b["tokens"] < 500).all() and (b["labels"] >= 0).all()
