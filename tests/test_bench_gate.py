"""The CI bench-regression gate must catch real regressions and stay quiet
on healthy runs (benchmarks/check_regression.py — PR 4).

The synthetic quick run is derived from the committed baseline itself, so
the tests are self-consistent whatever numbers the baseline carries.
"""
import copy
import json
import os

from benchmarks.check_regression import BASELINE, check

TOL = 0.30


def load_base():
    with open(os.path.abspath(BASELINE)) as f:
        return json.load(f)


def quick_from(base):
    """A quick-run JSON that matches the committed baseline exactly."""
    lh = copy.deepcopy(base["longhorizon"])
    lh.pop("ceiling_mb", None)    # quick mode measures streaming only
    lh.pop("stacked", None)
    return {
        "bench": base["bench"],
        "points": [copy.deepcopy(p) for p in base["points"]
                   if (p["n_hosts"], p["n_containers"]) == (100, 1500)],
        "sparse_speedup": 1.5,
        "sweep": copy.deepcopy(base["sweep_quick"]),
        "tune": copy.deepcopy(base["tune"]),
        "tune_grad": copy.deepcopy(base["tune_grad"]),
        "sweep_dist": copy.deepcopy(base["sweep_dist"]),
        "longhorizon": lh,
        "telescope": copy.deepcopy(base["telescope"]),
    }


def test_committed_baseline_has_the_gate_inputs():
    base = load_base()
    assert base.get("sweep_quick"), "full bench must record sweep_quick"
    assert base["sweep_quick"]["compile_cache_misses"] == 1
    assert base["sweep"]["vmap_axes"] == "policy,scenario,seed"
    assert any((p["n_hosts"], p["n_containers"]) == (100, 1500)
               for p in base["points"])
    assert base.get("tune"), "full bench must record the tune smoke entry"
    assert base["tune"]["compile_cache_misses"] == 1
    # ISSUE 5 acceptance: branch-free scoring keeps the policy axis near
    # data-parallel cost on the committed full grid (ceiling recalibrated
    # 1.25 -> 1.35 in PR 9: standalone cells sped up ~6%, sweep steady
    # wall unchanged — the ratio's denominator moved, not the sweep)
    assert base["sweep"]["vmap_cell_tax"] <= 1.35
    # PR 7 acceptance: the committed longhorizon entry must demonstrate
    # streaming completing UNDER the fixed ceiling the stacked path
    # exceeded — the gate re-asserts this on every CI run
    lh = base.get("longhorizon")
    assert lh, "full bench must record the longhorizon memory entry"
    assert lh["stream"]["max_rss_mb"] <= lh["ceiling_mb"]
    assert lh["stacked"]["exceeded_ceiling"] is True
    assert lh["stacked_buffer_mb"] > 0
    # PR 8 acceptance: the committed multi-process fabric entry must
    # demonstrate bit-identical distributed results on every spawned arm
    # with at most 2 compiles per process
    sd = base.get("sweep_dist")
    assert sd, "full bench must record the sweep_dist fabric entry"
    assert sd["finals_match"] is True
    assert set(sd["arms"]) == {"1proc", "2proc", "2proc_serial"}
    for arm in sd["arms"].values():
        assert arm["compile_cache_misses"] <= 2, sd["arms"]
        assert arm["finals_match"] is True
    # PR 9 acceptance: the committed tune_grad entry must demonstrate
    # gradient search beating equal-oracle-budget random search with the
    # 2-executable compile bill (surrogate value_and_grad + hard oracle)
    tg = base.get("tune_grad")
    assert tg, "full bench must record the tune_grad smoke entry"
    assert tg["compile_cache_misses"] <= 2
    assert tg["grad_vs_random"] >= 1.0, tg
    assert tg["grad_vs_incumbent"] >= 1.0, tg
    assert tg["oracle_evals"] > 0 and tg["surrogate_evals"] > 0
    # PR 10 acceptance: the committed telescope entry must demonstrate
    # bit-identical telescoped finals AND the >= 3x sparse-point speedup
    tl = base.get("telescope")
    assert tl, "full bench must record the telescope entry"
    assert tl["finals_bitwise_equal"] is True
    assert tl["summary_close"] is True
    assert tl["telescope_speedup"] >= 3.0, tl
    assert 0.0 < tl["full_tick_fraction"] < 1.0, tl


def test_gate_passes_on_matching_run():
    base = load_base()
    assert check(quick_from(base), base, TOL) == []


def test_gate_allows_noise_inside_tolerance():
    base = load_base()
    quick = quick_from(base)
    for p in quick["points"]:
        p["ticks_per_s"] = round(p["ticks_per_s"] * (1 - TOL + 0.05), 1)
    quick["sweep"]["sweep_steady_s"] = round(
        quick["sweep"]["sweep_steady_s"] * (1 + TOL - 0.05), 2)
    assert check(quick, base, TOL) == []


def test_gate_tolerates_uniform_machine_skew():
    """A uniformly 2x-slower CI runner moves every ratio together; the
    median normalization must keep the gate green (the whole point of
    relative gating — absolute wall-clock would be permanently red)."""
    base = load_base()
    quick = quick_from(base)
    for p in quick["points"]:
        p["ticks_per_s"] = round(p["ticks_per_s"] * 0.5, 1)
    quick["sweep"]["sweep_steady_s"] = round(
        quick["sweep"]["sweep_steady_s"] * 2.0, 2)
    quick["tune"]["tune_steady_s"] = round(
        quick["tune"]["tune_steady_s"] * 2.0, 2)
    assert check(quick, base, TOL) == []


def test_gate_catches_tick_wide_regression_via_within_run_ratio():
    """A regression hitting the sparse tick AND the sweep together (e.g. a
    scatter creeping back into the shared tick) moves 2 of the 3 wall-clock
    ratios, so the median-skew gate alone would absorb it — the within-run
    sparse/dense speedup must catch it."""
    base = load_base()
    quick = quick_from(base)
    for p in quick["points"]:
        if p["mode"] == "sparse":
            p["ticks_per_s"] = round(p["ticks_per_s"] * 0.4, 1)
    quick["sweep"]["sweep_steady_s"] = round(
        quick["sweep"]["sweep_steady_s"] * 2.5, 2)
    failures = check(quick, base, TOL)
    assert any("sparse/dense" in m for m in failures), failures


def test_gate_catches_sweep_batching_regression_via_vmap_cell_tax():
    """The sweep losing batching efficiency shows up in the within-run
    vmap_cell_tax even when wall-clock skew-normalization absorbs it."""
    base = load_base()
    quick = quick_from(base)
    quick["sweep"]["vmap_cell_tax"] = round(
        quick["sweep"]["vmap_cell_tax"] * (1 + TOL + 0.2), 2)
    failures = check(quick, base, TOL)
    assert any("vmap_cell_tax" in m for m in failures), failures


def test_gate_fails_on_ticks_regression():
    """One point falling >tol below the machine's median ratio fails."""
    base = load_base()
    quick = quick_from(base)
    quick["points"][0]["ticks_per_s"] = round(
        quick["points"][0]["ticks_per_s"] * (1 - TOL - 0.2), 1)
    failures = check(quick, base, TOL)
    assert any("regression" in m and "ticks_per_s" in m
               for m in failures), failures


def test_gate_fails_on_sweep_per_cell_regression():
    base = load_base()
    quick = quick_from(base)
    quick["sweep"]["sweep_steady_s"] = round(
        quick["sweep"]["sweep_steady_s"] * 2.0, 2)
    failures = check(quick, base, TOL)
    assert any("regression" in m and "per-cell" in m
               for m in failures), failures


def test_gate_fails_on_extra_compilation():
    base = load_base()
    quick = quick_from(base)
    quick["sweep"]["compile_cache_misses"] = 2
    failures = check(quick, base, TOL)
    assert any("exactly once" in m for m in failures), failures


def test_gate_fails_without_committed_sweep_quick():
    base = load_base()
    quick = quick_from(base)
    del base["sweep_quick"]
    failures = check(quick, base, TOL)
    assert any("sweep_quick" in m for m in failures), failures


def test_gate_fails_on_grid_mismatch():
    base = load_base()
    quick = quick_from(base)
    quick["sweep"]["n_hosts"] += 1
    failures = check(quick, base, TOL)
    assert any("grid" in m for m in failures), failures


def test_gate_fails_without_tune_entry():
    base = load_base()
    quick = quick_from(base)
    del quick["tune"]
    failures = check(quick, base, TOL)
    assert any("tune" in m for m in failures), failures


def test_gate_fails_on_tune_extra_compilation():
    """Weight search losing its single compilation (weights leaking into
    cache keys) must fail the build."""
    base = load_base()
    quick = quick_from(base)
    quick["tune"]["compile_cache_misses"] = 9
    failures = check(quick, base, TOL)
    assert any("tune" in m and "once" in m for m in failures), failures


def test_gate_fails_on_tune_per_cell_regression():
    """The gated metric is the WARM tune repeat (runtime-dominated), not
    the compile-dominated cold wall."""
    base = load_base()
    quick = quick_from(base)
    quick["tune"]["tune_steady_s"] = round(
        quick["tune"]["tune_steady_s"] * 2.5, 2)
    failures = check(quick, base, TOL)
    assert any("tune per-cell" in m for m in failures), failures


def test_gate_skips_cross_backend_points():
    """A cpu CI runner gated against a gpu-refreshed baseline must SKIP
    those point comparisons (loud note), not fail them — wall-clock across
    backends is meaningless at any tolerance (ISSUE 6)."""
    base = load_base()
    quick = quick_from(base)
    for p in base["points"]:
        p["backend"] = "gpu"
    for p in quick["points"]:
        p["backend"] = "cpu"
        p["ticks_per_s"] = round(p["ticks_per_s"] * 0.01, 3)  # 100x "slower"
    failures = check(quick, base, TOL)
    assert not any("ticks_per_s at" in m for m in failures), failures


def test_gate_skips_cross_backend_sweep_and_tune():
    base = load_base()
    quick = quick_from(base)
    base["sweep_quick"]["backend"] = "gpu"
    base["tune"]["backend"] = "gpu"
    quick["sweep"]["backend"] = "cpu"
    quick["tune"]["backend"] = "cpu"
    quick["sweep"]["sweep_steady_s"] = round(
        quick["sweep"]["sweep_steady_s"] * 10, 2)
    quick["tune"]["tune_steady_s"] = round(
        quick["tune"]["tune_steady_s"] * 10, 2)
    failures = check(quick, base, TOL)
    assert not any("per-cell" in m for m in failures), failures


def test_gate_still_compares_same_backend():
    """Matching backends on both sides must keep gating (the guard only
    skips MISmatches)."""
    base = load_base()
    quick = quick_from(base)
    for p in base["points"]:
        p["backend"] = "cpu"
    for p in quick["points"]:
        p["backend"] = "cpu"
    quick["points"][0]["ticks_per_s"] = round(
        quick["points"][0]["ticks_per_s"] * (1 - TOL - 0.2), 1)
    failures = check(quick, base, TOL)
    assert any("regression" in m and "ticks_per_s" in m
               for m in failures), failures


def test_gate_legacy_baseline_without_backend_still_gates():
    """Pre-ladder baselines have no backend field; they must keep gating
    (assumed comparable) rather than silently skipping everything."""
    base = load_base()
    quick = quick_from(base)
    for p in base["points"]:
        p.pop("backend", None)
    for p in quick["points"]:
        p["backend"] = "cpu"
    quick["points"][0]["ticks_per_s"] = round(
        quick["points"][0]["ticks_per_s"] * (1 - TOL - 0.2), 1)
    failures = check(quick, base, TOL)
    assert any("regression" in m and "ticks_per_s" in m
               for m in failures), failures


def test_gate_fails_when_stream_rss_exceeds_ceiling():
    """The O(state) memory property is gated ABSOLUTELY: streaming RSS
    above the committed ceiling fails regardless of wall-clock skew."""
    base = load_base()
    quick = quick_from(base)
    quick["longhorizon"]["stream"]["max_rss_mb"] = \
        base["longhorizon"]["ceiling_mb"] + 1
    failures = check(quick, base, TOL)
    assert any("peak RSS" in m and "ceiling" in m for m in failures), failures


def test_gate_fails_without_committed_longhorizon():
    base = load_base()
    quick = quick_from(base)
    del base["longhorizon"]
    failures = check(quick, base, TOL)
    assert any("longhorizon" in m for m in failures), failures


def test_gate_fails_when_baseline_lost_the_crossing():
    """A baseline refresh that records the stacked child NOT exceeding the
    ceiling (e.g. someone shrank the horizon) must fail — the memory claim
    would be ungated."""
    base = load_base()
    quick = quick_from(base)
    base["longhorizon"]["stacked"]["exceeded_ceiling"] = False
    failures = check(quick, base, TOL)
    assert any("exceeding" in m for m in failures), failures


def test_gate_skips_cross_backend_longhorizon():
    """RSS on a different backend (device memory vs host) is not
    comparable — skip with a note, like every other entry."""
    base = load_base()
    quick = quick_from(base)
    base["longhorizon"]["stream"]["backend"] = "gpu"
    quick["longhorizon"]["stream"]["backend"] = "cpu"
    quick["longhorizon"]["stream"]["max_rss_mb"] = \
        base["longhorizon"]["ceiling_mb"] * 10
    failures = check(quick, base, TOL)
    assert not any("peak RSS" in m for m in failures), failures


def test_gate_fails_on_longhorizon_grid_mismatch():
    base = load_base()
    quick = quick_from(base)
    quick["longhorizon"]["seeds"] += 1
    failures = check(quick, base, TOL)
    assert any("longhorizon grid" in m for m in failures), failures


def test_gate_longhorizon_speed_joins_the_ratio_pack():
    """Streaming ticks/s is skew-normalized with the other wall-clock
    metrics: dropping it far below the pack fails."""
    base = load_base()
    quick = quick_from(base)
    quick["longhorizon"]["stream"]["ticks_per_s"] = round(
        base["longhorizon"]["stream"]["ticks_per_s"] * (1 - TOL - 0.2), 1)
    failures = check(quick, base, TOL)
    assert any("longhorizon stream ticks_per_s" in m
               for m in failures), failures


def test_point_key_separates_kernel_variants():
    """A kernels='auto' fw point must never be gated against the
    kernels='off' twin — they are different measurements by construction."""
    from benchmarks.check_regression import point_key
    p_on = {"n_hosts": 500, "n_containers": 3000, "mode": "sparse",
            "delay_mode": "fw", "kernels": "auto"}
    p_off = dict(p_on, kernels="off")
    legacy = {"n_hosts": 500, "n_containers": 3000, "mode": "sparse"}
    assert point_key(p_on) != point_key(p_off)
    # pre-ladder rows keep their identity: defaults are path/off
    assert point_key(legacy) == point_key(dict(legacy, delay_mode="path",
                                               kernels="off"))


def test_gate_enforces_branch_free_tax_ceiling():
    """The ISSUE 5 acceptance number is a hard gate: a quick run whose
    vmap_cell_tax blows past 1.35 * (1 + tol) fails even if the committed
    baseline were equally bad."""
    base = load_base()
    quick = quick_from(base)
    bad = round(1.35 * (1 + TOL) + 0.3, 2)
    quick["sweep"]["vmap_cell_tax"] = bad
    base["sweep_quick"]["vmap_cell_tax"] = bad   # relative gate blinded
    failures = check(quick, base, TOL)
    assert any("ceiling" in m for m in failures), failures


# -- the multi-process fabric gate (PR 8) -----------------------------------

def test_gate_fails_without_committed_sweep_dist():
    base = load_base()
    quick = quick_from(base)
    del base["sweep_dist"]
    failures = check(quick, base, TOL)
    assert any("sweep_dist" in m for m in failures), failures


def test_gate_fails_when_dist_identity_breaks():
    """Bit-identity between the distributed and in-process sweeps is THE
    fabric's correctness claim — a quick run losing it must fail."""
    base = load_base()
    quick = quick_from(base)
    quick["sweep_dist"]["finals_match"] = False
    failures = check(quick, base, TOL)
    assert any("bit-identical" in m for m in failures), failures


def test_gate_fails_when_baseline_lost_dist_identity():
    """A baseline refresh recording finals_match=false must fail loudly —
    the identity claim would be ungated from then on."""
    base = load_base()
    quick = quick_from(base)
    base["sweep_dist"]["finals_match"] = False
    failures = check(quick, base, TOL)
    assert any("ungated" in m for m in failures), failures


def test_gate_fails_on_dist_extra_compilation():
    """Each worker process may compile at most twice (steady jstep +
    final-slab remainder); a third compile means sharding or shapes leak
    into the cache key."""
    base = load_base()
    quick = quick_from(base)
    quick["sweep_dist"]["arms"]["2proc"]["compile_cache_misses"] = 3
    failures = check(quick, base, TOL)
    assert any("sweep_dist arm" in m and "<= 2" in m
               for m in failures), failures


def test_gate_fails_on_dist_overlap_regression():
    """overlap_ratio is within-run (serial vs overlapped gather on the
    same box) so machine skew cancels; falling >tol below the committed
    ratio means the overlapped driver stopped overlapping."""
    base = load_base()
    quick = quick_from(base)
    quick["sweep_dist"]["overlap_ratio"] = round(
        base["sweep_dist"]["overlap_ratio"] * (1 - TOL - 0.2), 2)
    failures = check(quick, base, TOL)
    assert any("overlap_ratio" in m for m in failures), failures


def test_gate_fails_on_dist_grid_mismatch():
    base = load_base()
    quick = quick_from(base)
    quick["sweep_dist"]["slab"] += 1
    failures = check(quick, base, TOL)
    assert any("sweep_dist grid" in m for m in failures), failures


def test_gate_skips_cross_backend_sweep_dist():
    """Quick-vs-committed dist comparisons skip across backends like every
    other entry (the committed baseline's own identity claim still
    gates)."""
    base = load_base()
    quick = quick_from(base)
    base["sweep_dist"]["backend"] = "gpu"
    quick["sweep_dist"]["backend"] = "cpu"
    quick["sweep_dist"]["finals_match"] = False
    quick["sweep_dist"]["overlap_ratio"] = 0.01
    failures = check(quick, base, TOL)
    assert not any("bit-identical" in m or "overlap_ratio" in m
                   for m in failures), failures


def test_gate_keeps_dist_walls_out_of_the_ratio_pack():
    """Spawned-arm walls are compile-bound cold numbers (like
    tune_cold_s): inflating them 100x must not fail the gate — only the
    within-run ratios and the identity/compile gates apply."""
    base = load_base()
    quick = quick_from(base)
    for arm in quick["sweep_dist"]["arms"].values():
        arm["wall_s"] = round(arm["wall_s"] * 100, 2)
        arm["max_worker_wall_s"] = round(arm["max_worker_wall_s"] * 100, 2)
    quick["sweep_dist"]["inproc_wall_s"] = round(
        quick["sweep_dist"]["inproc_wall_s"] * 100, 2)
    assert check(quick, base, TOL) == []


# -- the differentiable-tuning gate (PR 9) ----------------------------------

def test_gate_fails_without_tune_grad_entry():
    base = load_base()
    quick = quick_from(base)
    del quick["tune_grad"]
    failures = check(quick, base, TOL)
    assert any("tune_grad" in m for m in failures), failures


def test_gate_fails_without_committed_tune_grad():
    base = load_base()
    quick = quick_from(base)
    del base["tune_grad"]
    failures = check(quick, base, TOL)
    assert any("tune_grad" in m and "re-run the full bench" in m
               for m in failures), failures


def test_gate_fails_on_tune_grad_extra_executable():
    """tau annealing rides a traced RunParams field; a third executable
    means something static (tau, weights, the plan itself) leaked into a
    jit cache key."""
    base = load_base()
    quick = quick_from(base)
    quick["tune_grad"]["compile_cache_misses"] = 3
    failures = check(quick, base, TOL)
    assert any("tune_grad" in m and "2 executables" in m
               for m in failures), failures


def test_gate_fails_when_grad_stops_beating_random():
    """grad_vs_random is within-run (same oracle, same budget, same box)
    so machine skew cancels; < 1.0 means the surrogate's gradient lost
    its signal about the hard objective."""
    base = load_base()
    quick = quick_from(base)
    quick["tune_grad"]["grad_vs_random"] = 0.93
    failures = check(quick, base, TOL)
    assert any("beating random" in m for m in failures), failures


def test_gate_fails_when_grad_falls_below_incumbent():
    """The incumbent is oracle-scored before step 0 and the best-ever
    candidate is tracked, so ranking below it can only mean the bounded
    tracking broke."""
    base = load_base()
    quick = quick_from(base)
    quick["tune_grad"]["grad_vs_incumbent"] = 0.99
    failures = check(quick, base, TOL)
    assert any("incumbent" in m for m in failures), failures


def test_gate_fails_when_baseline_lost_grad_claim():
    """A baseline refresh recording grad_vs_random < 1 must fail loudly —
    the differentiable-path claim would be ungated from then on."""
    base = load_base()
    quick = quick_from(base)
    base["tune_grad"]["grad_vs_random"] = 0.93
    failures = check(quick, base, TOL)
    assert any("ungated" in m and "tune_grad" in m
               for m in failures), failures


def test_gate_fails_on_tune_grad_grid_mismatch():
    base = load_base()
    quick = quick_from(base)
    quick["tune_grad"]["steps"] += 1
    failures = check(quick, base, TOL)
    assert any("tune_grad grid" in m for m in failures), failures


def test_gate_keeps_tune_grad_wall_out_of_the_ratio_pack():
    """The grad smoke's cold wall is compile-bound (like tune_cold_s):
    inflating it 100x must not fail — only the within-run ratios and the
    compile bill gate."""
    base = load_base()
    quick = quick_from(base)
    quick["tune_grad"]["tune_grad_cold_s"] = round(
        quick["tune_grad"]["tune_grad_cold_s"] * 100, 2)
    assert check(quick, base, TOL) == []


# -- the tick-telescoping gate (PR 10) --------------------------------------

def test_gate_fails_without_committed_telescope():
    base = load_base()
    quick = quick_from(base)
    del base["telescope"]
    failures = check(quick, base, TOL)
    assert any("telescope" in m and "re-run the full bench" in m
               for m in failures), failures


def test_gate_fails_without_telescope_entry():
    base = load_base()
    quick = quick_from(base)
    del quick["telescope"]
    failures = check(quick, base, TOL)
    assert any("no 'telescope' entry in the quick run" in m
               for m in failures), failures


def test_gate_fails_when_telescope_equality_breaks():
    """Bitwise equality of telescoped vs per-tick finals is THE exactness
    claim — a quick run losing it must fail regardless of wall-clock."""
    base = load_base()
    quick = quick_from(base)
    quick["telescope"]["finals_bitwise_equal"] = False
    failures = check(quick, base, TOL)
    assert any("bit-identical" in m and "telescope" in m
               for m in failures), failures


def test_gate_fails_when_baseline_lost_telescope_equality():
    """A baseline refresh recording finals_bitwise_equal=false must fail
    loudly — the exactness claim would be ungated from then on."""
    base = load_base()
    quick = quick_from(base)
    base["telescope"]["finals_bitwise_equal"] = False
    failures = check(quick, base, TOL)
    assert any("ungated" in m and "equality" in m for m in failures), failures


def test_gate_fails_when_baseline_lost_telescope_speedup():
    """A baseline refresh below the >= 3x acceptance floor (e.g. someone
    moved the bench point into a dense-event regime) must fail — the
    headline perf claim would be ungated."""
    base = load_base()
    quick = quick_from(base)
    base["telescope"]["telescope_speedup"] = 2.4
    failures = check(quick, base, TOL)
    assert any("ungated" in m and "3" in m and "speedup" in m
               for m in failures), failures


def test_gate_fails_on_telescope_speedup_regression():
    """telescope_speedup is within-run (off vs on through the same vmapped
    driver on the same box) so machine skew cancels; falling >tol below
    the committed ratio means quiescent ticks stopped telescoping."""
    base = load_base()
    quick = quick_from(base)
    quick["telescope"]["telescope_speedup"] = round(
        base["telescope"]["telescope_speedup"] * (1 - TOL - 0.2), 2)
    failures = check(quick, base, TOL)
    assert any("within-run telescope_speedup" in m for m in failures), failures


def test_gate_fails_on_telescope_grid_mismatch():
    base = load_base()
    quick = quick_from(base)
    quick["telescope"]["horizon"] += 1
    failures = check(quick, base, TOL)
    assert any("telescope grid" in m for m in failures), failures


def test_gate_skips_cross_backend_telescope_throughput():
    """on_ticks_per_s across backends is meaningless — skip with a note;
    the within-run speedup and equality gates still apply."""
    base = load_base()
    quick = quick_from(base)
    base["telescope"]["backend"] = "gpu"
    quick["telescope"]["backend"] = "cpu"
    quick["telescope"]["on_ticks_per_s"] = round(
        base["telescope"]["on_ticks_per_s"] * 0.01, 2)
    failures = check(quick, base, TOL)
    assert not any("on_ticks_per_s" in m for m in failures), failures


def test_gate_telescope_ticks_joins_the_ratio_pack():
    """The ON-side throughput is skew-normalized with the other wall-clock
    metrics: dropping it far below the pack fails."""
    base = load_base()
    quick = quick_from(base)
    quick["telescope"]["on_ticks_per_s"] = round(
        base["telescope"]["on_ticks_per_s"] * (1 - TOL - 0.25), 2)
    failures = check(quick, base, TOL)
    assert any("telescope on_ticks_per_s" in m for m in failures), failures


def test_gate_keeps_telescope_walls_out_of_the_ratio_pack():
    """The raw off/on walls are single-machine absolutes (the OFF side is
    deliberately slow); inflating both 100x must not fail — only the
    within-run speedup, equality, and the ON throughput ratio gate."""
    base = load_base()
    quick = quick_from(base)
    quick["telescope"]["off_wall_s"] = round(
        quick["telescope"]["off_wall_s"] * 100, 2)
    quick["telescope"]["on_wall_s"] = round(
        quick["telescope"]["on_wall_s"] * 100, 2)
    assert check(quick, base, TOL) == []


# -- the perf-history archive (PR 8) ----------------------------------------

def test_archive_appends_and_dedups(tmp_path):
    """One row per distinct snapshot: a rerun on an unchanged artifact
    appends nothing; a changed artifact appends exactly one more row."""
    import json as _json

    from benchmarks.archive import append_history, read_history

    bench = load_base()
    bp, hp = str(tmp_path / "bench.json"), str(tmp_path / "hist.jsonl")
    with open(bp, "w") as f:
        _json.dump(bench, f)
    assert append_history(bp, hp) is True
    assert append_history(bp, hp) is False      # unchanged -> dedup
    bench["sparse_speedup"] = (bench.get("sparse_speedup") or 1) + 1
    with open(bp, "w") as f:
        _json.dump(bench, f)
    assert append_history(bp, hp) is True
    rows = read_history(hp)
    assert len(rows) == 2
    assert rows[0]["digest"] != rows[1]["digest"]
    for row in rows:
        assert row["date"] and "sparse_speedup" in row
        assert "vmap_cell_tax" in row and "dist_overlap_ratio" in row
        # PR 9: the headline row tracks the differentiable-tuning claim
        assert "tune_grad_vs_random" in row
        assert "tune_grad_best_oracle" in row
        # PR 10: the headline row tracks the telescoping claim
        assert "telescope_speedup" in row
        assert "telescope_bitwise_equal" in row


def test_committed_history_has_rows():
    """PR 8 acceptance: the tracked BENCH_history.jsonl carries at least
    two distinct rows and its latest row reflects the current committed
    snapshot (digest match, dist identity demonstrated)."""
    from benchmarks.archive import _digest, read_history

    rows = read_history()
    assert len(rows) >= 2, "BENCH_history.jsonl must carry >= 2 rows"
    assert len({r["digest"] for r in rows}) == len(rows)
    assert rows[-1]["digest"] == _digest(load_base())
    assert rows[-1]["dist_finals_match"] is True
