"""MoE-specific tests: routing invariants, capacity behaviour, and the
hillclimb regression guards (bf16 RoPE, a2a-vs-oracle is covered in the
multi-device CI path; here we cover everything that runs on 1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope, rope_angles


def test_router_topk_normalized():
    cfg = get_reduced("olmoe_1b_7b")
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    topw, topi, aux = moe_mod.router_topk(params, x, cfg)
    assert topw.shape == (2, 8, cfg.top_k)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(topi) >= 0).all()
    assert (np.asarray(topi) < cfg.n_experts).all()
    # aux loss is ~1 for a balanced router, >= 1 by Cauchy-Schwarz
    assert float(aux) >= 0.99


def test_router_aux_penalizes_imbalance():
    cfg = get_reduced("olmoe_1b_7b")
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    # bias the router hard toward expert 0 (positive inputs so the
    # weight-column bias reliably dominates the logit)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 16, cfg.d_model))) + 0.1
    _, _, aux_uniform = moe_mod.router_topk(params, x, cfg)
    biased = dict(params, router=params["router"].at[:, 0].add(100.0))
    _, _, aux_biased = moe_mod.router_topk(biased, x, cfg)
    assert float(aux_biased) > float(aux_uniform) * 2


def test_dense_oracle_respects_gates():
    """Zeroing the router weight for one expert removes its contribution."""
    cfg = get_reduced("olmoe_1b_7b")
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    y1, _ = moe_mod.moe_layer_dense(params, x, cfg)
    # scale every expert's down-proj by 0 -> output must be ~0 (no shared)
    if cfg.n_shared_experts == 0:
        p0 = dict(params, w_down=jnp.zeros_like(params["w_down"]))
        y0, _ = moe_mod.moe_layer_dense(p0, x, cfg)
        assert float(jnp.abs(y0).max()) < 1e-6
    assert np.isfinite(np.asarray(y1, np.float32)).all()


def test_capacity_rounding():
    cfg = get_reduced("olmoe_1b_7b")
    c = moe_mod._capacity(1024, cfg)
    assert c % 8 == 0 and c >= 8
    expect = 1024 * cfg.top_k * cfg.capacity_factor / cfg.n_experts
    assert abs(c - expect) <= 8


def test_rope_preserves_dtype_and_norm():
    """Perf regression guard (EXPERIMENTS.md §Perf iteration 2): RoPE must
    not upcast bf16 q/k to f32, and rotations preserve pairwise norms."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32),
                          jnp.bfloat16)
    cos, sin = rope_angles(jnp.arange(16), 32)
    y = apply_rope(x, cos, sin)
    assert y.dtype == jnp.bfloat16
    xf = x.astype(jnp.float32)
    yf = apply_rope(xf, cos, sin)
    assert yf.dtype == jnp.float32
    # rotation preserves the norm of each (x1, x2) pair
    d = 16
    n_in = xf[..., :d] ** 2 + xf[..., d:] ** 2
    n_out = yf[..., :d] ** 2 + yf[..., d:] ** 2
    np.testing.assert_allclose(np.asarray(n_in), np.asarray(n_out),
                               rtol=1e-5, atol=1e-5)


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 2, 16))
    cos, sin = rope_angles(jnp.zeros((1,)), 16)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


@pytest.mark.parametrize("impl", ["psum", "a2a"])
def test_moe_impl_flag_single_device_falls_back(impl):
    """On a 1-device mesh both EP paths fall back to the dense oracle."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced("olmoe_1b_7b"), moe_impl=impl)
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, aux = moe_mod.moe_layer(params, x, cfg, mesh=None)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
