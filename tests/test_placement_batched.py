"""Unified score-based Policy API: the batched conflict-resolved round and
the derived sequential reference (a K=1 degenerate round) must produce
identical placements for EVERY registered policy — including the
co-location policies (jobgroup, netaware), whose intra-round same-job
count delta is carried through the admit scan.

Also: the sparse segment-min comm-peer picker vs its dense oracle, and the
large-C regression for the sortable-int FIFO selection key.

No hypothesis dependency — seeded loops so the suite runs on a clean env.
"""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, list_policies, paper_workload,
                        run_sim)
from repro.core.engine import (phase_arrive, phase_schedule, pick_comm_peers,
                               pick_comm_peers_dense)
from repro.core.scheduling import INT_BIG, select_key_fifo
from repro.core.types import (STATUS_COMMUNICATING, STATUS_COMPLETED,
                              STATUS_INACTIVE, STATUS_MIGRATING,
                              STATUS_RUNNING, empty_containers)


def make_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=40,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


def fresh_sim(cfg, seed=0):
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    return spec, init_sim(hosts, paper_workload(cfg, seed=seed), net,
                          seed=seed)


# ---------------------------------------------------------------------------
# Comm-peer picker: sparse segment-min == dense C x C oracle
# ---------------------------------------------------------------------------
def test_comm_peers_match_dense_oracle():
    rng = np.random.default_rng(3)
    for seed in range(5):
        cfg = make_cfg()
        spec, sim = fresh_sim(cfg, seed=seed)
        ct = sim.containers
        C = ct.status.shape[0]
        # randomize a mid-simulation-looking state
        status = rng.choice([0, STATUS_RUNNING, STATUS_COMMUNICATING,
                             STATUS_MIGRATING, STATUS_COMPLETED], size=C)
        host = rng.integers(-1, 20, size=C)
        ct = ct._replace(status=ct.status.at[:].set(status.astype(np.int32)),
                         host=ct.host.at[:].set(host.astype(np.int32)))
        sparse = np.asarray(pick_comm_peers(ct))
        dense = np.asarray(pick_comm_peers_dense(ct))
        np.testing.assert_array_equal(sparse, dense)


def test_comm_peers_self_when_alone():
    cfg = make_cfg()
    spec, sim = fresh_sim(cfg)
    peers = np.asarray(pick_comm_peers(sim.containers))  # nothing deployed yet
    np.testing.assert_array_equal(peers, np.arange(len(peers)))


# ---------------------------------------------------------------------------
# FIFO selection key: sortable-int encoding, exact at any magnitude
# ---------------------------------------------------------------------------
def test_fifo_key_exact_at_large_magnitudes():
    """Regression: the old ``submit_t * C + index`` f32 encoding lost the
    index tie-break once the combined key exceeded ~2^24.  The rank-based
    i32 key must order (submit_t, index) lexicographically at any scale."""
    C = 5000
    ct = empty_containers(C)
    submit = np.full(C, 1.0e6, np.float32)     # huge, heavily tied
    submit[-7:] = 1.0e6 - 1.0                  # strictly earlier block at end
    submit[::13] = 1.0e6 + 0.5                 # and a later stripe
    ct = ct._replace(
        submit_t=ct.submit_t.at[:].set(jnp.asarray(submit)),
        status=ct.status.at[:].set(STATUS_INACTIVE))
    sim = SimpleNamespace(containers=ct, t=jnp.float32(2.0e6))
    key = np.asarray(select_key_fifo(sim))
    assert (key < int(INT_BIG)).all()          # everything schedulable
    order = np.argsort(key)                    # keys are unique ints
    expect = np.lexsort((np.arange(C), submit))
    np.testing.assert_array_equal(order, expect)


def test_fifo_key_masks_unschedulable():
    C = 64
    ct = empty_containers(C)
    submit = np.arange(C, dtype=np.float32)
    ct = ct._replace(
        submit_t=ct.submit_t.at[:].set(jnp.asarray(submit)),
        status=ct.status.at[:].set(STATUS_INACTIVE))
    ct = ct._replace(status=ct.status.at[::2].set(STATUS_RUNNING))
    sim = SimpleNamespace(containers=ct, t=jnp.float32(1000.0))
    key = np.asarray(select_key_fifo(sim))
    assert (key[::2] == int(INT_BIG)).all()
    valid = key[1::2]
    assert (valid < int(INT_BIG)).all()
    np.testing.assert_array_equal(np.argsort(valid), np.arange(len(valid)))


# ---------------------------------------------------------------------------
# Batched placement round == derived sequential reference
# ---------------------------------------------------------------------------
def _one_schedule_tick(cfg, policy_name, seed=0):
    spec, sim = fresh_sim(cfg, seed=seed)
    sim = sim._replace(t=sim.t + 20.0)        # everything has arrived by t=20
    sim, _ = phase_arrive(sim)
    policy = get_policy(policy_name)
    out = jax.jit(lambda s: phase_schedule(s, cfg, policy))(sim)
    return out


def test_batched_matches_sequential_every_policy():
    """Both engine paths evaluate the same select_key/place_score/dynamic
    hooks, so with every candidate feasible they make EXACTLY the same
    decisions — for all registered policies, including jobgroup and
    netaware whose co-location score is updated intra-round by the carry."""
    for policy in list_policies():
        for seed in (0, 1, 4):
            seq = _one_schedule_tick(make_cfg(batched_placement=False),
                                     policy, seed)
            bat = _one_schedule_tick(make_cfg(batched_placement=True),
                                     policy, seed)
            np.testing.assert_array_equal(np.asarray(seq.containers.status),
                                          np.asarray(bat.containers.status),
                                          err_msg=policy)
            np.testing.assert_array_equal(np.asarray(seq.containers.host),
                                          np.asarray(bat.containers.host),
                                          err_msg=policy)
            np.testing.assert_allclose(np.asarray(seq.hosts.used),
                                       np.asarray(bat.hosts.used),
                                       rtol=1e-5, err_msg=policy)
            assert int(seq.sched.decisions) == int(bat.sched.decisions)
            assert int(seq.sched.rr_pointer) == int(bat.sched.rr_pointer)


def test_batched_matches_sequential_full_run():
    """The equivalence must survive full simulations (comm pauses, retries,
    migrations) — exercised on the two scan-carried dynamic-score policies."""
    for policy in ["round", "jobgroup", "netaware"]:
        finals = {}
        for batched in (True, False):
            cfg = make_cfg(batched_placement=batched, horizon=50)
            spec, sim0 = fresh_sim(cfg, seed=2)
            finals[batched], _ = run_sim(sim0, cfg, get_policy(policy),
                                         spec.n_hosts, spec.n_nodes,
                                         cfg.horizon)
        for field in ("status", "host", "start_t", "finish_t"):
            np.testing.assert_array_equal(
                np.asarray(getattr(finals[True].containers, field)),
                np.asarray(getattr(finals[False].containers, field)),
                err_msg=f"{policy}.{field}")


def test_batched_skips_blocked_head():
    """A giant container with no feasible host must not block the rest of
    the round (the sequential argmin re-selects it forever — the paper's
    semantics, kept on the reference path)."""
    cfg = make_cfg(batched_placement=True)
    spec, sim = fresh_sim(cfg, seed=1)
    ct = sim.containers
    req = np.asarray(ct.req).copy()
    req[0] = [1e6, 1e6, 1e6]                  # infeasible everywhere
    submit = np.asarray(ct.submit_t).copy()
    submit[0] = 0.0                           # and first in FIFO order
    ct = ct._replace(req=ct.req.at[:].set(req),
                     submit_t=ct.submit_t.at[:].set(submit))
    sim = sim._replace(containers=ct, t=sim.t + 20.0)
    sim, _ = phase_arrive(sim)
    out = jax.jit(lambda s: phase_schedule(s, cfg, get_policy("firstfit")))(sim)
    st = np.asarray(out.containers.status)
    assert st[0] != STATUS_RUNNING            # the blocker stays queued
    assert (st == STATUS_RUNNING).sum() >= cfg.placements_per_tick - 1
    assert int(out.sched.decisions) >= cfg.placements_per_tick - 1


def test_batched_respects_capacity_over_full_run():
    for policy in ["firstfit", "round", "jobgroup", "netaware",
                   "overload_migrate"]:
        for seed in (0, 3):
            cfg = make_cfg(batched_placement=True)
            spec, sim0 = fresh_sim(cfg, seed=seed)
            final, _ = run_sim(sim0, cfg, get_policy(policy), spec.n_hosts,
                               spec.n_nodes, cfg.horizon)
            used = np.asarray(final.hosts.used)
            cap = np.asarray(final.hosts.cap)
            assert (used <= cap + 1e-3).all(), (policy, seed)
            assert (np.asarray(final.hosts.n_containers)
                    <= cfg.max_containers_per_host).all()


def test_batched_and_sequential_complete_the_workload():
    """Both paths finish the small paper workload within the horizon."""
    for batched in (True, False):
        cfg = make_cfg(batched_placement=batched, horizon=60)
        spec, sim0 = fresh_sim(cfg, seed=2)
        final, _ = run_sim(sim0, cfg, get_policy("firstfit"), spec.n_hosts,
                           spec.n_nodes, cfg.horizon)
        st = np.asarray(final.containers.status)
        assert (st == STATUS_COMPLETED).sum() == 40, batched


def test_round_policy_rotates_hosts_batched():
    cfg = make_cfg(batched_placement=True)
    out = _one_schedule_tick(cfg, "round")
    hosts = np.asarray(out.containers.host)
    placed = hosts[hosts >= 0]
    # round-robin across 20 feasible hosts: 16 placements hit 16 distinct hosts
    assert len(np.unique(placed)) == len(placed)
