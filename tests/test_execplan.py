"""ExecPlan API: one execution-options object, uniform across entry
points, with exactly one deprecation cycle for the old bare kwargs and
unchanged jit-cache-key semantics (the plan is resolved at the call
boundary — kernel selectors fold into the static SimConfig, tau and
weights stay traced)."""
import argparse
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, get_policy, run_sim
from repro.core.engine import resolve_plan
from repro.core.scenario import ScenarioSpec, build_scenario, build_scenarios
from repro.core.types import ExecPlan, PolicyParams
from repro.launch.execargs import add_exec_args
from repro.launch import sweep as sweep_mod
from repro.launch.dist import _resolve_dist_plan
from repro.launch.sweep import make_sweep_fn, run_sweep


def small_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=24,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


# --------------------------------------------------------------------------
# the dataclass itself
# --------------------------------------------------------------------------

def test_plan_validation_and_defaults():
    p = ExecPlan()
    assert p.chunk is None and p.slab is None and p.devices is None
    assert p.overlap and p.procs == 1 and p.devices_per_proc == 1
    with pytest.raises(ValueError):
        ExecPlan(chunk=0)
    with pytest.raises(ValueError):
        ExecPlan(slab=-1)
    with pytest.raises(ValueError):
        ExecPlan(delay_kernel="pallas")
    # devices: a count, a sequence (coerced to tuple for hashing), or None
    assert ExecPlan(devices=2).devices == 2
    assert isinstance(ExecPlan(devices=[0, 1]).devices, tuple)


def test_apply_to_config_folds_kernel_selectors_only():
    cfg = small_cfg()
    out = ExecPlan(delay_kernel="off", waterfill_kernel="on") \
        .apply_to_config(cfg)
    assert out.delay_kernel == "off" and out.waterfill_kernel == "on"
    assert out.horizon == cfg.horizon
    # None selectors keep the caller's config verbatim (same hashable key)
    assert ExecPlan(chunk=8).apply_to_config(cfg) == cfg


# --------------------------------------------------------------------------
# resolve_plan: one deprecation cycle, loud conflicts
# --------------------------------------------------------------------------

def test_resolve_plan_deprecation_cycle():
    cfg = small_cfg()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        plan, cfg2 = resolve_plan(None, cfg, chunk=8, slab=None)
    assert plan.chunk == 8
    assert [w for w in rec if issubclass(w.category, DeprecationWarning)]
    # plan AND legacy kwarg together: never silently prefer one
    with pytest.raises(TypeError, match="not both"):
        resolve_plan(ExecPlan(chunk=8), cfg, chunk=8)
    # plan-only and kwargless paths stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p1, _ = resolve_plan(ExecPlan(chunk=8), cfg, chunk=None)
        p0, _ = resolve_plan(None, cfg, chunk=None)
    assert p1.chunk == 8 and p0 == ExecPlan()


def test_run_sim_plan_equals_legacy_kwarg():
    cfg = small_cfg()
    spec, net = __import__("repro.core", fromlist=["build_paper_network"]) \
        .build_paper_network(cfg, n_hosts=8, n_leaf=4)
    from repro.core import build_paper_hosts, init_sim, paper_workload
    from repro.core import scaled_hosts
    sim0 = init_sim(scaled_hosts(8, 4), paper_workload(cfg, seed=0), net,
                    seed=0)
    pol = get_policy("firstfit")
    with pytest.deprecated_call():
        f_old, m_old = run_sim(sim0, cfg, pol, spec.n_hosts, spec.n_nodes,
                               cfg.horizon, chunk=8)
    f_new, m_new = run_sim(sim0, cfg, pol, spec.n_hosts, spec.n_nodes,
                           cfg.horizon, plan=ExecPlan(chunk=8))
    for a, b in zip(jax.tree.leaves(f_old), jax.tree.leaves(f_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(m_old), jax.tree.leaves(m_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_sweep_plan_equals_legacy_kwarg():
    cfg = small_cfg()
    with pytest.deprecated_call():
        old = run_sweep(["firstfit", "round"], seeds=(0,),
                        scenarios=[ScenarioSpec("baseline")], cfg=cfg,
                        n_hosts=8, n_leaf=4, chunk=8)
    new = run_sweep(["firstfit", "round"], seeds=(0,),
                    scenarios=[ScenarioSpec("baseline")], cfg=cfg,
                    n_hosts=8, n_leaf=4, plan=ExecPlan(chunk=8))
    old_rows, new_rows = old.summaries(), new.summaries()
    assert ([r["policy"] for r in old_rows]
            == [r["policy"] for r in new_rows])
    for ro, rn in zip(old_rows, new_rows):
        for k, v in ro.items():
            if isinstance(v, float) and np.isnan(v):
                assert np.isnan(rn[k]), k
            elif isinstance(v, (int, float)):
                assert rn[k] == pytest.approx(v, rel=1e-6), k


def test_dist_plan_keeps_historical_default():
    """No plan + no kwargs must still mean the historical 2-process
    launch, NOT ExecPlan's in-process procs=1 default."""
    cfg = small_cfg()
    plan, _ = _resolve_dist_plan(None, cfg)
    assert plan.procs == 2 and plan.devices_per_proc == 1
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        plan2, _ = _resolve_dist_plan(None, cfg, num_procs=3, chunk=6)
    assert plan2.procs == 3 and plan2.chunk == 6
    assert [w for w in rec if issubclass(w.category, DeprecationWarning)]
    with pytest.raises(TypeError):
        _resolve_dist_plan(ExecPlan(), cfg, num_procs=3)
    # the dist fabric has no stacked path: a plan without chunk is caught
    # at make_dist_fn time (run_tune/run_dist_sweep supply a default)
    from repro.launch.dist import make_dist_fn
    with pytest.raises(ValueError, match="chunk"):
        make_dist_fn(cfg, [ScenarioSpec("baseline")], (0,),
                     policies=["firstfit"], plan=ExecPlan(procs=2))


# --------------------------------------------------------------------------
# jit-cache-key semantics
# --------------------------------------------------------------------------

def test_traced_knobs_never_recompile_static_knobs_do():
    """tau / bw / weights ride RunParams or PolicyParams (traced: zero
    recompiles); kernel selectors fold into SimConfig (static: a new
    executable) — the plan never becomes a jit argument itself."""
    cfg = small_cfg(soft_placement=True)
    net_spec, sims, rps = build_scenarios([ScenarioSpec("baseline")], cfg,
                                          n_hosts=8, n_spine=2, n_leaf=4,
                                          seeds=(0,))
    fn = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon)
    pol = PolicyParams(weights=jnp.asarray(
        np.asarray(get_policy("netaware").weights)[None, :]))
    fn(sims, pol, rps)
    assert fn._cache_size() == 1
    fn(sims, pol, rps._replace(tau=jnp.full_like(rps.tau, 0.25)))
    fn(sims, pol, rps._replace(bw_mbps=jnp.full_like(rps.bw_mbps, 200.0)))
    w2 = jax.tree.map(lambda x: x * 1.5, pol)
    fn(sims, w2, rps)
    assert fn._cache_size() == 1               # all traced: one executable
    # a kernel selector is a DIFFERENT static config -> different program
    cfg_off = ExecPlan(waterfill_kernel="off").apply_to_config(cfg)
    assert hash(cfg_off) != hash(cfg)
    assert dataclasses.asdict(cfg_off) != dataclasses.asdict(cfg)


def test_plan_is_hashable_and_frozen():
    p = ExecPlan(chunk=8, devices=(0, 1))
    assert hash(p) == hash(ExecPlan(chunk=8, devices=(0, 1)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.chunk = 16


# --------------------------------------------------------------------------
# the shared CLI surface
# --------------------------------------------------------------------------

def test_add_exec_args_roundtrip():
    ap = argparse.ArgumentParser()
    add_exec_args(ap, dist=True)
    ns = ap.parse_args(["--chunk", "16", "--slab", "64", "--devices", "2",
                        "--no-overlap", "--delay-kernel", "off",
                        "--waterfill-kernel", "on", "--procs", "3",
                        "--devices-per-proc", "2"])
    plan = ExecPlan.from_args(ns)
    assert plan == ExecPlan(chunk=16, slab=64, devices=2, overlap=False,
                            delay_kernel="off", waterfill_kernel="on",
                            procs=3, devices_per_proc=2)
    # unset flags mean "keep defaults", including the kernel selectors
    # (None, NOT 'auto' — they must not clobber a caller-built config)
    empty = ExecPlan.from_args(ap.parse_args([]))
    assert empty == ExecPlan()
    assert empty.delay_kernel is None


def test_every_launcher_spells_exec_flags_identically():
    """sim/sweep/tune accept the same --chunk/--delay-kernel spellings;
    flags that make no sense for a launcher are absent, so argparse
    rejects them loudly instead of ignoring them."""
    sim_ap = argparse.ArgumentParser()
    add_exec_args(sim_ap, slab=False, devices=False, overlap=False)
    full_ap = argparse.ArgumentParser()
    add_exec_args(full_ap, dist=True)
    for ap in (sim_ap, full_ap):
        ns = ap.parse_args(["--chunk", "8", "--delay-kernel", "auto"])
        assert ExecPlan.from_args(ns).chunk == 8
    with pytest.raises(SystemExit):
        sim_ap.parse_args(["--slab", "8"])     # no grid -> no slab
    with pytest.raises(SystemExit):
        sim_ap.parse_args(["--procs", "2"])    # no dist either
    # the real sweep parser is built from the same helper
    ns = sweep_mod.build_parser().parse_args(
        ["--chunk", "8", "--slab", "4", "--waterfill-kernel", "off"])
    plan = ExecPlan.from_args(ns)
    assert (plan.chunk, plan.slab, plan.waterfill_kernel) == (8, 4, "off")


def test_grid_spec_carries_plan_fields(tmp_path):
    """The dist launcher's GridSpec JSON contract is built from the plan:
    chunk/slab/overlap/devices_per_proc land in the spec the workers
    parse (schema unchanged from the pre-plan fabric)."""
    from repro.launch.dist import GridSpec
    cfg = small_cfg()
    spec = GridSpec.build(cfg=cfg, scenarios=[ScenarioSpec("baseline")],
                          seeds=(0,), policies=["firstfit"], n_hosts=8,
                          n_spine=2, n_leaf=4, chunk=6, slab=2,
                          overlap=False, devices_per_proc=2)
    path = tmp_path / "grid.json"
    spec.save(str(path))
    back = GridSpec.load(str(path))
    assert back.chunk == 6 and back.slab == 2
    assert back.overlap is False and back.devices_per_proc == 2
    assert back.sim_config() == cfg
