"""Sparse flow-path engine vs the dense [F, E] oracle (hypothesis-free).

Properties (ISSUE 1 acceptance):
  * link-capacity conservation: per-link load from allocated rates never
    exceeds link bandwidth (beyond the freeze-rule epsilon);
  * numerical equivalence: sparse segment-based rates == dense membership
    oracle within rtol 1e-4;
  * leftover-flow regression: with more distinct bottleneck levels than
    waterfilling rounds, unfrozen flows get their fair-share bound, not the
    4 GB/s loopback alloc0 (the seed engine's oversubscription bug).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import SimConfig
from repro.core.datacenter import build_paper_network
from repro.core.network import (MBPS_TO_KBPS, SpineLeafSpec, build_network,
                                flow_rates, max_min_fair_rates,
                                max_min_fair_rates_sparse,
                                path_membership, set_link_params)

EPS = 1.02  # freeze rule admits bound <= m * 1.000001 + 1e-6 per round


def net20():
    return build_paper_network(SimConfig())


def random_flows(net, rng, n_flows, n_hosts=20):
    src = jnp.asarray(rng.integers(0, n_hosts, n_flows), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n_hosts, n_flows), jnp.int32)
    active = jnp.asarray(rng.random(n_flows) < 0.8)
    return src, dst, active


def link_load(net, src, dst, rates, active):
    member = path_membership(net.path_links, src, dst, net.link_bw.shape[0])
    member = np.asarray(member) & np.asarray(active)[:, None]
    return (member * np.asarray(rates)[:, None]).sum(0)


def test_sparse_matches_dense_oracle():
    """Sparse rates == dense oracle within rtol 1e-4 on random flow sets."""
    spec, net = net20()
    rng = np.random.default_rng(0)
    for trial in range(20):
        n_flows = int(rng.integers(1, 40))
        src, dst, active = random_flows(net, rng, n_flows)
        r_sparse, u_sparse = flow_rates(net, src, dst, active, sparse=True)
        r_dense, u_dense = flow_rates(net, src, dst, active, sparse=False)
        np.testing.assert_allclose(np.asarray(r_sparse), np.asarray(r_dense),
                                   rtol=1e-4, atol=1e-3, err_msg=f"trial {trial}")
        np.testing.assert_allclose(np.asarray(u_sparse), np.asarray(u_dense),
                                   rtol=1e-4, atol=1e-5)


def test_sparse_matches_dense_with_loss():
    spec, net = net20()
    lossy = set_link_params(net, loss=0.01)
    rng = np.random.default_rng(7)
    src, dst, active = random_flows(lossy, rng, 24)
    r_s, _ = flow_rates(lossy, src, dst, active, sparse=True)
    r_d, _ = flow_rates(lossy, src, dst, active, sparse=False)
    np.testing.assert_allclose(np.asarray(r_s), np.asarray(r_d),
                               rtol=1e-4, atol=1e-3)


def test_link_capacity_conservation():
    """segment_sum of rates over links <= link_bw_kbps * (1 + eps)."""
    spec, net = net20()
    bw_kbps = np.asarray(net.link_bw) * MBPS_TO_KBPS
    rng = np.random.default_rng(42)
    for _ in range(20):
        n_flows = int(rng.integers(1, 64))
        src, dst, active = random_flows(net, rng, n_flows)
        for sparse in (True, False):
            rates, util = flow_rates(net, src, dst, active, sparse=sparse)
            load = link_load(net, src, dst, rates, active)
            assert (load <= bw_kbps * EPS + 1e-3).all(), \
                f"sparse={sparse}: overload {(load - bw_kbps).max()}"
            assert (np.asarray(rates) >= 0).all()
            assert (np.asarray(util) <= 1.0 + 1e-6).all()


def _many_bottleneck_net(n_bottlenecks=10):
    """Spine-leaf fabric whose first ``n_bottlenecks`` host uplinks each have
    a distinct bandwidth — progressive filling needs one round per distinct
    bottleneck level, exceeding the default 8-round budget."""
    spec = SpineLeafSpec(n_spine=2, n_leaf=4, n_hosts=24)
    net = build_network(spec)
    bw = np.asarray(net.link_bw).copy()
    for i in range(n_bottlenecks):
        bw[i] = 10.0 * (i + 1)          # 10, 20, ..., 100 Mbps uplinks
    new_bw = jnp.asarray(bw)
    return spec, net._replace(link_bw=new_bw,
                              link_bw_kbps=new_bw * MBPS_TO_KBPS)


def test_leftover_flows_bounded_regression():
    """Seed bug: flows unfrozen after n_rounds kept alloc0 = 4 GB/s.

    10 flows, each alone on a distinctly-sized bottleneck uplink => 10
    distinct fair-share levels; with n_rounds=8 at least one flow used to
    fall through with LOCAL_RATE_KBPS and oversubscribe its links.
    """
    spec, net = _many_bottleneck_net(10)
    n = 10
    src = jnp.arange(n, dtype=jnp.int32)
    dst = jnp.arange(n, dtype=jnp.int32) + 10       # distinct dst hosts
    active = jnp.ones((n,), bool)
    bw_kbps = np.asarray(net.link_bw) * MBPS_TO_KBPS
    for sparse in (True, False):
        rates, _ = flow_rates(net, src, dst, active, n_rounds=8, sparse=sparse)
        r = np.asarray(rates)
        # every flow bounded by its own bottleneck uplink (flow i <- link i)
        assert (r <= bw_kbps[:n] * EPS + 1e-3).all(), \
            f"sparse={sparse}: rates {r} exceed uplinks {bw_kbps[:n]}"
        load = link_load(net, src, dst, rates, active)
        assert (load <= bw_kbps * EPS + 1e-3).all()
    # the two engines agree on the leftover allocation too
    r_s, _ = flow_rates(net, src, dst, active, n_rounds=8, sparse=True)
    r_d, _ = flow_rates(net, src, dst, active, n_rounds=8, sparse=False)
    np.testing.assert_allclose(np.asarray(r_s), np.asarray(r_d),
                               rtol=1e-4, atol=1e-3)


def test_leftover_fallback_is_fair_share():
    """Direct max_min unit check: with rounds exhausted the unfrozen flow's
    allocation equals its remaining fair share, not LOCAL_RATE_KBPS."""
    spec, net = _many_bottleneck_net(10)
    n = 10
    src = jnp.arange(n, dtype=jnp.int32)
    dst = jnp.arange(n, dtype=jnp.int32) + 10
    active = jnp.ones((n,), bool)
    E = net.link_bw.shape[0]
    bw_kbps = net.link_bw * MBPS_TO_KBPS
    member = path_membership(net.path_links, src, dst, E) & active[:, None]
    links = net.path_links[src, dst]
    for n_rounds in (2, 4, 8):
        dense = np.asarray(max_min_fair_rates(member, active, bw_kbps,
                                              n_rounds=n_rounds))
        sp = np.asarray(max_min_fair_rates_sparse(links, active, bw_kbps,
                                                  n_rounds=n_rounds))
        assert dense.max() < 1e6, f"n_rounds={n_rounds}: leftover kept alloc0"
        assert sp.max() < 1e6
        np.testing.assert_allclose(sp, dense, rtol=1e-4, atol=1e-3)


def test_path_loss_matrix_matches_membership_product():
    spec, net = net20()
    lossy = set_link_params(net, loss=0.015)
    P = np.asarray(lossy.path_loss)
    loss = np.asarray(lossy.link_loss)
    pl = np.asarray(lossy.path_links)
    for i, j in [(0, 1), (0, 4), (3, 17), (5, 5)]:
        links = pl[i, j][pl[i, j] >= 0]
        expect = 1.0 - np.prod(1.0 - loss[links]) if len(links) else 0.0
        np.testing.assert_allclose(P[i, j], expect, rtol=1e-5, atol=1e-7)


def test_same_host_flow_local_sparse():
    spec, net = net20()
    src = jnp.asarray([3], jnp.int32)
    dst = jnp.asarray([3], jnp.int32)
    rates, util = flow_rates(net, src, dst, jnp.ones((1,), bool), sparse=True)
    assert float(rates[0]) >= 1e6
    assert float(np.asarray(util).max()) == 0.0
