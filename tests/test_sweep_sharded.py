"""Multi-device sweep: the flattened policy x scenario x seed grid axis is
sharded with a ``NamedSharding`` and must stay BIT-FOR-BIT equal to the
unsharded run — cells are independent, sharding only partitions the batch.

The subprocess test forces 4 fake CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
set before jax initializes, so the main test process — pinned to one
device — cannot do it in-process).  The grid is deliberately 18 cells
(3 policies x 3 scenarios x 2 seeds), NOT a multiple of 4, so the
round-robin pad path is exercised too.  CI additionally runs the
in-process variant in the tier-1 matrix with the env set.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax
import numpy as np

from repro.core import SimConfig
from repro.core.scenario import ScenarioSpec, build_scenarios
from repro.launch.sweep import make_sweep_fn, stack_policies

cfg = SimConfig(n_jobs=10, n_tasks=40, n_containers=40, horizon=30,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
specs = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0),
         ScenarioSpec("bursty_premium", arrival="bursty",
                      host_mix="premium")]
net_spec, sims, rps = build_scenarios(specs, cfg, seeds=(0, 1))
pol = stack_policies(["firstfit", "round", "netaware"])   # 18 cells % 4 != 0

f1 = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                   devices=1)
f4 = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon)
o1 = f1(sims, pol, rps)
o4 = f4(sims, pol, rps)
equal = all(np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o4)))
print(json.dumps({
    "device_count": jax.device_count(),
    "n_devices_sharded": f4.n_devices,
    "n_devices_unsharded": f1.n_devices,
    "compiles_sharded": f4._cache_size(),
    "compiles_unsharded": f1._cache_size(),
    "bitwise_equal": equal,
    "grid_shape": list(np.asarray(o4[0].t).shape),
}))
"""


def _run_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_grid_matches_unsharded_bitwise():
    """4 forced host devices: sharded == unsharded, one compile each, the
    [P, S, N] output shape intact through the pad/flatten round-trip."""
    out = _run_subprocess()
    assert out["device_count"] == 4
    assert out["n_devices_sharded"] == 4
    assert out["n_devices_unsharded"] == 1
    assert out["compiles_sharded"] == 1
    assert out["compiles_unsharded"] == 1
    assert out["grid_shape"] == [3, 3, 2]
    assert out["bitwise_equal"] is True


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count set before jax init (CI step)")
def test_sharded_grid_matches_unsharded_in_process():
    """In-process variant for environments launched with the XLA_FLAGS env
    (the tier-1 CI matrix runs this file with 4 forced devices)."""
    import numpy as np

    from repro.core import SimConfig
    from repro.core.scenario import ScenarioSpec, build_scenarios
    from repro.launch.sweep import make_sweep_fn, stack_policies

    cfg = SimConfig(n_jobs=10, n_tasks=40, n_containers=40, horizon=20,
                    arrival_window=10.0, placements_per_tick=16,
                    migrations_per_tick=2)
    specs = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0)]
    net_spec, sims, rps = build_scenarios(specs, cfg, seeds=(0,))
    pol = stack_policies(["firstfit", "netaware", "jobgroup"])  # 6 cells
    f1 = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                       devices=1)
    fd = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon)
    assert fd.n_devices == jax.device_count()
    o1, od = f1(sims, pol, rps), fd(sims, pol, rps)
    for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(od)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
