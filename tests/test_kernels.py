"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention as fa_ref
from repro.kernels.fw_minplus import ops as fw_ops
from repro.kernels.fw_minplus.ref import floyd_warshall_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan.ref import ssd_chunked_ref

rng = np.random.default_rng(42)


# --- fw_minplus -------------------------------------------------------------
@pytest.mark.parametrize("n,bs", [(8, 4), (24, 8), (64, 16), (100, 32),
                                  (128, 64), (30, 16)])
def test_fw_matches_ref(n, bs):
    A = rng.uniform(0.1, 10, (n, n)).astype(np.float32)
    A[rng.uniform(size=(n, n)) < 0.5] = 1e9
    A = np.minimum(A, A.T)
    np.fill_diagonal(A, 0.0)
    D_ref = np.asarray(floyd_warshall_ref(jnp.asarray(A)))
    D_k = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=bs))
    np.testing.assert_allclose(D_k, D_ref, rtol=1e-5, atol=1e-4)


def test_fw_disconnected_stays_inf():
    A = np.full((12, 12), 1e9, np.float32)
    np.fill_diagonal(A, 0)
    A[0, 1] = A[1, 0] = 1.0          # only one edge
    D = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=4))
    assert D[0, 1] == 1.0
    assert D[0, 2] >= 1e8            # unreachable remains "inf"


# --- flash attention ---------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,D,causal,dtype",
    [(2, 128, 4, 4, 64, True, jnp.float32),
     (2, 256, 8, 2, 64, True, jnp.bfloat16),
     (1, 256, 15, 5, 64, True, jnp.float32),    # smollm GQA 15/5
     (2, 128, 4, 1, 128, True, jnp.bfloat16),   # MQA
     (2, 128, 4, 4, 64, False, jnp.float32),
     (1, 512, 2, 2, 32, True, jnp.float32)])
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, causal, dtype):
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    o_k = fa_ops.flash_attention(q, k, v, causal)
    o_r = fa_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grads_match_ref():
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)

    def loss_k(q, k, v):
        return (fa_ops.flash_attention(q, k, v) ** 2).sum()

    def loss_r(q, k, v):
        return (fa_ref(q, k, v) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_flash_attention_causality():
    """Changing future K/V must not change past outputs."""
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    o1 = fa_ops.flash_attention(q, k, v, True)
    k2 = k.at[:, 64:].set(99.0)
    v2 = v.at[:, 64:].set(-99.0)
    o2 = fa_ops.flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(np.asarray(o1[:, :64]),
                               np.asarray(o2[:, :64]), atol=1e-6)


# --- ssd scan ----------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,P,N,Q",
    [(2, 64, 4, 32, 16, 16), (1, 128, 2, 64, 32, 32),
     (2, 256, 4, 64, 128, 64), (1, 64, 8, 16, 8, 64),
     (1, 96, 2, 32, 16, 32)])
def test_ssd_matches_ref(B, S, H, P, N, Q):
    xs = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 0.5, (H,)), jnp.float32)
    y_r, h_r = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, Q)
    y_k, h_k = ssd_ops.ssd_chunked(xs, Bm, Cm, dt, A_log, Q)
    scale = max(float(np.abs(np.asarray(y_r)).max()), 1.0)
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_r) / scale, atol=2e-2)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-2)


def test_ssd_chunking_invariance():
    """Different chunk sizes must give the same sequence output."""
    B, S, H, P, N = 1, 128, 2, 32, 16
    xs = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A_log = jnp.zeros((H,), jnp.float32)
    y16, h16 = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, 16)
    y64, h64 = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=3e-2)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64), atol=1e-2)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive per-step recurrence (the definition)."""
    B, S, H, P, N, Q = 1, 32, 2, 8, 4, 8
    xs = np.asarray(rng.standard_normal((B, S, H, P)) * 0.5, np.float32)
    Bm = np.asarray(rng.standard_normal((B, S, N)) * 0.5, np.float32)
    Cm = np.asarray(rng.standard_normal((B, S, N)) * 0.5, np.float32)
    dt = np.asarray(rng.uniform(0.05, 0.3, (B, S, H)), np.float32)
    A_log = np.asarray(rng.uniform(-0.5, 0.5, (H,)), np.float32)

    h = np.zeros((B, H, P, N), np.float64)
    y_seq = np.zeros((B, S, H, P), np.float64)
    A = -np.exp(A_log)
    for t in range(S):
        a_t = np.exp(A[None] * dt[:, t])                     # [B,H]
        upd = np.einsum("bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], xs[:, t])
        h = a_t[..., None, None] * h + upd
        y_seq[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)

    y_c, h_c = ssd_chunked_ref(jnp.asarray(xs), jnp.asarray(Bm),
                               jnp.asarray(Cm), jnp.asarray(dt),
                               jnp.asarray(A_log), Q)
    np.testing.assert_allclose(np.asarray(y_c), y_seq, atol=3e-2)
    np.testing.assert_allclose(np.asarray(h_c), h, atol=1e-2)
