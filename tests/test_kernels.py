"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import resolve_kernel, use_interpret
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention as fa_ref
from repro.kernels.fw_minplus import ops as fw_ops
from repro.kernels.fw_minplus.ref import floyd_warshall_ref
from repro.kernels.seg_waterfill import ops as wf_ops
from repro.kernels.seg_waterfill.ref import seg_waterfill_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan.ref import ssd_chunked_ref

rng = np.random.default_rng(42)

INF = 1e9


def random_adjacency(n, p_edge=0.5, dyadic=False):
    """Symmetric adjacency with INF non-edges and a zero diagonal.

    ``dyadic=True`` draws weights from multiples of 1/64 — path sums of
    dyadic rationals are EXACT in f32, so the blocked kernel's different
    add association cannot round differently and kernel == ref bit-for-bit.
    Arbitrary floats get the documented ~1 ulp tolerance instead
    (docs/kernels.md).
    """
    if dyadic:
        A = (rng.integers(8, 512, (n, n)) / 64.0).astype(np.float32)
    else:
        A = rng.uniform(0.1, 10, (n, n)).astype(np.float32)
    A[rng.uniform(size=(n, n)) < 1 - p_edge] = INF
    A = np.minimum(A, A.T)
    np.fill_diagonal(A, 0.0)
    return A


# --- fw_minplus -------------------------------------------------------------
@pytest.mark.parametrize("n,bs", [(8, 4), (24, 8), (64, 16), (100, 32),
                                  (128, 64), (30, 16)])
def test_fw_matches_ref(n, bs):
    A = random_adjacency(n)
    D_ref = np.asarray(floyd_warshall_ref(jnp.asarray(A)))
    D_k = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=bs))
    np.testing.assert_allclose(D_k, D_ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,bs", [(8, 4), (24, 8), (37, 16), (64, 16),
                                  (100, 32)])
def test_fw_bit_exact_on_dyadic_weights(n, bs):
    """On dyadic-rational weights every path sum is exact, so the blocked
    pivot decomposition must agree with the scan ref BIT-FOR-BIT — the
    ISSUE 6 oracle contract (fp-associativity excuses don't apply here)."""
    A = random_adjacency(n, dyadic=True)
    D_ref = np.asarray(floyd_warshall_ref(jnp.asarray(A)))
    D_k = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=bs))
    np.testing.assert_array_equal(D_k, D_ref)


def test_fw_non_block_multiple_padding_is_invisible():
    """N not a multiple of bs: the INF/0-diag padding must not leak into
    the real block (shortest paths never route through pad nodes)."""
    A = random_adjacency(45, dyadic=True)
    D_ref = np.asarray(floyd_warshall_ref(jnp.asarray(A)))
    for bs in (8, 16, 32, 64):
        D_k = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=bs))
        np.testing.assert_array_equal(D_k, D_ref)


def test_fw_disconnected_stays_inf():
    A = np.full((12, 12), INF, np.float32)
    np.fill_diagonal(A, 0)
    A[0, 1] = A[1, 0] = 1.0          # only one edge
    D = np.asarray(fw_ops.floyd_warshall(jnp.asarray(A), bs=4))
    assert D[0, 1] == 1.0
    assert D[0, 2] >= 1e8            # unreachable remains "inf"


def test_fw_matches_ref_under_vmap():
    """The sweep vmaps the delay refresh over grid cells; the kernel must
    agree with the vmapped ref (bit-for-bit on dyadic weights)."""
    batch = np.stack([random_adjacency(24, dyadic=True) for _ in range(3)])
    A = jnp.asarray(batch)
    D_ref = np.asarray(jax.vmap(floyd_warshall_ref)(A))
    D_k = np.asarray(jax.vmap(
        lambda a: fw_ops.floyd_warshall(a, bs=8))(A))
    np.testing.assert_array_equal(D_k, D_ref)


# --- kernel dispatch --------------------------------------------------------
def test_resolve_kernel_flags():
    assert resolve_kernel("on", backend="cpu") is True
    assert resolve_kernel("off", backend="tpu") is False
    assert resolve_kernel("auto", backend="tpu") is True
    assert resolve_kernel("auto", backend="gpu") is True   # compiled Triton,
    assert resolve_kernel("auto", backend="cpu") is False  # NOT interpreter
    assert resolve_kernel(True, backend="cpu") is True
    with pytest.raises(ValueError):
        resolve_kernel("maybe")


def test_use_interpret_only_on_cpu():
    # the satellite-1 fix: GPU gets the compiled Triton lowering, the
    # interpreter is strictly a CPU test vehicle
    assert use_interpret(backend="cpu") is True
    assert use_interpret(backend="gpu") is False
    assert use_interpret(backend="tpu") is False


# --- seg_waterfill ----------------------------------------------------------
def random_flows(F, E, seed=0, p_active=0.8, p_local=0.1, p_lossy=0.3):
    r = np.random.default_rng(seed)
    links = r.integers(0, E, (F, 4)).astype(np.int32)
    # ECMP lists are -1 padded; local (same-host) flows have NO links
    n_valid = r.integers(0, 5, F)
    links[np.arange(4)[None, :] >= n_valid[:, None]] = -1
    links[r.uniform(size=F) < p_local] = -1
    active = (r.uniform(size=F) < p_active)
    bw = r.uniform(1e3, 1e5, E).astype(np.float32)
    tcp = np.where(r.uniform(size=F) < p_lossy,
                   r.uniform(10, 1e4, F), INF).astype(np.float32)
    return (jnp.asarray(links), jnp.asarray(active), jnp.asarray(bw),
            jnp.asarray(tcp))


def assert_waterfill_matches(links, active, bw, tcp, n_rounds=8):
    r_ref, l_ref = seg_waterfill_ref(links, active, bw, tcp,
                                     n_rounds=n_rounds)
    r_k, l_k = wf_ops.seg_waterfill(links, active, bw, tcp,
                                    n_rounds=n_rounds)
    # rates: identical op order per flow -> bit-for-bit; load: tree-reduce
    # per tile vs segment_sum scatter order -> documented ~1 ulp tolerance
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_ref))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_ref),
                               rtol=2e-6, atol=1e-3)


@pytest.mark.parametrize("F,E,seed", [(5, 7, 0), (33, 16, 1), (200, 40, 2),
                                      (64, 9, 3), (128, 130, 4)])
def test_waterfill_matches_ref(F, E, seed):
    assert_waterfill_matches(*random_flows(F, E, seed=seed))


def test_waterfill_no_active_flows():
    links, _, bw, tcp = random_flows(16, 8, seed=5)
    active = jnp.zeros(16, bool)
    r_k, l_k = wf_ops.seg_waterfill(links, active, bw, tcp)
    assert (np.asarray(r_k) == 0).all()
    assert (np.asarray(l_k) == 0).all()
    assert_waterfill_matches(links, active, bw, tcp)


def test_waterfill_local_flows_get_local_rate():
    """Flows with no links (same-host loopback) freeze at the local rate
    (capped by Mathis), and contribute nothing to any link's load."""
    links = jnp.full((6, 4), -1, jnp.int32)
    active = jnp.ones(6, bool)
    bw = jnp.full(4, 1e4, jnp.float32)
    tcp = jnp.asarray([INF, INF, 100.0, INF, 5e6, 1e3], jnp.float32)
    r_k, l_k = wf_ops.seg_waterfill(links, active, bw, tcp)
    np.testing.assert_array_equal(
        np.asarray(r_k), np.minimum(np.asarray(tcp), 4.0e6))
    assert (np.asarray(l_k) == 0).all()
    assert_waterfill_matches(links, active, bw, tcp)


def test_waterfill_all_lossless_tcp_inf():
    links, active, bw, _ = random_flows(40, 12, seed=6)
    tcp = jnp.full(40, INF, jnp.float32)
    assert_waterfill_matches(links, active, bw, tcp)


def test_waterfill_fewer_rounds_than_bottlenecks():
    """n_rounds=1 exercises the leftover tail (flows never frozen get the
    current fair share) — same rule in kernel and ref."""
    assert_waterfill_matches(*random_flows(50, 6, seed=7), n_rounds=1)


def test_waterfill_matches_ref_under_vmap():
    """The sweep's grid vmap batches every flow-engine input; the kernel
    must stay equal to the ref under vmap (grid-less pallas_call)."""
    packs = [random_flows(48, 10, seed=s) for s in (8, 9, 10)]
    links = jnp.stack([p[0] for p in packs])
    active = jnp.stack([p[1] for p in packs])
    bw = jnp.stack([p[2] for p in packs])
    tcp = jnp.stack([p[3] for p in packs])
    r_ref, l_ref = jax.vmap(seg_waterfill_ref)(links, active, bw, tcp)
    r_k, l_k = jax.vmap(
        lambda *a: wf_ops.seg_waterfill(*a))(links, active, bw, tcp)
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_ref))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_ref),
                               rtol=2e-6, atol=1e-3)


# --- flash attention ---------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,Hq,Hkv,D,causal,dtype",
    [(2, 128, 4, 4, 64, True, jnp.float32),
     (2, 256, 8, 2, 64, True, jnp.bfloat16),
     (1, 256, 15, 5, 64, True, jnp.float32),    # smollm GQA 15/5
     (2, 128, 4, 1, 128, True, jnp.bfloat16),   # MQA
     (2, 128, 4, 4, 64, False, jnp.float32),
     (1, 512, 2, 2, 32, True, jnp.float32)])
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, causal, dtype):
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), dtype)
    o_k = fa_ops.flash_attention(q, k, v, causal)
    o_r = fa_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grads_match_ref():
    q = jnp.asarray(rng.standard_normal((1, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.float32)

    def loss_k(q, k, v):
        return (fa_ops.flash_attention(q, k, v) ** 2).sum()

    def loss_r(q, k, v):
        return (fa_ref(q, k, v) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_flash_attention_causality():
    """Changing future K/V must not change past outputs."""
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 32)), jnp.float32)
    o1 = fa_ops.flash_attention(q, k, v, True)
    k2 = k.at[:, 64:].set(99.0)
    v2 = v.at[:, 64:].set(-99.0)
    o2 = fa_ops.flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(np.asarray(o1[:, :64]),
                               np.asarray(o2[:, :64]), atol=1e-6)


# --- ssd scan ----------------------------------------------------------------
@pytest.mark.parametrize(
    "B,S,H,P,N,Q",
    [(2, 64, 4, 32, 16, 16), (1, 128, 2, 64, 32, 32),
     (2, 256, 4, 64, 128, 64), (1, 64, 8, 16, 8, 64),
     (1, 96, 2, 32, 16, 32)])
def test_ssd_matches_ref(B, S, H, P, N, Q):
    xs = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 0.5, (H,)), jnp.float32)
    y_r, h_r = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, Q)
    y_k, h_k = ssd_ops.ssd_chunked(xs, Bm, Cm, dt, A_log, Q)
    scale = max(float(np.abs(np.asarray(y_r)).max()), 1.0)
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_r) / scale, atol=2e-2)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=1e-2)


def test_ssd_chunking_invariance():
    """Different chunk sizes must give the same sequence output."""
    B, S, H, P, N = 1, 128, 2, 32, 16
    xs = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A_log = jnp.zeros((H,), jnp.float32)
    y16, h16 = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, 16)
    y64, h64 = ssd_chunked_ref(xs, Bm, Cm, dt, A_log, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=3e-2)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64), atol=1e-2)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive per-step recurrence (the definition)."""
    B, S, H, P, N, Q = 1, 32, 2, 8, 4, 8
    xs = np.asarray(rng.standard_normal((B, S, H, P)) * 0.5, np.float32)
    Bm = np.asarray(rng.standard_normal((B, S, N)) * 0.5, np.float32)
    Cm = np.asarray(rng.standard_normal((B, S, N)) * 0.5, np.float32)
    dt = np.asarray(rng.uniform(0.05, 0.3, (B, S, H)), np.float32)
    A_log = np.asarray(rng.uniform(-0.5, 0.5, (H,)), np.float32)

    h = np.zeros((B, H, P, N), np.float64)
    y_seq = np.zeros((B, S, H, P), np.float64)
    A = -np.exp(A_log)
    for t in range(S):
        a_t = np.exp(A[None] * dt[:, t])                     # [B,H]
        upd = np.einsum("bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], xs[:, t])
        h = a_t[..., None, None] * h + upd
        y_seq[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)

    y_c, h_c = ssd_chunked_ref(jnp.asarray(xs), jnp.asarray(Bm),
                               jnp.asarray(Cm), jnp.asarray(dt),
                               jnp.asarray(A_log), Q)
    np.testing.assert_allclose(np.asarray(y_c), y_seq, atol=3e-2)
    np.testing.assert_allclose(np.asarray(h_c), h, atol=1e-2)
