"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values (the FULL configs are exercised only via
the dry-run — ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import transformer
from repro.models.config import SHAPES, cell_is_runnable
from repro.serve.step import _load_prefill, make_decode_step, make_prefill_step
from repro.train.optimizer import OptimizerConfig
from repro.train.step import StepConfig, init_train_state, make_train_step

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def batch_for(cfg, B=B, S=S, with_labels=True):
    rng = np.random.default_rng(0)
    if cfg.frontend == "patch_embeds":
        s_text = S - cfg.n_prefix
        b = {"patch_embeds": jnp.asarray(
                 rng.standard_normal((B, cfg.n_prefix, cfg.d_model)),
                 jnp.bfloat16),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_text)),
                                   jnp.int32)}
        if with_labels:
            b["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (B, s_text)), jnp.int32)
        return b
    if cfg.frontend == "frame_embeds":
        b = {"frame_embeds": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.bfloat16)}
        if with_labels:
            b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)
        return b
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                               jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced(arch)
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, OptimizerConfig()))
    state2, metrics = step(state, batch_for(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    # params actually changed
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(state2.params)[0]
    assert np.abs(np.asarray(l0) - np.asarray(l1)).max() > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases(arch):
    cfg = get_reduced(arch)
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    batch = batch_for(cfg)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_reduced(arch)
    params = transformer.init_params(cfg, KEY)
    pf = jax.jit(make_prefill_step(cfg))
    dc = jax.jit(make_decode_step(cfg))
    batch = batch_for(cfg, with_labels=False)
    tok, logits, cache = pf(params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    full = transformer.init_cache(cfg, B, S + 8)
    full = _load_prefill(cfg, full, cache, S)
    t2, lg, full = dc(params, tok[:, None], full, jnp.array(S, jnp.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert t2.shape == (B,)


@pytest.mark.parametrize("arch", ["smollm_360m", "qwen2_5_3b",
                                  "mamba2_1_3b", "olmoe_1b_7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits position by
    position (KV-cache correctness)."""
    cfg = get_reduced(arch)
    if cfg.family in ("ssm", "hybrid"):
        cfg = cfg  # ssm decode path exercised the same way
    params = transformer.init_params(cfg, KEY)
    rng = np.random.default_rng(1)
    T = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, T)), jnp.int32)

    hidden, _ = transformer.forward_train(cfg, params, {"tokens": toks})
    logits_full = (hidden.astype(jnp.bfloat16)
                   @ params["unembed"].astype(jnp.bfloat16)).astype(
                       jnp.float32)

    # prefill on the first half, decode the second half token by token
    half = T // 2
    _, pf_cache, _ = transformer.prefill(cfg, params,
                                         {"tokens": toks[:, :half]})
    cache = transformer.init_cache(cfg, 1, T)
    cache = _load_prefill(cfg, cache, pf_cache, half)
    for t in range(half, T):
        lg, cache = transformer.decode_step(
            cfg, params, toks[:, t:t + 1], cache,
            jnp.array(t, jnp.int32))
        # decode_step at position t sees tokens[0..t]; forward logits at t
        ref = np.asarray(logits_full[0, t], np.float32)
        got = np.asarray(lg[0], np.float32)
        # compare argmax + correlation (bf16 noise tolerated)
        denom = (np.linalg.norm(ref) * np.linalg.norm(got) + 1e-9)
        corr = float(ref @ got) / denom
        assert corr > 0.99, (arch, t, corr)


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are in the right ballpark for the
    published model sizes (sanity on the exact configs)."""
    expect = {
        "deepseek_v2_236b": (200e9, 280e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "smollm_360m": (0.30e9, 0.50e9),
        "phi4_mini_3_8b": (3.3e9, 4.6e9),
        "minitron_4b": (3.8e9, 5.2e9),
        "qwen2_5_3b": (2.6e9, 3.7e9),
        "zamba2_1_2b": (1.0e9, 1.6e9),
        "paligemma_3b": (2.2e9, 3.3e9),    # text backbone (vision stubbed)
        "musicgen_large": (2.8e9, 3.9e9),
        "mamba2_1_3b": (1.1e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("deepseek_v2_236b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.15 * total            # 21B active vs 236B total
    assert 15e9 <= active <= 32e9


def test_cell_applicability_matrix():
    """40 assigned cells: 32 runnable + 8 documented long-context skips."""
    n_run = n_skip = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            n_run += ok
            n_skip += not ok
            if not ok:
                assert shape.name == "long_500k" and not cfg.sub_quadratic
    assert n_run == 32 and n_skip == 8
