"""Network-model unit + property tests (flow rates, delays, APSP)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import SimConfig
from repro.core.datacenter import build_paper_network
from repro.core.network import (SpineLeafSpec, adjacency_from_links,
                                build_network, congested_link_delay,
                                floyd_warshall_ref, flow_rates,
                                max_min_fair_rates, path_membership,
                                set_link_params, update_delay_matrix)


def net20():
    return build_paper_network(SimConfig())


def test_topology_shapes():
    spec, net = net20()
    assert net.path_links.shape == (20, 20, 4)
    # same-leaf pairs use 2 links, cross-leaf 4
    pn = np.asarray(net.path_nlinks)
    assert pn[0, 4] == 2      # hosts 0 and 4 share leaf 0 (i % 4)
    assert pn[0, 1] == 4
    assert (np.diag(pn) == 0).all()


def test_delay_matrix_symmetric_nonneg():
    spec, net = net20()
    D = np.asarray(net.delay_matrix)
    assert (D >= 0).all()
    np.testing.assert_allclose(D, D.T, atol=1e-5)
    assert (np.diag(D) == 0).all()


def test_fw_equals_path_delay_uncongested():
    """With no congestion the ECMP path delay equals true shortest paths
    (all links equal) — 'path' and 'fw' modes agree."""
    spec, net = net20()
    d_path = update_delay_matrix(net, spec.n_hosts, spec.n_nodes,
                                 mode="path").delay_matrix
    d_fw = update_delay_matrix(net, spec.n_hosts, spec.n_nodes,
                               mode="fw").delay_matrix
    np.testing.assert_allclose(np.asarray(d_path), np.asarray(d_fw),
                               rtol=1e-5)


def test_congestion_increases_delay():
    spec, net = net20()
    base = congested_link_delay(net)
    loaded = congested_link_delay(
        net._replace(link_util=jnp.full_like(net.link_util, 0.9)))
    assert (np.asarray(loaded) > np.asarray(base)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_flows=st.integers(1, 12))
def test_flow_rates_respect_capacity(seed, n_flows):
    """Max-min allocation never oversubscribes any link."""
    spec, net = net20()
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, 20, n_flows), jnp.int32)
    dst = jnp.asarray(rng.integers(0, 20, n_flows), jnp.int32)
    active = jnp.ones((n_flows,), bool)
    rates, util = flow_rates(net, src, dst, active)
    member = path_membership(net.path_links, src, dst, net.link_bw.shape[0])
    bw_kbps = np.asarray(net.link_bw) * 125.0
    load = (np.asarray(member) * np.asarray(rates)[:, None]).sum(0)
    assert (load <= bw_kbps * 1.02 + 1e-3).all()
    assert (np.asarray(rates) >= 0).all()
    assert (np.asarray(util) <= 1.0 + 1e-6).all()


def test_single_flow_gets_full_bandwidth():
    spec, net = net20()
    src = jnp.asarray([0], jnp.int32)
    dst = jnp.asarray([1], jnp.int32)
    rates, _ = flow_rates(net, src, dst, jnp.ones((1,), bool))
    assert abs(float(rates[0]) - 1000.0 * 125.0) < 1.0   # 1 Gbps in KB/s


def test_fair_share_splits_bottleneck():
    spec, net = net20()
    # two flows from the same source host share its uplink
    src = jnp.asarray([0, 0], jnp.int32)
    dst = jnp.asarray([1, 2], jnp.int32)
    rates, _ = flow_rates(net, src, dst, jnp.ones((2,), bool))
    r = np.asarray(rates)
    np.testing.assert_allclose(r[0], r[1], rtol=0.05)
    assert abs(r.sum() - 125000.0) < 125000 * 0.05


def test_loss_throttles_tcp():
    """Mathis bound: a lossy path caps well below the fair share."""
    spec, net = net20()
    lossy = set_link_params(net, loss=0.02)
    src = jnp.asarray([0], jnp.int32)
    dst = jnp.asarray([1], jnp.int32)
    r0, _ = flow_rates(net, src, dst, jnp.ones((1,), bool))
    r1, _ = flow_rates(lossy, src, dst, jnp.ones((1,), bool))
    assert float(r1[0]) < float(r0[0]) * 0.5


def test_same_host_flow_is_local():
    spec, net = net20()
    src = jnp.asarray([3], jnp.int32)
    dst = jnp.asarray([3], jnp.int32)
    rates, util = flow_rates(net, src, dst, jnp.ones((1,), bool))
    assert float(rates[0]) >= 1e6            # loopback rate
    assert float(np.asarray(util).max()) == 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.sampled_from([6, 10, 17]))
def test_fw_ref_properties(seed, n):
    """APSP output: triangle inequality + idempotence."""
    rng = np.random.default_rng(seed)
    A = rng.uniform(0.1, 5.0, (n, n)).astype(np.float32)
    A = np.minimum(A, A.T)
    np.fill_diagonal(A, 0)
    D = np.asarray(floyd_warshall_ref(jnp.asarray(A)))
    D2 = np.asarray(floyd_warshall_ref(jnp.asarray(D)))
    np.testing.assert_allclose(D, D2, rtol=1e-5)     # idempotent
    viol = D[:, :, None] > D[:, None, :] + D[None, :, :] + 1e-4
    assert not viol.any()
