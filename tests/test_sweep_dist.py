"""Multi-host sweep fabric acceptance (PR 8).

The tentpole property: the distributed slab-per-process sweep is a pure
EXECUTION change, never a results change —

* 2 spawned processes x 2 forced CPU devices produce finals and online
  summaries BIT-IDENTICAL to the single-process sweep, in <= 2 compiles
  per process (the oracle CI's ``dist-smoke`` step runs);
* wrap-padded slab partitioning reproduces the unpartitioned sweep
  bit-for-bit under uneven plans: grids not divisible by the slab, slabs
  smaller than a worker's fair share, the 1-cell grid;
* ``stats.online_merge`` (the cross-host reduction) is an exact identity
  over zero partials and matches a direct Welford pass when supports
  overlap;
* a partial run dir RESUMES: completed slabs are skipped and merged even
  when their worker died before writing its meta (orphan adoption).
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core import SimConfig, stats
from repro.core.scenario import ScenarioSpec
from repro.core.types import OnlineSummary
from repro.launch import dist
from repro.launch.sweep import run_sweep

from test_streaming import assert_trees_bitwise_equal

SCEN = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0)]
POLS = ["firstfit", "netaware"]


def tiny_cfg(**kw):
    base = dict(horizon=20, n_jobs=6, n_tasks=12, n_containers=12,
                arrival_window=8.0, placements_per_tick=8,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


def tiny_spec(cfg, *, scenarios=SCEN, policies=POLS, seeds=(0, 1, 2),
              chunk=8, slab=None, devices_per_proc=1):
    return dist.GridSpec.build(
        cfg=cfg, scenarios=scenarios, seeds=seeds, policies=policies,
        n_hosts=6, n_spine=2, n_leaf=4, chunk=chunk, slab=slab,
        overlap=True, devices_per_proc=devices_per_proc)


def reference(spec):
    """The single-process streamed sweep over the same grid (itself pinned
    bit-identical to the stacked sweep by tests/test_streaming.py)."""
    return run_sweep(policies=spec.policy_names(),
                     scenarios=spec.scenario_specs(),
                     seeds=spec.seeds, cfg=spec.sim_config(),
                     n_hosts=spec.n_hosts, n_spine=spec.n_spine,
                     n_leaf=spec.n_leaf, chunk=spec.chunk, slab=spec.slab)


def assert_summary_bitwise(a: OnlineSummary, b: OnlineSummary):
    for name, xa, xb in zip(OnlineSummary._fields, a, b):
        xa, xb = np.asarray(xa), np.asarray(xb)
        assert xa.dtype == xb.dtype and xa.shape == xb.shape, name
        assert (xa == xb).all(), name


# ---------------------------------------------------------------------------
# online_merge: the cross-host reduction
# ---------------------------------------------------------------------------

def _rand_summary(rng, shape):
    n = rng.integers(0, 50, shape)
    xs = [rng.normal(0.5, 0.2, shape) * (n > 0) for _ in range(2)]
    f = lambda x: np.asarray(x, np.float64)
    i = lambda x: np.asarray(x, np.int64)
    return OnlineSummary(
        n_ticks=i(n), sum_util_var=f(xs[0]), sum_mean_util=f(xs[1]),
        sum_flow_rate=f(xs[0] * 3), w_mean_util=f(xs[1] * (n > 0)),
        w_m2_util=f(np.abs(xs[0]) * (n > 0)),
        sum_active_flows=i(n * 2), sum_arrivals=i(n // 2),
        sum_decisions=i(n // 3), sum_migrations=i(n // 5),
        peak_running=i(n % 7), peak_deployed=i(n % 5),
        peak_overloaded=i(n % 3), peak_inactive=i(n % 11),
        sum_soft_comm=f(xs[0] * 2), sum_soft_util=f(xs[1] * 2),
        sum_soft_n=f(n // 2), sum_soft_mig=f(xs[0] * (n > 0)),
        sum_soft_mig_n=f(n // 4))


def test_online_merge_disjoint_support_is_exact_identity():
    # the fabric's invariant: each cell is owned by exactly ONE process,
    # so every merge pairs real data with an n == 0 partial — and that
    # must be bitwise lossless, or distributed != single-process
    rng = np.random.default_rng(0)
    full = _rand_summary(rng, (32,))
    own = rng.random(32) < 0.5
    mask = lambda s, m: OnlineSummary(*(np.where(m, x, x.dtype.type(0))
                                        for x in s))
    a, b = mask(full, own), mask(full, ~own)
    for merged in (stats.online_merge(a, b), stats.online_merge(b, a)):
        assert_summary_bitwise(merged, full)
    # zero is the identity on both sides, and merging in a third zero
    # partial (the 'resumed' owner with no slabs) changes nothing
    zero = stats.online_init((32,))
    assert_summary_bitwise(stats.online_merge(full, zero), full)
    assert_summary_bitwise(stats.online_merge(zero, full), full)
    assert_summary_bitwise(
        stats.online_merge(stats.online_merge(a, zero), b), full)


def test_online_merge_overlapping_matches_direct_welford():
    # general Chan combine (not required by the fabric, but online_merge
    # must be a correct parallel Welford, not just a zero-identity hack)
    rng = np.random.default_rng(1)
    xs = rng.normal(0.4, 0.1, 37)
    def welford(vals):
        mean, m2 = 0.0, 0.0
        for k, v in enumerate(vals):
            d = v - mean
            mean += d / (k + 1)
            m2 += d * (v - mean)
        return OnlineSummary(
            *(np.asarray(x, t) for x, t in zip(
                [len(vals), 0, sum(vals), 0, mean, m2,
                 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0],
                [np.int64] + [np.float64] * 5 + [np.int64] * 8
                + [np.float64] * 5)))
    for split in (1, 13, 36):
        merged = stats.online_merge(welford(xs[:split]), welford(xs[split:]))
        ref = welford(xs)
        assert int(merged.n_ticks) == 37
        np.testing.assert_allclose(merged.w_mean_util, ref.w_mean_util,
                                   rtol=1e-12)
        np.testing.assert_allclose(merged.w_m2_util, ref.w_m2_util,
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(merged.sum_mean_util, ref.sum_mean_util,
                                   rtol=1e-12)


# ---------------------------------------------------------------------------
# GridSpec: the launcher <-> worker contract
# ---------------------------------------------------------------------------

def test_grid_spec_json_roundtrip(tmp_path):
    cfg = tiny_cfg(duration_range=(5.0, 9.0))
    spec = tiny_spec(cfg, slab=5)
    p = str(tmp_path / "spec.json")
    spec.save(p)
    back = dist.GridSpec.load(p)
    assert back.sim_config() == cfg          # tuple fields restored
    assert back.scenario_specs() == spec.scenario_specs()
    assert back.policy_names() == POLS
    np.testing.assert_array_equal(np.asarray(back.policy_params().weights),
                                  np.asarray(spec.policy_params().weights))
    assert back.n_cells == 2 * 2 * 3

    W = np.asarray(spec.policy_params().weights)  # raw-weights variant
    wspec = dist.GridSpec.build(
        cfg=cfg, scenarios=SCEN, seeds=(0,), weights=W, n_hosts=6,
        n_spine=2, n_leaf=4, chunk=8, slab=None, overlap=False,
        devices_per_proc=2)
    wspec.save(p)
    wback = dist.GridSpec.load(p)
    assert wback.policy_names() == ["w000", "w001"]
    np.testing.assert_array_equal(np.asarray(wback.policy_params().weights),
                                  W)
    with pytest.raises(ValueError, match="exactly one"):
        dist.GridSpec.build(cfg=cfg, scenarios=SCEN, seeds=(0,),
                            policies=POLS, weights=W, n_hosts=6, n_spine=2,
                            n_leaf=4, chunk=8, slab=None, overlap=True,
                            devices_per_proc=1)


# ---------------------------------------------------------------------------
# Uneven partitions: wrap-padded slab-per-worker == unpartitioned, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [
    # (slab, worker share of the slab-start list) — B = 12 cells
    (5, [1, 2]),          # B % slab != 0: last slab wraps; uneven 1-vs-2
    (2, [1, 4, 1]),       # slab far below fair share, 3 workers, lopsided
    (12, [1]),            # one worker owns the whole grid in one slab
])
def test_uneven_partitions_bitwise(tmp_path, plan):
    slab, shares = plan
    cfg = tiny_cfg()
    spec = tiny_spec(cfg, slab=slab)
    B = spec.n_cells
    starts = list(range(0, B, dist._slab_cells(B, spec.slab, 1)))
    assert sum(shares) == len(starts), "plan must cover every slab"
    ref = reference(spec)

    out = str(tmp_path / "run")
    k = 0
    for wid, share in enumerate(shares):
        dist.run_worker_inline(spec, out, wid, starts[k:k + share])
        k += share
    finals, summary, metas = dist.merge_out_dir(spec, out)
    assert_trees_bitwise_equal(ref.finals, finals)
    assert_summary_bitwise(ref.summary, summary)
    assert sorted(s for m in metas for s in m["slabs"]) == starts


def test_one_cell_grid_bitwise(tmp_path):
    cfg = tiny_cfg()
    spec = tiny_spec(cfg, scenarios=[SCEN[0]], policies=["netaware"],
                     seeds=(0,), slab=None, devices_per_proc=1)
    assert spec.n_cells == 1
    ref = reference(spec)
    out = str(tmp_path / "run")
    dist.run_worker_inline(spec, out, 0, [0])
    finals, summary, _ = dist.merge_out_dir(spec, out)
    assert_trees_bitwise_equal(ref.finals, finals)
    assert_summary_bitwise(ref.summary, summary)


def test_slab_plan_mismatch_is_loud(tmp_path):
    # a worker whose local device count pads the slab differently than the
    # spec planned must refuse to run, not silently diverge ownership
    spec = tiny_spec(tiny_cfg(), slab=5, devices_per_proc=4)
    with pytest.raises(RuntimeError, match="pad the slab"):
        dist.run_worker_inline(spec, str(tmp_path), 0, [0])


# ---------------------------------------------------------------------------
# Failure semantics: missing slabs, resume, orphan adoption
# ---------------------------------------------------------------------------

def test_resume_skips_done_and_adopts_orphans(tmp_path):
    cfg = tiny_cfg()
    spec = tiny_spec(cfg, slab=5)
    B = spec.n_cells
    starts = list(range(0, B, dist._slab_cells(B, spec.slab, 1)))
    ref = reference(spec)
    out = str(tmp_path / "run")

    # "crashed" first run: one slab completed, but the worker died before
    # writing its meta -> the slab is an orphan on disk
    dist.run_worker_inline(spec, out, 0, starts[:1])
    os.remove(os.path.join(out, "worker_00.json"))
    assert dist.completed_slab_starts(out) == {starts[0]}
    with pytest.raises(RuntimeError, match="incomplete"):
        dist.merge_out_dir(spec, out)

    # resume: a fresh worker takes only the remaining slabs
    remaining = [s for s in starts
                 if s not in dist.completed_slab_starts(out)]
    assert remaining == starts[1:]
    dist.run_worker_inline(spec, out, 1, remaining)
    finals, summary, metas = dist.merge_out_dir(spec, out)
    assert_trees_bitwise_equal(ref.finals, finals)
    assert_summary_bitwise(ref.summary, summary)
    assert [m["process_index"] for m in metas] == [1]   # orphan adopted


def test_merge_rejects_foreign_slab_plan(tmp_path):
    cfg = tiny_cfg()
    spec = tiny_spec(cfg, slab=5)
    out = str(tmp_path / "run")
    dist.run_worker_inline(spec, out, 0,
                           range(0, spec.n_cells,
                                 dist._slab_cells(spec.n_cells, 5, 1)))
    other = dataclasses.replace(spec, slab=4)
    with pytest.raises(RuntimeError, match="different grid/slab plan"):
        dist.merge_out_dir(other, out)


# ---------------------------------------------------------------------------
# The oracle: 2 spawned processes x 2 forced CPU devices, jax.distributed
# ---------------------------------------------------------------------------

def test_dist_sweep_oracle_2proc_2dev(tmp_path):
    """CI's ``dist-smoke``: real ``jax.distributed`` workers, forced
    2-device CPU meshes, dynamic slab handout — finals and summaries
    bit-identical to the single-process run, <= 2 compiles/process."""
    cfg = tiny_cfg()
    out = str(tmp_path / "run")
    ref = run_sweep(policies=POLS, scenarios=SCEN, seeds=(0, 1, 2),
                    cfg=cfg, n_hosts=6, n_spine=2, n_leaf=4, chunk=8,
                    slab=4)
    res = dist.run_dist_sweep(
        policies=POLS, scenarios=SCEN, seeds=(0, 1, 2), cfg=cfg,
        n_hosts=6, n_spine=2, n_leaf=4, num_procs=2, devices_per_proc=2,
        chunk=8, slab=4, out_dir=out, timeout_s=480.0)

    assert_trees_bitwise_equal(ref.finals, res.finals)
    assert_summary_bitwise(ref.summary, res.summary)
    assert res.n_devices == 4
    assert res.compile_cache_misses <= 2, \
        f"{res.compile_cache_misses} compiles/process (want <= 2)"
    for m in res.worker_meta:
        assert m["compile_cache_misses"] <= 2
        assert m["n_local_devices"] == 2
    assert len(res.worker_meta) == 2
    # dynamic handout: every slab assigned exactly once, none lost
    with open(os.path.join(out, "coordinator.json")) as f:
        coord = json.load(f)
    assigned = sorted(s for ss in coord["assignments"].values() for s in ss)
    B = len(POLS) * len(SCEN) * 3
    assert assigned == list(range(0, B, dist._slab_cells(B, 4, 2)))

    # summaries() rides the online summary exactly like the streamed sweep
    rows = res.summaries()
    ref_rows = ref.summaries()
    assert len(rows) == len(ref_rows) == B
    for ra, rb in zip(ref_rows, rows):
        assert ra.keys() == rb.keys()
        for k in ra:
            va, vb = ra[k], rb[k]
            if isinstance(va, float) and np.isnan(va):
                assert np.isnan(vb), k      # nan != nan, but same cell
            else:
                assert va == vb, k
