"""Streaming engine acceptance: chunked == unchunked.

The tentpole property — ``run_sim(chunk=...)`` must be a pure
representation change, never a dynamics change:

* final state bit-for-bit equal to the stacked path, for ALL six
  registered policies, under non-dividing chunk sizes and chunk > horizon;
* integer summary keys (sums, counts, peaks) EXACTLY equal;
* float summary keys equal to ~f32-ulp (Kahan on device + f64 host fold);
* the vmapped/sweep streaming variants agree the same way;
* the f64 fold beats a naive f32 running sum at long synthetic horizons
  (the dtype-audit satellite's regression test).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, get_policy, list_policies, run_sim,
                        summarize)
from repro.core import stats
from repro.core.scenario import ScenarioSpec, build_scenario, build_scenarios
from repro.core.types import OnlineSummary, TickMetrics
from repro.launch.sweep import run_sim_vmapped, run_sweep

SEEDS = (0, 3)

INT_KEYS = ("total_arrivals", "total_decisions", "total_migration_starts",
            "flow_ticks", "peak_running", "peak_deployed", "peak_overloaded",
            "peak_queue", "n_completed", "total_migrations", "n_containers")
FLOAT_KEYS = ("mean_util", "mean_util_variance", "mean_flow_rate",
              "util_time_variance")


def small_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=40,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


def build_small(cfg, seed=0, spec=None):
    spec = spec or ScenarioSpec("baseline")
    net_spec, sims, rp = build_scenario(spec, cfg, n_hosts=8, n_spine=2,
                                        n_leaf=4, seeds=(seed,))
    sim0 = jax.tree.map(lambda x: x[0], sims)
    return net_spec, sim0, rp


def assert_trees_bitwise_equal(a, b):
    for (pa, xa), (_, xb) in zip(jax.tree_util.tree_flatten_with_path(a)[0],
                                 jax.tree_util.tree_flatten_with_path(b)[0]):
        xa, xb = np.asarray(xa), np.asarray(xb)
        assert xa.shape == xb.shape, pa
        assert (xa == xb).all(), f"{pa}: max |delta| = " \
            f"{np.abs(xa.astype(np.float64) - xb.astype(np.float64)).max()}"


def assert_rows_match(stacked, streamed, rtol=3e-6):
    assert stacked.keys() == streamed.keys()
    for k in stacked:
        va, vb = stacked[k], streamed[k]
        if k in INT_KEYS:
            assert va == vb, (k, va, vb)
        elif isinstance(va, float) and isinstance(vb, float):
            if np.isnan(va) and np.isnan(vb):
                continue
            assert va == pytest.approx(vb, rel=rtol), (k, va, vb)
        else:
            assert va == vb, (k, va, vb)


@pytest.mark.parametrize("policy", list_policies())
def test_chunked_equals_stacked_all_policies(policy):
    """Non-dividing chunk (17 into 40): bit-exact state, exact int keys."""
    cfg = small_cfg()
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy(policy)
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_ch, os_ch = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp, chunk=17)
    assert isinstance(os_ch, OnlineSummary)
    assert_trees_bitwise_equal(f_st, f_ch)
    assert_rows_match(summarize(f_st, m_st), summarize(f_ch, os_ch))


@pytest.mark.parametrize("chunk", [1, 7, 40, 64])
def test_chunk_sizes(chunk):
    """Dividing, non-dividing, exact, and > horizon chunk sizes all match."""
    cfg = small_cfg()
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy("netaware")
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_ch, os_ch = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp, chunk=chunk)
    assert int(os_ch.n_ticks) == cfg.horizon
    assert_trees_bitwise_equal(f_st, f_ch)
    assert_rows_match(summarize(f_st, m_st), summarize(f_ch, os_ch))


def test_chunked_does_not_corrupt_sim0():
    """The caller's initial state must stay valid after a chunked run
    (donation copies it first) — launch/sim.py reuses one built state."""
    cfg = small_cfg()
    net_spec, sim0, rp = build_small(cfg)
    before = jax.tree.map(np.array, sim0)
    run_sim(sim0, cfg, get_policy("firstfit"), net_spec.n_hosts,
            net_spec.n_nodes, cfg.horizon, params=rp, chunk=8)
    assert_trees_bitwise_equal(before, sim0)


def test_check_chunk_guard():
    assert stats.max_chunk_ticks(40) == (2**31 - 1) // 80
    with pytest.raises(ValueError, match="chunk must be >= 1"):
        stats.check_chunk(0, 40)
    with pytest.raises(ValueError, match="overflow"):
        stats.check_chunk(stats.max_chunk_ticks(40) + 1, 40)
    stats.check_chunk(stats.max_chunk_ticks(40), 40)   # boundary OK


def test_summarize_key_parity():
    """A streamed run reports EXACTLY the stacked run's summary keys."""
    cfg = small_cfg(horizon=20)
    net_spec, sim0, rp = build_small(cfg)
    pol = get_policy("round")
    f_st, m_st = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, params=rp)
    f_ch, os_ch = run_sim(sim0, cfg, pol, net_spec.n_hosts, net_spec.n_nodes,
                          cfg.horizon, params=rp, chunk=6)
    assert summarize(f_st, m_st).keys() == summarize(f_ch, os_ch).keys()


def test_vmapped_chunked_equals_stacked():
    """Seed-batched streaming (the bench runner) matches the stacked
    vmapped run: bit-exact finals, per-seed summaries to f32 ulp."""
    cfg = small_cfg()
    net_spec, sims, rps = build_scenarios([ScenarioSpec("baseline")], cfg,
                                          n_hosts=8, n_spine=2, n_leaf=4,
                                          seeds=(0, 1, 2))
    sims1 = jax.tree.map(lambda x: x[0], sims)
    rp1 = jax.tree.map(lambda x: x[0], rps)
    pol = get_policy("jobgroup")
    f_st, m_st = run_sim_vmapped(sims1, cfg, pol, net_spec.n_hosts,
                                 net_spec.n_nodes, cfg.horizon, rp1)
    f_ch, os_ch = run_sim_vmapped(sims1, cfg, pol, net_spec.n_hosts,
                                  net_spec.n_nodes, cfg.horizon, rp1,
                                  chunk=13)
    assert_trees_bitwise_equal(f_st, f_ch)
    ref = stats.online_from_metrics(m_st)
    for name in OnlineSummary._fields:
        a, b = np.asarray(getattr(ref, name)), np.asarray(getattr(os_ch, name))
        if a.dtype.kind == "i":
            assert (a == b).all(), name
        else:
            np.testing.assert_allclose(a, b, rtol=3e-6, err_msg=name)


def test_streaming_sweep_equals_stacked_sweep():
    """Full grid through slabs smaller than the grid: finals bit-exact,
    summary rows int-exact / float to f32 ulp, and the one-compiled-step
    property (1 main compile + 1 tail compile at most)."""
    cfg = small_cfg()
    scens = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0)]
    kw = dict(scenarios=scens, seeds=SEEDS, cfg=cfg, n_hosts=8, n_spine=2,
              n_leaf=4)
    st = run_sweep(policies=["firstfit", "netaware"], **kw)
    sm = run_sweep(policies=["firstfit", "netaware"], chunk=17, slab=5, **kw)
    assert sm.metrics is None and isinstance(sm.summary, OnlineSummary)
    assert sm.compile_cache_misses <= 2   # main chunk + tail
    assert_trees_bitwise_equal(st.finals, sm.finals)
    for a, b in zip(st.summaries(), sm.summaries()):
        assert_rows_match(a, b)


def test_online_fold_beats_naive_f32_at_long_horizons():
    """Dtype-audit regression (satellite): the chunked Kahan + f64 fold must
    track the true f64 sum at horizons where a naive f32 running sum has
    visibly drifted.  Synthetic series — ~1e6 'ticks' of mean-util-like
    values — so it runs in milliseconds, no simulation needed."""
    rng = np.random.default_rng(0)
    T, chunk = 1_000_000, 4096
    xs = (0.5 + 0.25 * np.sin(np.arange(T) / 37.0)
          + 0.01 * rng.standard_normal(T)).astype(np.float32)
    true = xs.astype(np.float64).sum()

    # strictly sequential f32 sum (numpy's pairwise .sum() hides the drift)
    naive = jax.jit(lambda v: jax.lax.scan(
        lambda c, x: (c + x, None), jnp.float32(0.0), v)[0])(
            jnp.asarray(xs))

    @jax.jit
    def fold_chunk(acc, block):
        def body(a, m):
            return stats.acc_update(a, m), None
        zeros_i = jnp.zeros((), jnp.int32)
        m = TickMetrics(
            t=jnp.zeros((), jnp.float32), n_overloaded=zeros_i,
            n_inactive=zeros_i, n_running=zeros_i, n_deployed=zeros_i,
            n_communicating=zeros_i, n_waiting=zeros_i, n_completed=zeros_i,
            n_migrating=zeros_i, new_arrivals=zeros_i, decisions=zeros_i,
            migrations=zeros_i, util_variance=jnp.zeros((), jnp.float32),
            mean_util=jnp.zeros((), jnp.float32), active_flows=zeros_i,
            mean_flow_rate=jnp.zeros((), jnp.float32),
            soft_comm=jnp.zeros((), jnp.float32),
            soft_util=jnp.zeros((), jnp.float32),
            soft_n=jnp.zeros((), jnp.float32),
            soft_mig=jnp.zeros((), jnp.float32),
            soft_mig_n=jnp.zeros((), jnp.float32))
        ms = jax.vmap(lambda v: m._replace(mean_util=v))(block)
        acc, _ = jax.lax.scan(body, acc, ms)
        return acc

    online = stats.online_init()
    for i in range(0, T, chunk):
        acc = fold_chunk(stats.acc_init(), jnp.asarray(xs[i:i + chunk]))
        online = stats.online_fold(online, acc)

    err_naive = abs(float(naive) - true)
    err_online = abs(float(online.sum_mean_util) - true)
    assert int(online.n_ticks) == T
    assert err_online < 0.01, err_online          # ~f32-ulp-per-chunk tight
    assert err_naive > 100 * max(err_online, 1e-9), (err_naive, err_online)
    # Welford/Chan variance matches the f64 reference too
    mu = xs.astype(np.float64)
    ref_m2 = ((mu - mu.mean()) ** 2).sum()
    assert float(online.w_m2_util) == pytest.approx(ref_m2, rel=1e-4)


def test_online_init_fields_do_not_alias():
    """Each field must own its buffer — the slab driver writes summaries
    in place, and shared zero arrays silently merge every field."""
    os_ = stats.online_init((4,))
    bufs = [x for x in os_]
    for i, a in enumerate(bufs):
        for b in bufs[i + 1:]:
            assert a is not b
    os_.n_ticks[0] = 7
    assert os_.sum_active_flows[0] == 0
    assert os_.peak_running[0] == 0
