"""Sharding-spec correctness without building 256-device meshes:
every spec must divide its dimension on the PRODUCTION mesh shapes.
(The actual lower+compile proof is the dry-run; this is the fast guard.)"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import sharding as shd
from repro.models import transformer
from repro.models.config import SHAPES, cell_is_runnable
from repro.train.step import init_train_state


class FakeMesh:
    """Stand-in with the production mesh shape (no devices needed)."""

    def __init__(self, multi_pod=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})
        self.axis_names = tuple(self.shape)
        self.size = int(np.prod(list(self.shape.values())))


def axis_size(mesh, ax):
    if isinstance(ax, tuple):
        return int(np.prod([axis_size(mesh, a) for a in ax]))
    return mesh.shape[ax]


def check_divisible(shapes, specs, mesh, where=""):
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                n = axis_size(mesh, ax)
                assert dim % n == 0, (
                    f"{where}{jax.tree_util.keystr(path)}: dim {dim} "
                    f"not divisible by {ax}={n} (shape {leaf.shape})")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divide_production_mesh(arch, multi_pod):
    cfg = get_config(arch)
    mesh = FakeMesh(multi_pod)
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_specs(cfg, shapes, mesh)
    check_divisible(shapes, specs, mesh, where=f"{arch}.")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_divide_production_mesh(arch):
    cfg = get_config(arch)
    mesh = FakeMesh(False)
    for shape in SHAPES.values():
        if shape.kind != "decode" or not cell_is_runnable(cfg, shape)[0]:
            continue
        cache = jax.eval_shape(lambda: transformer.init_cache(
            cfg, shape.global_batch, shape.seq_len))
        specs = shd.cache_specs(cfg, cache, mesh, shape.global_batch)
        check_divisible(cache, specs, mesh, where=f"{arch}.{shape.name}.")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_batch_specs_divide(arch):
    cfg = get_config(arch)
    for multi_pod in (False, True):
        mesh = FakeMesh(multi_pod)
        for shape in SHAPES.values():
            if not cell_is_runnable(cfg, shape)[0]:
                continue
            specs = shd.batch_specs(cfg, shape, mesh)
            for name, spec in specs.items():
                bax = tuple(spec)[0]
                if bax is not None:
                    assert shape.global_batch % axis_size(mesh, bax) == 0


def test_vocab_padding_divides_model_axis():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 16 == 0, arch
        assert cfg.vocab_padded >= cfg.vocab


def test_embed_sharded_over_both_axes():
    """FSDP storage rule: the big tables shard over data AND model."""
    cfg = get_config("phi4_mini_3_8b")
    mesh = FakeMesh(False)
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_specs(cfg, shapes, mesh)
    assert tuple(specs["embed"]) == ("model", "data")
    assert tuple(specs["unembed"]) == ("data", "model")
