"""Kernel dispatch through the PRODUCTION tick (ISSUE 6 acceptance).

The per-kernel oracle sweeps live in test_kernels.py; these tests pin the
*integration*: ``update_delay_matrix(mode='fw', use_kernel=True)`` and the
``flow_rates`` kernel arm against their jnp paths, the SimConfig flag
resolution, and a full ``run_sim`` / vmapped-sweep run with kernels forced
'on' (interpreter-lowered on CPU) vs 'off' at tiny scale.

Tolerances: the fw kernel's blocked pivot decomposition associates path
sums differently from the scan ref (~1 ulp on arbitrary floats — exact on
dyadic weights, see test_kernels.py); the fused waterfill kernel's link
load is tree-reduced per tile vs segment_sum order.  End-to-end runs gate
on behavioral equality (placements, completions, costs) plus tight
allclose on the float state, not bit-equality of every float.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, get_policy, init_sim, paper_workload,
                        run_sim)
from repro.core import network
from repro.core.datacenter import build_paper_network, scaled_hosts


def small_net(n_hosts=12, n_leaf=4, seed=0, congest=True):
    cfg = SimConfig()
    spec, net = build_paper_network(cfg, n_hosts=n_hosts, n_leaf=n_leaf)
    if congest:  # non-trivial congestion so the refresh has signal
        r = np.random.default_rng(seed)
        util = r.uniform(0.0, 0.9, net.link_util.shape).astype(np.float32)
        net = net._replace(link_util=jnp.asarray(util))
    return spec, net


def test_update_delay_matrix_fw_kernel_matches_ref():
    spec, net = small_net()
    out_ref = network.update_delay_matrix(net, spec.n_hosts, spec.n_nodes,
                                          mode="fw", use_kernel=False)
    out_k = network.update_delay_matrix(net, spec.n_hosts, spec.n_nodes,
                                        mode="fw", use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k.delay_matrix),
                               np.asarray(out_ref.delay_matrix),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_k.comm_cost),
                               np.asarray(out_ref.comm_cost),
                               rtol=1e-5, atol=1e-4)


def test_update_delay_matrix_fw_kernel_matches_ref_under_vmap():
    nets = [small_net(seed=s)[1] for s in range(3)]
    spec, _ = small_net()
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *nets)

    def refresh(net, use_kernel):
        return network.update_delay_matrix(
            net, spec.n_hosts, spec.n_nodes, mode="fw",
            use_kernel=use_kernel).delay_matrix

    d_ref = jax.vmap(lambda n: refresh(n, False))(batched)
    d_k = jax.vmap(lambda n: refresh(n, True))(batched)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=1e-5, atol=1e-4)


def test_flow_rates_kernel_arm_matches_jnp():
    spec, net = small_net()
    r = np.random.default_rng(1)
    F = 40
    src = jnp.asarray(r.integers(0, spec.n_hosts, F), jnp.int32)
    dst = jnp.asarray(r.integers(0, spec.n_hosts, F), jnp.int32)
    active = jnp.asarray(r.uniform(size=F) < 0.7)
    rates_ref, util_ref = network.flow_rates(net, src, dst, active,
                                             use_kernel=False)
    rates_k, util_k = network.flow_rates(net, src, dst, active,
                                         use_kernel=True)
    np.testing.assert_array_equal(np.asarray(rates_k),
                                  np.asarray(rates_ref))
    np.testing.assert_allclose(np.asarray(util_k), np.asarray(util_ref),
                               rtol=2e-6, atol=1e-6)


def tiny_cfg(**kw):
    base = dict(n_jobs=8, n_tasks=24, n_containers=24, horizon=12,
                arrival_window=6.0, placements_per_tick=8,
                migrations_per_tick=2, delay_mode="fw")
    base.update(kw)
    return SimConfig(**base)


def run_tiny(cfg, n_hosts=12, seed=0):
    hosts = scaled_hosts(n_hosts, 4)
    spec, net = build_paper_network(cfg, n_hosts=n_hosts, n_leaf=4)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    return run_sim(sim0, cfg, get_policy("netaware"), spec.n_hosts,
                   spec.n_nodes, cfg.horizon)


@pytest.mark.parametrize("policy_irrelevant_seed", [0, 3])
def test_run_sim_kernels_on_equals_off(policy_irrelevant_seed):
    """Full tick scan across a delay refresh (horizon 12 > interval 10):
    kernels forced 'on' (interpreter on CPU) must reproduce the 'off' run's
    behavior — same placements, completions, cost — with float state tight."""
    seed = policy_irrelevant_seed
    f_off, m_off = run_tiny(tiny_cfg(delay_kernel="off",
                                     waterfill_kernel="off"), seed=seed)
    f_on, m_on = run_tiny(tiny_cfg(delay_kernel="on",
                                   waterfill_kernel="on"), seed=seed)
    np.testing.assert_array_equal(np.asarray(f_on.containers.status),
                                  np.asarray(f_off.containers.status))
    np.testing.assert_array_equal(np.asarray(f_on.containers.host),
                                  np.asarray(f_off.containers.host))
    np.testing.assert_allclose(np.asarray(f_on.total_cost),
                               np.asarray(f_off.total_cost), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(f_on.net.delay_matrix),
                               np.asarray(f_off.net.delay_matrix),
                               rtol=1e-5, atol=1e-4)
    for a, b in zip(jax.tree.leaves(m_on), jax.tree.leaves(m_off)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-4)


def test_run_sim_auto_resolves_to_ref_on_cpu():
    """'auto' on CPU must take the jnp reference path — bit-identical to an
    explicit 'off' run (the dispatch rule benchmarks rely on: CPU rows with
    kernels='auto' measure the production ref, not the interpreter)."""
    if jax.default_backend() != "cpu":
        pytest.skip("dispatch-identity check is CPU-specific")
    f_auto, _ = run_tiny(tiny_cfg(delay_kernel="auto",
                                  waterfill_kernel="auto"))
    f_off, _ = run_tiny(tiny_cfg(delay_kernel="off",
                                 waterfill_kernel="off"))
    for a, b in zip(jax.tree.leaves(f_auto), jax.tree.leaves(f_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_vmapped_sweep_with_kernels_on_matches_off():
    """Kernels must survive the sweep's seed vmap inside the full tick."""
    from repro.launch.sweep import run_sim_vmapped

    def batch(cfg, seeds=(0, 1)):
        hosts = scaled_hosts(12, 4)
        spec, net = build_paper_network(cfg, n_hosts=12, n_leaf=4)
        sims = [init_sim(hosts, paper_workload(cfg, seed=s), net, seed=s)
                for s in seeds]
        sims = jax.tree.map(lambda *xs: jnp.stack(xs), *sims)
        return run_sim_vmapped(sims, cfg, get_policy("netaware"),
                               spec.n_hosts, spec.n_nodes, cfg.horizon)

    f_on, _ = batch(tiny_cfg(delay_kernel="on", waterfill_kernel="on"))
    f_off, _ = batch(tiny_cfg(delay_kernel="off", waterfill_kernel="off"))
    np.testing.assert_array_equal(np.asarray(f_on.containers.status),
                                  np.asarray(f_off.containers.status))
    np.testing.assert_allclose(np.asarray(f_on.net.delay_matrix),
                               np.asarray(f_off.net.delay_matrix),
                               rtol=1e-5, atol=1e-4)


def test_simconfig_rejects_nothing_but_cache_keys_change():
    """Kernel flags are static config: two flags -> two distinct configs
    (hashable, usable as jit cache keys), same shapes."""
    a = tiny_cfg(delay_kernel="auto")
    b = dataclasses.replace(a, delay_kernel="on")
    assert a != b and hash(a) != hash(b)
