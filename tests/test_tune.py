"""Weight search (repro.launch.tune): the search population must ride the
compiled sweep — weights on the policy batch axis, ONE jit — and emit a
ranked best-weights table."""
import numpy as np
import pytest

from repro.core import NUM_POLICY_WEIGHTS, WEIGHT_NAMES, SimConfig, get_policy
from repro.core.scenario import ScenarioSpec
from repro.launch.tune import (DEFAULT_SPACE, TuneResult, run_tune,
                               sample_weights)


def small_cfg():
    return SimConfig(n_jobs=10, n_tasks=40, n_containers=40, horizon=30,
                     arrival_window=10.0, placements_per_tick=16,
                     migrations_per_tick=2)


@pytest.fixture(scope="module")
def tune_result() -> TuneResult:
    return run_tune(n_samples=5, seeds=(0,),
                    scenarios=[ScenarioSpec("baseline"),
                               ScenarioSpec("slow_net", bw=200.0)],
                    cfg=small_cfg(), objective="avg_runtime")


def test_tune_compiles_once(tune_result):
    """5 weight samples x 2 scenarios x 1 seed = 10 cells, one XLA
    compilation — weights are the policy axis of the sweep program."""
    assert tune_result.compile_cache_misses == 1
    assert tune_result.scores.shape == (5,)
    assert len(tune_result.rows) == 10


def test_tune_keeps_incumbent_and_ranks(tune_result):
    """Sample 0 is the untouched base policy; the best sample's score is
    the minimum of all finite scores (avg_runtime minimizes)."""
    base = np.asarray(get_policy("netaware").weights)
    np.testing.assert_array_equal(tune_result.weights[0], base)
    assert tune_result.minimize
    s = tune_result.scores
    finite = s[np.isfinite(s)]
    assert finite.size > 0
    assert s[tune_result.best] == finite.min()


def test_tune_table_lists_searched_dimensions(tune_result):
    table = tune_result.table()
    assert "w000" in table
    for name in DEFAULT_SPACE:
        assert name in table, name
    bw = tune_result.best_weights()
    assert set(bw) == set(WEIGHT_NAMES)


def test_sample_weights_shapes_and_grid():
    W = sample_weights(8, seed=1)
    assert W.shape == (8, NUM_POLICY_WEIGHTS)
    base = np.asarray(get_policy("netaware").weights)
    np.testing.assert_array_equal(W[0], base)
    # searched dims vary, unsearched dims stay at the base vector
    for j, name in enumerate(WEIGHT_NAMES):
        col = W[:, j]
        if name not in DEFAULT_SPACE:
            assert (col == base[j]).all(), name
    # grid mode: each non-base sample perturbs exactly one dimension
    G = sample_weights(9, base="netaware", grid=True)
    np.testing.assert_array_equal(G[0], base)
    for i in range(1, 9):
        assert (G[i] != base).sum() <= 1


def test_tune_objective_direction():
    """Maximize-metrics keep their TRUE sign in scores/table/JSON; only
    the ranking direction flips (the review caught the earlier design
    leaking negated values into every user-facing output)."""
    res = run_tune(n_samples=3, seeds=(0,),
                   scenarios=[ScenarioSpec("baseline")], cfg=small_cfg(),
                   objective="completion_rate")
    assert not res.minimize
    rates = {r["policy"]: r["completion_rate"] for r in res.rows}
    for i in range(3):
        assert res.scores[i] == rates[f"w{i:03d}"]       # true sign
    finite = res.scores[np.isfinite(res.scores)]
    assert res.scores[res.best] == finite.max()          # ranked descending
    assert "higher = better" in res.table()
