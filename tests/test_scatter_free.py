"""Scatter-free tick unit oracles (PR 4; PR 5 removed the deprecated
``cfg.scatter_tick`` full-tick fork after its one promised cycle).

The tick expresses every ``.at[idx].set/add`` state update as a where-mask
or a segment reduction so all sweep axes ``vmap`` (docs/perf.md).  The
cheap unit oracles that don't fork the tick are kept here: the rank-key
inverse permutation vs its scatter form, the same-job host-count
segment-sum vs the per-candidate scatter-adds, and the segment-min
adjacency vs the ``.at[u, v].min`` build.  (Full-run semantics are pinned
by tests/test_policy_equivalence.py against the PR 4 switch-based scoring
reference, and by the sweep cell == standalone equalities in
tests/test_sweep.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SimConfig, build_paper_network
from repro.core.network import adjacency_from_links
from repro.core.scenario import ScenarioSpec, build_scenario
from repro.core.scheduling import (INT_BIG, rank_key, same_job_host_counts,
                                   same_job_host_counts_scatter)
from repro.core.types import (STATUS_COMMUNICATING, STATUS_INACTIVE,
                              STATUS_RUNNING)


def make_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=60,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


def test_scatter_tick_flag_is_gone():
    """PR 4 kept the scatter-based tick ONE deprecation cycle behind
    ``cfg.scatter_tick``; passing it must now fail loudly, not silently
    run the default tick."""
    import pytest
    with pytest.raises(TypeError):
        make_cfg(scatter_tick=True)


def test_rank_key_is_inverse_permutation_of_argsort():
    """The double-argsort rank must equal the former
    ``zeros.at[order].set(arange)`` scatter exactly, ties and all."""
    rng = np.random.default_rng(7)
    for _ in range(5):
        C = 257
        values = jnp.asarray(
            rng.choice([0.0, 1.0, 2.5, 1e6], size=C).astype(np.float32))
        mask = jnp.asarray(rng.random(C) < 0.7)
        got = np.asarray(rank_key(values, mask))
        order = jnp.argsort(values, stable=True)
        want = np.asarray(jnp.where(
            mask,
            jnp.zeros((C,), jnp.int32).at[order].set(
                jnp.arange(C, dtype=jnp.int32)),
            INT_BIG))
        np.testing.assert_array_equal(got, want)


def test_same_job_host_counts_matches_scatter_oracle():
    """Segment-sum [K, H] table == the PR 2 per-candidate scatter-adds,
    including candidates sharing a job and undeployed/-1-job rows."""
    rng = np.random.default_rng(11)
    cfg = make_cfg()
    net_spec, sims, _ = build_scenario(ScenarioSpec("baseline"), cfg,
                                       seeds=(3,))
    sim = jax.tree.map(lambda x: x[0], sims)
    ct = sim.containers
    C = ct.status.shape[0]
    H = sim.hosts.cap.shape[0]
    status = rng.choice([STATUS_INACTIVE, STATUS_RUNNING,
                         STATUS_COMMUNICATING], size=C).astype(np.int32)
    host = rng.integers(-1, H, size=C).astype(np.int32)
    sim = sim._replace(containers=ct._replace(
        status=jnp.asarray(status), host=jnp.asarray(host)))
    for _ in range(4):
        cand = jnp.asarray(rng.integers(0, C, size=16).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(same_job_host_counts(sim, cand)),
            np.asarray(same_job_host_counts_scatter(sim, cand)))


def test_leafpeers_incremental_matches_recompute():
    """The F_CROSS_LEAF numerator is maintained by elementwise adds in the
    admit scan (a per-step segment_sum would be a batched scatter in the
    hot loop); after any admit sequence it must equal the from-scratch
    per-leaf reduction of the carried counts."""
    from repro.core import get_policy
    from repro.core.scheduling import init_place_carry, update_place_carry

    rng = np.random.default_rng(5)
    cfg = make_cfg()
    net_spec, sims, _ = build_scenario(ScenarioSpec("baseline"), cfg,
                                       seeds=(0,))
    sim = jax.tree.map(lambda x: x[0], sims)
    ct = sim.containers
    C = ct.status.shape[0]
    H = sim.hosts.cap.shape[0]
    status = rng.choice([STATUS_INACTIVE, STATUS_RUNNING], size=C)
    host = rng.integers(-1, H, size=C).astype(np.int32)
    sim = sim._replace(containers=ct._replace(
        status=jnp.asarray(status.astype(np.int32)), host=jnp.asarray(host)))
    cand = jnp.asarray(rng.integers(0, C, size=8).astype(np.int32))
    pol = get_policy("round")
    carry = init_place_carry(sim, cand, pol)
    leaf = np.asarray(sim.hosts.leaf)

    def recompute(counts):
        out = np.zeros_like(counts)
        for k in range(counts.shape[0]):
            per_leaf = np.zeros(H)
            np.add.at(per_leaf, leaf, counts[k])
            out[k] = per_leaf[leaf]
        return out

    np.testing.assert_array_equal(np.asarray(carry.leafpeers),
                                  recompute(np.asarray(carry.counts)))
    for k in range(6):          # admit a few candidates onto random hosts
        hh = jnp.asarray(int(rng.integers(0, H)), jnp.int32)
        carry = update_place_carry(sim, pol, carry, k, cand, hh,
                                   jnp.asarray(True))
        np.testing.assert_array_equal(np.asarray(carry.leafpeers),
                                      recompute(np.asarray(carry.counts)))


def test_adjacency_segment_min_matches_scatter_build():
    cfg = SimConfig()
    spec, net = build_paper_network(cfg)
    delay = net.link_delay * 3.0 + 0.01
    got = adjacency_from_links(net, delay, spec.n_nodes)
    n = spec.n_nodes
    A = jnp.full((n, n), jnp.float32(1e9))
    A = A.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    A = A.at[net.link_u, net.link_v].min(delay)
    A = A.at[net.link_v, net.link_u].min(delay)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(A))
