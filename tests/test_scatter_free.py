"""Scatter-free tick acceptance (PR 4).

The default tick replaces every ``.at[idx].set/add`` state-update scatter
with where-masks / segment reductions so all three sweep axes can ``vmap``
(docs/perf.md).  ``cfg.scatter_tick=True`` keeps the PR 3 scatter updates
for one deprecation cycle as the oracle: a full mixed bursty-arrival run
must agree BIT-FOR-BIT across the two paths for every registered policy —
every masked form is either a single-index update (identical float
operands) or an integer-valued / shared reduction, so there is no rounding
to hide behind.

Plus unit oracles for the shared scatter-free helpers (rank_key inverse
permutation, same-job host counts, segment-min adjacency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, build_paper_network, get_policy,
                        list_policies, run_sim)
from repro.core.network import adjacency_from_links
from repro.core.scenario import ScenarioSpec, build_scenario
from repro.core.scheduling import (INT_BIG, rank_key, same_job_host_counts,
                                   same_job_host_counts_scatter)
from repro.core.types import (STATUS_COMMUNICATING, STATUS_INACTIVE,
                              STATUS_RUNNING)


def make_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=60,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


MIXED_BURSTY = ScenarioSpec("mixed_bursty", arrival="bursty",
                            host_mix="premium", bw=300.0)


@pytest.mark.parametrize("policy", list_policies())
def test_scatter_free_tick_matches_scatter_oracle_bitwise(policy):
    """Full-run state AND metrics, every leaf, np.array_equal — on a mixed
    bursty scenario that exercises placement, co-location scoring,
    communication stalls, migration and completion."""
    outs = {}
    for scat in (False, True):
        cfg = make_cfg(scatter_tick=scat)
        net_spec, sims, rp = build_scenario(MIXED_BURSTY, cfg, seeds=(0,))
        sim0 = jax.tree.map(lambda x: x[0], sims)
        outs[scat] = run_sim(sim0, cfg, get_policy(policy), net_spec.n_hosts,
                             net_spec.n_nodes, cfg.horizon, params=rp)
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=policy)


def test_scatter_free_tick_matches_on_sequential_path():
    """The sequential reference path (K=1 degenerate rounds) gates its
    deploy scatters on the same flag."""
    outs = {}
    for scat in (False, True):
        cfg = make_cfg(scatter_tick=scat, batched_placement=False)
        net_spec, sims, rp = build_scenario(MIXED_BURSTY, cfg, seeds=(1,))
        sim0 = jax.tree.map(lambda x: x[0], sims)
        outs[scat] = run_sim(sim0, cfg, get_policy("round"), net_spec.n_hosts,
                             net_spec.n_nodes, cfg.horizon, params=rp)
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rank_key_is_inverse_permutation_of_argsort():
    """The double-argsort rank must equal the former
    ``zeros.at[order].set(arange)`` scatter exactly, ties and all."""
    rng = np.random.default_rng(7)
    for _ in range(5):
        C = 257
        values = jnp.asarray(
            rng.choice([0.0, 1.0, 2.5, 1e6], size=C).astype(np.float32))
        mask = jnp.asarray(rng.random(C) < 0.7)
        got = np.asarray(rank_key(values, mask))
        order = jnp.argsort(values, stable=True)
        want = np.asarray(jnp.where(
            mask,
            jnp.zeros((C,), jnp.int32).at[order].set(
                jnp.arange(C, dtype=jnp.int32)),
            INT_BIG))
        np.testing.assert_array_equal(got, want)


def test_same_job_host_counts_matches_scatter_oracle():
    """Segment-sum [K, H] table == the PR 2 per-candidate scatter-adds,
    including candidates sharing a job and undeployed/-1-job rows."""
    rng = np.random.default_rng(11)
    cfg = make_cfg()
    net_spec, sims, _ = build_scenario(ScenarioSpec("baseline"), cfg,
                                       seeds=(3,))
    sim = jax.tree.map(lambda x: x[0], sims)
    ct = sim.containers
    C = ct.status.shape[0]
    H = sim.hosts.cap.shape[0]
    status = rng.choice([STATUS_INACTIVE, STATUS_RUNNING,
                         STATUS_COMMUNICATING], size=C).astype(np.int32)
    host = rng.integers(-1, H, size=C).astype(np.int32)
    sim = sim._replace(containers=ct._replace(
        status=jnp.asarray(status), host=jnp.asarray(host)))
    for _ in range(4):
        cand = jnp.asarray(rng.integers(0, C, size=16).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(same_job_host_counts(sim, cand)),
            np.asarray(same_job_host_counts_scatter(sim, cand)))


def test_adjacency_segment_min_matches_scatter_build():
    cfg = SimConfig()
    spec, net = build_paper_network(cfg)
    delay = net.link_delay * 3.0 + 0.01
    got = adjacency_from_links(net, delay, spec.n_nodes)
    n = spec.n_nodes
    A = jnp.full((n, n), jnp.float32(1e9))
    A = A.at[jnp.arange(n), jnp.arange(n)].set(0.0)
    A = A.at[net.link_u, net.link_v].min(delay)
    A = A.at[net.link_v, net.link_u].min(delay)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(A))


def test_scatter_free_fw_delay_mode_matches():
    """'fw' delay mode runs the rewritten adjacency + APSP inside the tick."""
    outs = {}
    for scat in (False, True):
        cfg = make_cfg(scatter_tick=scat, delay_mode="fw", horizon=30)
        net_spec, sims, rp = build_scenario(ScenarioSpec("baseline"), cfg,
                                            seeds=(0,))
        sim0 = jax.tree.map(lambda x: x[0], sims)
        outs[scat] = run_sim(sim0, cfg, get_policy("netaware"),
                             net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                             params=rp)
    for a, b in zip(jax.tree.leaves(outs[False]), jax.tree.leaves(outs[True])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
