"""Branch-free scoring acceptance (PR 5).

Every legacy policy's weight vector must reproduce the PR 4
switch-dispatched run BIT-FOR-BIT.  The PR 4 per-policy hook
implementations (select / carry init / host row / carry update / migrate)
are embedded here verbatim as the reference: the engine is run once with
``repro.core.scheduling``'s generic weighted hooks monkeypatched to the
reference closures (plain Python dispatch — one policy at a time needs no
``lax.switch``) under a FRESH ``jax.jit`` trace, and once through the
normal weight-vector path — full final state AND per-tick metrics, every
leaf, ``np.array_equal``, on a mixed bursty-arrival premium-host scenario
that exercises placement, co-location scoring, communication stalls,
migration and completion.

The equivalence is exact by construction: each legacy vector is one-hot
(or disjoint-support) over features computed with the same ops as the old
rows, every feature is finite, and a zero weight contributes an exact 0.0
to the score dot product.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, get_policy, list_policies
from repro.core import scheduling as sched
from repro.core.engine import simulate
from repro.core.network import path_util_row
from repro.core.scenario import ScenarioSpec, build_scenario
from repro.core.scheduling import (PlaceCarry, _first_true, _migration_pair,
                                   _overload_source, _worst_fit_row,
                                   same_job_host_counts, select_key_fifo)

LEGACY = ["firstfit", "round", "performance_first", "jobgroup", "netaware",
          "overload_migrate"]

MIXED_BURSTY = ScenarioSpec("mixed_bursty", arrival="bursty",
                            host_mix="premium", bw=300.0)


def make_cfg(**kw):
    base = dict(n_jobs=10, n_tasks=40, n_containers=40, horizon=60,
                arrival_window=10.0, placements_per_tick=16,
                migrations_per_tick=2)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# The PR 4 branches, verbatim (modulo the hook signatures the engine calls)
# ---------------------------------------------------------------------------
def _row_firstfit(sim, cfg, params, w, carry, k, cand, used):
    return jnp.arange(sim.hosts.cap.shape[0], dtype=jnp.float32)


def _row_performance_first(sim, cfg, params, w, carry, k, cand, used):
    return -sim.hosts.speed[:, sim.containers.ctype[cand[k]]]


def _row_round(sim, cfg, params, w, carry, k, cand, used):
    H = sim.hosts.cap.shape[0]
    return jnp.mod(jnp.arange(H) - carry.rr - 1, H).astype(jnp.float32)


def _row_jobgroup(sim, cfg, params, w, carry, k, cand, used):
    cnt = carry.counts[k]
    return jnp.where(cnt.sum() > 0, -cnt, _worst_fit_row(sim, used))


def _row_netaware(sim, cfg, params, w, carry, k, cand, used):
    cnt = carry.counts[k]
    cost = cnt @ sim.net.comm_cost
    return jnp.where(cnt.sum() > 0, cost / jnp.maximum(cnt.sum(), 1.0),
                     _worst_fit_row(sim, used))


def _zero_counts(sim, cand):
    return jnp.zeros((cand.shape[0], sim.hosts.cap.shape[0]), jnp.float32)


# PR 4's PlaceCarry had (rr, counts); the generic carry adds the per-leaf
# peer totals for the F_CROSS_LEAF tuning feature.  The reference hooks
# zero it — no PR 4 branch reads it, and the engine only threads the carry
# through these hooks — so the pytree structure matches without changing
# reference semantics.
def _init_static(sim, cand):
    return PlaceCarry(rr=sim.sched.rr_pointer,
                      counts=_zero_counts(sim, cand),
                      leafpeers=_zero_counts(sim, cand))


def _init_coloc(sim, cand):
    return PlaceCarry(rr=sim.sched.rr_pointer,
                      counts=same_job_host_counts(sim, cand),
                      leafpeers=_zero_counts(sim, cand))


def _update_noop(sim, carry, k, cand, hh, ok):
    return carry


def _update_round(sim, carry, k, cand, hh, ok):
    return carry._replace(rr=jnp.where(ok, hh, carry.rr))


def _update_coloc(sim, carry, k, cand, hh, ok):
    same = sim.containers.job[cand] == sim.containers.job[cand[k]]
    hot = (jnp.arange(carry.counts.shape[1]) == hh) & ok
    return carry._replace(counts=jnp.where(
        hot[None, :] & same[:, None], carry.counts + 1.0, carry.counts))


def _migrate_none(sim, cfg, params):
    minus1 = jnp.full((), -1, jnp.int32)
    return minus1, minus1


def _migrate_overload(sim, cfg, params):
    src, cont, src_c, dst_mask = _overload_source(sim, cfg, params)
    H = dst_mask.shape[0]
    dst = _first_true(jnp.arange(H, dtype=jnp.float32), dst_mask)
    return _migration_pair(src, cont, dst)


def _migrate_congestion(sim, cfg, params):
    src, cont, src_c, dst_mask = _overload_source(sim, cfg, params)
    dst = _first_true(path_util_row(sim.net, src_c), dst_mask)
    return _migration_pair(src, cont, dst)


# PR 4 registry: name -> (row, init, update, migrate)
PR4_DEFS = {
    "firstfit": (_row_firstfit, _init_static, _update_noop, _migrate_none),
    "round": (_row_round, _init_static, _update_round, _migrate_none),
    "performance_first": (_row_performance_first, _init_static,
                          _update_noop, _migrate_none),
    "jobgroup": (_row_jobgroup, _init_coloc, _update_coloc, _migrate_none),
    "netaware": (_row_netaware, _init_coloc, _update_coloc,
                 _migrate_congestion),
    "overload_migrate": (_row_firstfit, _init_static, _update_noop,
                         _migrate_overload),
}


def run_reference(policy, cfg, sim0, net_spec, rp, monkeypatch):
    """Run the engine with the PR 4 hooks for ONE policy (plain Python
    dispatch) under a fresh jit — the jit must be fresh because the
    module-level ``run_sim`` cache is keyed on config/shapes only and
    would otherwise replay the generic weighted trace."""
    row, init, update, mig = PR4_DEFS[policy]
    pol = get_policy(policy)
    with monkeypatch.context() as m:
        m.setattr(sched, "select_key", lambda sim, p: select_key_fifo(sim))
        m.setattr(sched, "init_place_carry",
                  lambda sim, cand, p: init(sim, cand))
        m.setattr(sched, "host_row",
                  lambda sim, cfg_, params, p, carry, k, cand, used:
                  row(sim, cfg_, params, p.weights, carry, k, cand, used))
        m.setattr(sched, "update_place_carry",
                  lambda sim, p, carry, k, cand, hh, ok:
                  update(sim, carry, k, cand, hh, ok))
        m.setattr(sched, "migrate",
                  lambda sim, cfg_, params, p: mig(sim, cfg_, params))
        fn = jax.jit(lambda s: simulate(s, cfg, pol, net_spec.n_hosts,
                                        net_spec.n_nodes, cfg.horizon, rp))
        out = fn(sim0)
        jax.tree.leaves(out)[0].block_until_ready()
    return out


def run_weighted(policy, cfg, sim0, net_spec, rp):
    pol = get_policy(policy)
    fn = jax.jit(lambda s: simulate(s, cfg, pol, net_spec.n_hosts,
                                    net_spec.n_nodes, cfg.horizon, rp))
    out = fn(sim0)
    jax.tree.leaves(out)[0].block_until_ready()
    return out


def assert_trees_equal(got, want, msg):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=msg)


def test_all_legacy_policies_registered():
    assert set(LEGACY) <= set(list_policies())


@pytest.mark.parametrize("policy", LEGACY)
def test_weight_vector_matches_pr4_switch_run_bitwise(policy, monkeypatch):
    """Full-run state AND metrics, every leaf, np.array_equal — weighted
    scoring vs the PR 4 per-policy branches."""
    cfg = make_cfg()
    net_spec, sims, rp = build_scenario(MIXED_BURSTY, cfg, seeds=(0,))
    sim0 = jax.tree.map(lambda x: x[0], sims)
    want = run_reference(policy, cfg, sim0, net_spec, rp, monkeypatch)
    got = run_weighted(policy, cfg, sim0, net_spec, rp)
    assert_trees_equal(got, want, policy)


def test_weighted_matches_pr4_on_sequential_path(monkeypatch):
    """The sequential reference path (K=1 degenerate rounds) consumes the
    same hooks — the equivalence must hold there too."""
    cfg = make_cfg(batched_placement=False)
    net_spec, sims, rp = build_scenario(MIXED_BURSTY, cfg, seeds=(1,))
    sim0 = jax.tree.map(lambda x: x[0], sims)
    want = run_reference("round", cfg, sim0, net_spec, rp, monkeypatch)
    got = run_weighted("round", cfg, sim0, net_spec, rp)
    assert_trees_equal(got, want, "round/sequential")


def test_weighted_matches_pr4_fw_delay_mode(monkeypatch):
    """'fw' delay mode runs the full APSP refresh inside the tick; the
    comm-cost table the netaware score reads must still be identical."""
    cfg = make_cfg(delay_mode="fw", horizon=30)
    net_spec, sims, rp = build_scenario(ScenarioSpec("baseline"), cfg,
                                        seeds=(0,))
    sim0 = jax.tree.map(lambda x: x[0], sims)
    want = run_reference("netaware", cfg, sim0, net_spec, rp, monkeypatch)
    got = run_weighted("netaware", cfg, sim0, net_spec, rp)
    assert_trees_equal(got, want, "netaware/fw")


def test_migration_wrappers_match_generic():
    """overload_migrate / congestion_migrate convenience wrappers ARE the
    generic weighted migrate under the corresponding vectors."""
    cfg = make_cfg()
    net_spec, sims, rp = build_scenario(MIXED_BURSTY, cfg, seeds=(2,))
    sim = jax.tree.map(lambda x: x[0], sims)
    # drive the state into an overloaded shape
    hs = sim.hosts._replace(
        used=sim.hosts.used.at[0].set(0.9 * sim.hosts.cap[0]),
        n_containers=sim.hosts.n_containers.at[0].set(1))
    ct = sim.containers
    ct = ct._replace(status=ct.status.at[0].set(1), host=ct.host.at[0].set(0))
    sim = sim._replace(hosts=hs, containers=ct)
    for name, wrapper in [("overload_migrate", sched.overload_migrate),
                          ("netaware", sched.congestion_migrate)]:
        c1, d1 = wrapper(sim, cfg, rp)
        c2, d2 = sched.migrate(sim, cfg, rp, get_policy(name))
        assert int(c1) == int(c2) and int(d1) == int(d2), name
