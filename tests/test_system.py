"""End-to-end behaviour of the DCSim simulator against the paper's claims
(Figs 4-8; see EXPERIMENTS.md §Paper-validation for the full sweeps)."""
import numpy as np
import pytest

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim,
                        summarize)
from repro.core.network import set_link_params

POLICIES = ["firstfit", "round", "performance_first", "jobgroup",
            "overload_migrate"]


def run_policy(name, cfg=None, bw=None, loss=None, seed=0):
    cfg = cfg or SimConfig()
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    if bw is not None or loss is not None:
        net = set_link_params(net, bw=bw, loss=loss)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    final, metrics = run_sim(sim0, cfg, get_policy(name), spec.n_hosts,
                             spec.n_nodes, cfg.horizon)
    return summarize(final, metrics), metrics


@pytest.fixture(scope="module")
def reports():
    return {name: run_policy(name) for name in POLICIES}


@pytest.mark.parametrize("name", POLICIES)
def test_all_containers_complete(reports, name):
    rep, _ = reports[name]
    assert rep["n_containers"] == 300
    assert rep["completion_rate"] == 1.0, rep


def test_running_queue_saturates_near_120(reports):
    """Paper Fig 4: 'the running queue stabilized after reaching 120'."""
    peaks = [reports[n][0]["peak_deployed"] for n in POLICIES]
    assert max(peaks) > 100, peaks
    assert max(peaks) < 150, peaks


def test_round_no_overload_early(reports):
    """Paper Fig 4a: Round has zero overloaded hosts during 0-8 s."""
    _, m = reports["round"]
    assert np.asarray(m.n_overloaded)[:8].max() == 0


def test_firstfit_overloads_before_round(reports):
    _, m_ff = reports["firstfit"]
    _, m_rd = reports["round"]
    ff = np.asarray(m_ff.n_overloaded)
    rd = np.asarray(m_rd.n_overloaded)
    first = lambda a: int(np.argmax(a > 0)) if (a > 0).any() else 10**9
    assert first(ff) <= first(rd)


def test_jobgroup_lowest_comm_time(reports):
    """Paper Fig 5: JobGroup lowest avg comm time; Round worst."""
    comm = {n: reports[n][0]["avg_comm_time"] for n in POLICIES}
    assert comm["jobgroup"] == min(comm.values()), comm
    assert comm["round"] >= comm["jobgroup"], comm


def test_overload_migrate_migrates(reports):
    rep, _ = reports["overload_migrate"]
    assert rep["total_migrations"] > 0


def test_decisions_stop_when_done(reports):
    """Paper Fig 6: scheduling decisions fall to ~zero once arrivals stop
    and capacity catches up."""
    _, m = reports["firstfit"]
    dec = np.asarray(m.decisions)
    assert dec.sum() >= 300                      # every container placed
    assert dec[:60].sum() >= 280                 # bulk placed early
    assert dec[-20:].sum() == 0                  # quiet at the end


def test_degraded_network_slows_comm():
    """Paper Figs 5/8: lower bandwidth / higher loss => higher comm time."""
    good, _ = run_policy("firstfit", bw=1000.0, loss=0.0)
    bad, _ = run_policy("firstfit", bw=200.0, loss=0.02)
    assert bad["avg_comm_time"] > good["avg_comm_time"]
    assert bad["avg_runtime"] > good["avg_runtime"]


def test_stretched_workload_empties_waiting_queue():
    """Paper Fig 9: arrivals over 100 s instead of 36 s => waiting ~ 0."""
    cfg = SimConfig(arrival_window=100.0, horizon=160)
    rep, m = run_policy("round", cfg=cfg)
    assert rep["completion_rate"] == 1.0
    waiting = np.asarray(m.n_inactive)
    # after warmup the backlog stays tiny compared to the packed workload
    assert waiting[20:].max() <= 30


def test_seed_determinism():
    a, _ = run_policy("jobgroup", seed=3)
    b, _ = run_policy("jobgroup", seed=3)
    assert a == b
