"""Engine invariants — hypothesis property tests over random workloads.

The properties the SoA port must preserve from the paper's process model:
  * resource conservation: host ``used`` == sum of deployed containers' req;
  * status legality: every container is in exactly one Table-2 state;
  * monotone completion: completed stays completed, finish_t set once;
  * cost monotonicity.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (requirements-dev)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, paper_workload, run_sim)
from repro.core.types import (STATUS_COMMUNICATING, STATUS_COMPLETED,
                              STATUS_MIGRATING, STATUS_RUNNING)


def small_cfg(n_jobs, n_containers, horizon):
    return SimConfig(n_jobs=n_jobs, n_tasks=n_containers,
                     n_containers=n_containers, horizon=horizon,
                     arrival_window=10.0, placements_per_tick=16,
                     migrations_per_tick=2)


def run(seed, policy, n_jobs=10, n_containers=40, horizon=60):
    cfg = small_cfg(n_jobs, n_containers, horizon)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=seed), net, seed=seed)
    final, metrics = run_sim(sim0, cfg, get_policy(policy), spec.n_hosts,
                             spec.n_nodes, horizon)
    return cfg, final, metrics


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["firstfit", "round", "performance_first",
                               "jobgroup", "overload_migrate"]))
def test_resource_conservation(seed, policy):
    """host.used must equal the sum of requests of deployed containers
    (+ reserved destinations of in-flight migrations)."""
    cfg, final, _ = run(seed, policy)
    ct, hosts = final.containers, final.hosts
    st_ = np.asarray(ct.status)
    host = np.asarray(ct.host)
    req = np.asarray(ct.req)
    mig_dst = np.asarray(ct.mig_dst)
    H = np.asarray(hosts.cap).shape[0]

    expect = np.zeros((H, 3), np.float64)
    deployed = np.isin(st_, [STATUS_RUNNING, STATUS_COMMUNICATING,
                             STATUS_MIGRATING])
    for c in np.where(deployed)[0]:
        expect[host[c]] += req[c]
    for c in np.where(st_ == STATUS_MIGRATING)[0]:
        expect[mig_dst[c]] += req[c]           # reserved on destination
    np.testing.assert_allclose(np.asarray(hosts.used), expect,
                               rtol=1e-4, atol=1e-2)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["firstfit", "jobgroup", "overload_migrate"]))
def test_capacity_never_exceeded(seed, policy):
    cfg, final, _ = run(seed, policy)
    used = np.asarray(final.hosts.used)
    cap = np.asarray(final.hosts.cap)
    assert (used <= cap + 1e-3).all(), (used - cap).max()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_completion_consistency(seed):
    cfg, final, _ = run(seed, "firstfit", horizon=100)
    ct = final.containers
    st_ = np.asarray(ct.status)
    done = st_ == STATUS_COMPLETED
    fin = np.asarray(ct.finish_t)
    run_at = np.asarray(ct.run_at)
    dur = np.asarray(ct.duration)
    assert (fin[done] >= 0).all()
    assert (run_at[done] >= dur[done] - 1e-3).all()
    # undeployed completed containers hold no host slot
    assert (np.asarray(ct.host)[done] == -1).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_queue_counts_partition_containers(seed):
    """Every tick: queue counts sum to the number of *born* containers."""
    cfg, final, metrics = run(seed, "round", horizon=50)
    born = int(np.isfinite(np.asarray(final.containers.submit_t)).sum())
    total = (np.asarray(metrics.n_inactive) + np.asarray(metrics.n_deployed)
             + np.asarray(metrics.n_completed))
    arrived = np.cumsum(np.asarray(metrics.new_arrivals))
    np.testing.assert_array_equal(total, arrived)
    assert arrived[-1] == born


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_cost_monotone_nonnegative(seed):
    cfg, final, metrics = run(seed, "performance_first")
    assert float(final.total_cost) >= 0.0
    busy = np.asarray(final.hosts.busy_time)
    assert (busy >= 0).all() and busy.max() <= cfg.horizon
