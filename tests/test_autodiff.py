"""Differentiable soft placement (SimConfig.soft_placement).

The contract under test, in order of importance:
  * soft placement NEVER changes the simulation — final state and every
    hard metric are bit-for-bit identical to ``soft_placement=False``
    for all six built-in policies (the surrogate only ADDS observables);
  * ``jax.grad`` through the compiled sweep matches central differences;
  * the chunked (streamed) gradient equals the stacked gradient in <= 2
    compilations — every state-mediated path crosses an integer
    decision, so no cross-chunk adjoint exists to lose;
  * as ``tau -> 0`` the softmax relaxation anneals to the hard argmin;
  * at equal hard-oracle budget, gradient tuning beats random search.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SimConfig, build_paper_hosts, build_paper_network,
                        get_policy, init_sim, list_policies, paper_workload,
                        run_sim, stats)
from repro.core.scenario import ScenarioSpec, build_scenarios
from repro.core.scheduling import soft_assign, weight_index
from repro.core.types import PolicyParams
from repro.launch.sweep import make_grad_fn, make_sweep_fn
from repro.launch.tune import run_tune, run_tune_grad


def small_cfg(**kw):
    kw.setdefault("n_jobs", 10)
    kw.setdefault("n_tasks", 40)
    kw.setdefault("n_containers", 40)
    kw.setdefault("horizon", 30)
    return SimConfig(arrival_window=10.0, placements_per_tick=16,
                     migrations_per_tick=2, **kw)


SOFT_FIELDS = ("soft_comm", "soft_util", "soft_n", "soft_mig", "soft_mig_n")


# --------------------------------------------------------------------------
# soft_placement=False must be the PR-8 simulator, and soft_placement=True
# must not perturb it
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", list_policies())
def test_soft_flag_never_changes_dynamics(policy):
    """Hard run vs soft run: identical final state, identical hard
    metrics, for every built-in policy — the relaxation is observability,
    not dynamics."""
    cfg = small_cfg()
    soft = dataclasses.replace(cfg, soft_placement=True)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=3), net, seed=3)
    pol = get_policy(policy)
    f_hard, m_hard = run_sim(sim0, cfg, pol, spec.n_hosts, spec.n_nodes,
                             cfg.horizon)
    f_soft, m_soft = run_sim(sim0, soft, pol, spec.n_hosts, spec.n_nodes,
                             cfg.horizon)
    for a, b in zip(jax.tree.leaves(f_hard), jax.tree.leaves(f_soft)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in m_hard._fields:
        if name in SOFT_FIELDS:
            continue
        np.testing.assert_array_equal(np.asarray(getattr(m_hard, name)),
                                      np.asarray(getattr(m_soft, name)),
                                      err_msg=name)
    # and the soft run actually measured something
    assert float(np.asarray(m_soft.soft_n).sum()) > 0
    assert float(np.asarray(m_hard.soft_n).sum()) == 0.0


def test_tau_never_changes_dynamics():
    """tau only scales the surrogate softmax: wildly different
    temperatures produce bit-identical states (else annealing would be
    re-running a different simulator every step)."""
    cfg = small_cfg(soft_placement=True)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=5), net, seed=5)
    pol = get_policy("netaware")
    outs = []
    for tau in (0.05, 5.0):
        params = cfg.run_params()._replace(tau=jnp.float32(tau))
        f, m = run_sim(sim0, cfg, pol, spec.n_hosts, spec.n_nodes,
                       cfg.horizon, params=params)
        outs.append((f, m))
    (f0, m0), (f1, m1) = outs
    for a, b in zip(jax.tree.leaves(f0), jax.tree.leaves(f1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the surrogate DID move with tau (it is tau's only consumer)
    assert not np.allclose(np.asarray(m0.soft_comm).sum(),
                           np.asarray(m1.soft_comm).sum())


# --------------------------------------------------------------------------
# the relaxation itself
# --------------------------------------------------------------------------

def test_soft_assign_anneals_to_hard_argmin():
    row = jnp.asarray([3.0, 1.0, 2.0, 0.5], jnp.float32)
    feas = jnp.asarray([True, True, True, False])
    one_hot = soft_assign(row, feas, jnp.float32(1e-4))
    np.testing.assert_allclose(np.asarray(one_hot), [0.0, 1.0, 0.0, 0.0],
                               atol=1e-6)
    warm = np.asarray(soft_assign(row, feas, jnp.float32(10.0)))
    assert warm[3] == 0.0                      # infeasible stays exact 0
    assert np.all(warm[:3] > 0.1)              # near-uniform when hot
    np.testing.assert_allclose(warm.sum(), 1.0, rtol=1e-6)
    # all-infeasible: all-zero, not uniform, and NaN-free under grad
    none = soft_assign(row, jnp.zeros((4,), bool), jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(none), 0.0)
    g = jax.grad(lambda r: soft_assign(r, feas, jnp.float32(0.5)).sum())(row)
    assert np.isfinite(np.asarray(g)).all()


def test_annealing_converges_run_level():
    """Whole-run surrogate sums converge as tau -> 0 (successive halvings
    approach a fixed point) and that limit is NOT the hot-tau value."""
    cfg = small_cfg(soft_placement=True)
    hosts = build_paper_hosts()
    spec, net = build_paper_network(cfg)
    sim0 = init_sim(hosts, paper_workload(cfg, seed=5), net, seed=5)
    pol = get_policy("netaware")

    def surrogate(tau):
        params = cfg.run_params()._replace(tau=jnp.float32(tau))
        _, m = run_sim(sim0, cfg, pol, spec.n_hosts, spec.n_nodes,
                       cfg.horizon, params=params)
        return float(np.asarray(m.soft_comm).sum())

    v = {tau: surrogate(tau) for tau in (2.0, 1e-2, 1e-4, 2e-5)}
    np.testing.assert_allclose(v[1e-4], v[2e-5], rtol=1e-4)   # converged
    lim = v[2e-5]
    assert abs(v[1e-2] - lim) <= abs(v[2.0] - lim)            # monotone-ish
    assert abs(v[2.0] - lim) > 1e-3            # annealing actually moved


# --------------------------------------------------------------------------
# jax.grad through the compiled sweep
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def grad_setup():
    cfg = small_cfg(soft_placement=True)
    scen = [ScenarioSpec("baseline"), ScenarioSpec("slow_net", bw=200.0)]
    net_spec, sims, rps = build_scenarios(scen, cfg, seeds=(0,))
    return cfg, net_spec, sims, rps


def test_grad_matches_central_differences(grad_setup):
    """Directional derivative vs central differences THROUGH the compiled
    sweep: batch [w, w+eps*d, w-eps*d] on the policy axis, so one call
    yields the gradient and both FD probes from the same executable.

    The surrogate is piecewise-smooth: the hard argmin trajectory is
    locally constant in w, and FD is only valid on a piece.  Two
    precautions make the probe land on one: the base point adds random
    offsets to the searched row weights (the built-ins' clean weights sit
    ON tie boundaries — identical idle hosts score exactly equal, and
    ANY perturbation flips the tie-break), and eps shrinks until all
    three runs produce the SAME final state (no decision flipped).  The
    direction stays off util/cross_leaf: those feed the continuous
    ``net.comm_cost`` refresh, so final states can never be bit-equal
    along them (the chunked-grad test covers that channel)."""
    cfg, net_spec, sims, rps = grad_setup
    gfn = make_grad_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                       objective="soft_blend")
    swp = make_sweep_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                        cfg.horizon)
    dims = [weight_index(n) for n in
            ("row_comm", "row_coloc", "row_worst_fit", "row_cross_leaf")]
    rng = np.random.default_rng(11)
    w = np.asarray(get_policy("netaware").weights, np.float32).copy()
    w[dims] += rng.uniform(0.05, 0.4, len(dims)).astype(np.float32)
    d = np.zeros_like(w)
    d[dims] = rng.normal(size=len(dims)).astype(np.float32)
    d /= np.linalg.norm(d)

    def same_trajectory(W):
        finals, _ = swp(sims, PolicyParams(weights=jnp.asarray(W)), rps)
        return all((np.asarray(x)[0] == np.asarray(x)[1]).all()
                   and (np.asarray(x)[0] == np.asarray(x)[2]).all()
                   for x in jax.tree.leaves(finals))

    for eps in (2e-2, 1e-2, 5e-3, 2e-3, 1e-3):
        W = np.stack([w, w + eps * d, w - eps * d]).astype(np.float32)
        if same_trajectory(W):
            break
    else:
        pytest.fail("no flip-free eps found for the FD probe")
    vals, grads = gfn(sims, PolicyParams(weights=jnp.asarray(W)), rps)
    vals = np.asarray(vals, np.float64)
    fd = (vals[1] - vals[2]) / (2 * eps)
    analytic = float(np.asarray(grads)[0] @ d)
    assert abs(analytic) > 1e-6                # a real, nonzero derivative
    np.testing.assert_allclose(analytic, fd, rtol=1e-2, atol=1e-5)
    assert gfn._cache_size() == 1


def test_chunked_grad_matches_stacked(grad_setup):
    """Streaming the horizon must not change the gradient: every
    decision-mediated state path crosses an integer argmin and carries
    zero cotangent, so the per-chunk gradients sum to the stacked one.

    The ONE exception (docs/autodiff.md): the periodic delay refresh
    bakes weights[util]/weights[cross_leaf] into the persistent
    ``net.comm_cost`` cache, a continuous path the chunked gradient
    truncates at chunk boundaries (truncated-BPTT semantics).  So: a
    boundary after the admit window is exact on ALL components; a
    boundary inside it is exact on every component EXCEPT those two.
    Values are exact either way, in <= 2 compilations (main + tail)."""
    cfg, net_spec, sims, rps = grad_setup
    W = np.stack([np.asarray(get_policy("netaware").weights),
                  np.asarray(get_policy("jobgroup").weights)])
    pols = PolicyParams(weights=jnp.asarray(W))
    gfn_s = make_grad_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, objective="soft_blend")
    v_s, g_s = gfn_s(sims, pols, rps)
    g_s = np.asarray(g_s)

    # boundaries at 10/20 — past the 10-tick admit window: exact
    gfn_c = make_grad_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, objective="soft_blend", chunk=10)
    v_c, g_c = gfn_c(sims, pols, rps)
    np.testing.assert_allclose(np.asarray(v_s), np.asarray(v_c), rtol=1e-5)
    np.testing.assert_allclose(g_s, np.asarray(g_c), rtol=1e-4, atol=1e-7)
    assert gfn_c._cache_size() == 1            # 30 = 3 x 10, no tail

    # boundaries at 8/16/24 — mid-window: truncated ONLY on the two
    # comm-cost cache weights, exact everywhere else + a ragged tail
    gfn_t = make_grad_fn(cfg, net_spec.n_hosts, net_spec.n_nodes,
                         cfg.horizon, objective="soft_blend", chunk=8)
    v_t, g_t = gfn_t(sims, pols, rps)
    np.testing.assert_allclose(np.asarray(v_s), np.asarray(v_t), rtol=1e-5)
    cache_dims = [weight_index("util"), weight_index("cross_leaf")]
    exact = np.ones(g_s.shape[1], bool)
    exact[cache_dims] = False
    np.testing.assert_allclose(g_s[:, exact], np.asarray(g_t)[:, exact],
                               rtol=1e-4, atol=1e-7)
    assert gfn_t._cache_size() <= 2
    assert np.isfinite(np.asarray(g_t)).all()


def test_grad_fn_rejects_hard_config_and_unknown_objective(grad_setup):
    cfg, net_spec, *_ = grad_setup
    hard = dataclasses.replace(cfg, soft_placement=False)
    with pytest.raises(ValueError, match="soft_placement"):
        make_grad_fn(hard, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon)
    with pytest.raises(KeyError):
        make_grad_fn(cfg, net_spec.n_hosts, net_spec.n_nodes, cfg.horizon,
                     objective="avg_runtime")
    assert set(stats.SOFT_OBJECTIVES) >= {"soft_blend", "soft_comm",
                                          "soft_util"}


# --------------------------------------------------------------------------
# the point of it all: gradient tuning beats random search
# --------------------------------------------------------------------------

def test_grad_tune_beats_random_at_equal_oracle_budget():
    """slow_net avg_runtime, 12 hard-oracle evaluations each: descending
    the soft surrogate finds strictly better weights than 12 uniform
    draws (both populations include the netaware incumbent, so neither
    can rank below it)."""
    cfg = small_cfg()
    scen = [ScenarioSpec("slow_net", bw=200.0)]
    g = run_tune_grad(steps=6, batch=4, eval_every=3, lr=0.3, cfg=cfg,
                      scenarios=scen, seeds=(0,), objective="avg_runtime",
                      seed=0)
    assert g.oracle_evals == 12
    r = run_tune(n_samples=g.oracle_evals, cfg=cfg, scenarios=scen,
                 seeds=(0,), objective="avg_runtime", seed=0)
    assert np.isfinite(g.best_oracle)
    assert g.best_oracle < float(r.scores[r.best])
    # surrogate + trajectory reporting came along
    assert g.surrogate is not None and g.surrogate.shape == (4,)
    assert [h["tau"] for h in g.history] == sorted(
        [h["tau"] for h in g.history], reverse=True)
    assert g.best_oracle_weights is not None
