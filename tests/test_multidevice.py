"""Multi-device integration guard: the optimized distribution configs
(seq_parallel=full, moe_impl=a2a) must produce the same training loss as
the single-device baseline.  Runs in a subprocess with 8 fake CPU devices
(the main test process must keep exactly 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.launch.mesh import compat_mesh
from repro.models import sharding as shd
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

out = {}
for arch, overrides in [
    ("smollm_360m", {"seq_parallel": "full"}),
    ("olmoe_1b_7b", {"moe_impl": "a2a", "capacity_factor": 2.0}),
    ("qwen2_5_3b", {"seq_parallel": "full"}),
]:
    base = get_reduced(arch)
    rng = np.random.default_rng(0)
    B, S = 8, 32
    batch = {"tokens": jnp.asarray(rng.integers(0, base.vocab, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, base.vocab, (B, S)), jnp.int32)}

    losses = {}
    for name, cfg, mesh in [
        ("1dev", base, None),
        ("8dev", dataclasses.replace(base, **overrides),
         compat_mesh((2, 4), ("data", "model"))),
    ]:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        dp = ("data",)
        step = make_train_step(cfg, OptimizerConfig(), mesh=mesh, dp=dp)
        if mesh is not None:
            with mesh:
                pspec = shd.param_specs(cfg, state.params, mesh)
                shardings = type(state)(
                    params=shd.to_shardings(pspec, mesh),
                    opt=type(state.opt)(m=shd.to_shardings(pspec, mesh),
                                        v=shd.to_shardings(pspec, mesh),
                                        step=NamedSharding(mesh, P())))
                state = jax.device_put(state, shardings)
                _, m = jax.jit(step)(state, batch)
                losses[name] = float(m["loss"])
        else:
            _, m = jax.jit(step)(state, batch)
            losses[name] = float(m["loss"])
    out[arch] = losses
print(json.dumps(out))
"""


@pytest.mark.slow
def test_optimized_configs_match_baseline_loss():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for arch, losses in out.items():
        # same params/batch; sharded math is bf16-reduction-order sensitive
        assert abs(losses["1dev"] - losses["8dev"]) < 0.05, (arch, losses)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.distributed import checkpoint as ckpt
from repro.launch.mesh import compat_mesh
from repro.models import sharding as shd
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_train_state, make_train_step

cfg = get_reduced("qwen2_5_3b")
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}

def sharded_state(mesh, state):
    pspec = shd.param_specs(cfg, state.params, mesh)
    sh = type(state)(params=shd.to_shardings(pspec, mesh),
                     opt=type(state.opt)(m=shd.to_shardings(pspec, mesh),
                                         v=shd.to_shardings(pspec, mesh),
                                         step=NamedSharding(mesh, P())))
    return jax.device_put(state, sh), sh

# "2-pod" mesh: (pod=2, data=2, model=2); train 2 steps; checkpoint
mesh_big = compat_mesh((2, 2, 2), ("pod", "data", "model"))
state = init_train_state(cfg, jax.random.PRNGKey(0))
with mesh_big:
    state, _ = sharded_state(mesh_big, state)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(), mesh=mesh_big,
                                   dp=("pod", "data")))
    for s in range(2):
        state, m = step(state, batch)
    loss_big = float(m["loss"])

d = tempfile.mkdtemp() + "/step_2"
ckpt.save_checkpoint(d, state, 2)

# elastic downsize: restore the same checkpoint onto a 1-pod (2,2) mesh
# (pod lost), continue training — the DCSim fault plan's 'elastic_downsize'
mesh_small = compat_mesh((2, 2), ("data", "model"))
with mesh_small:
    fresh = init_train_state(cfg, jax.random.PRNGKey(0))
    _, sh_small = sharded_state(mesh_small, fresh)
    restored, step_idx = ckpt.restore_checkpoint(d, fresh, shardings=sh_small)
    step2 = jax.jit(make_train_step(cfg, OptimizerConfig(), mesh=mesh_small,
                                    dp=("data",)))
    restored2, m2 = step2(restored, batch)
    loss_small = float(m2["loss"])

# the restored params are bit-identical; the next-step loss must be very
# close to what the big mesh would produce (reduction-order noise only)
with mesh_big:
    state3, m3 = step(state, batch)
    loss_big_next = float(m3["loss"])
print(json.dumps({"step_idx": step_idx, "loss_small": loss_small,
                  "loss_big_next": loss_big_next}))
"""


@pytest.mark.slow
def test_elastic_downsize_restores_across_meshes():
    """2-pod checkpoint -> 1-pod mesh restore -> training continues with
    matching loss (the recovery path of distributed/fault.plan_recovery)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["step_idx"] == 2
    assert abs(out["loss_small"] - out["loss_big_next"]) < 0.05, out
